"""Manifest / artifact consistency checks (the L2 ⇄ L3 ABI)."""

import json
import os

import pytest

from compile.config import BertConfig, CnnConfig, act_sites, chunk_bounds

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def load():
    with open(MANIFEST) as f:
        return json.load(f)


def test_all_hlo_files_exist_and_parse_headers():
    man = load()
    for name, entry in man["executables"].items():
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, f"{name} does not look like HLO text"


def test_bert_io_counts():
    man = load()
    cfg = BertConfig()
    nparams = len(cfg.param_order())
    for b in (1, 8, 32):
        e = man["executables"][f"bert_fwd_b{b}"]
        assert len(e["inputs"]) == nparams + 2
        assert e["inputs"][-2]["name"] == "input_ids"
        assert e["inputs"][-2]["shape"] == [b, cfg.max_len]
        assert e["outputs"][0]["shape"] == [b, cfg.num_classes]
    t = man["executables"]["bert_train_step_b32"]
    assert len(t["inputs"]) == 3 * nparams + 5
    assert len(t["outputs"]) == 3 * nparams + 1
    assert t["outputs"][-1]["name"] == "loss"


def test_param_order_roundtrip():
    man = load()
    cfg = BertConfig()
    got = [(n, tuple(s)) for n, s in man["bert_param_order"]]
    assert got == cfg.param_order()
    ccfg = CnnConfig()
    got = [(n, tuple(s)) for n, s in man["cnn_param_order"]]
    assert got == ccfg.param_order()


def test_act_sites_table():
    man = load()
    cfg = BertConfig()
    sites = act_sites(cfg)
    assert len(man["act_sites"]) == len(sites) == 3 * cfg.layers + 2
    for entry, (name, width) in zip(man["act_sites"], sites):
        assert entry["name"] == name
        assert entry["width"] == width
        assert entry["bounds"] == chunk_bounds(width)


def test_manifest_dtypes_are_known():
    man = load()
    for e in man["executables"].values():
        for io in e["inputs"] + e["outputs"]:
            assert io["dtype"] in ("f32", "i32", "i8")
