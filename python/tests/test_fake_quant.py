"""Kernel-vs-oracle tests for the fake-quant Pallas kernel (L1 correctness)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fake_quant import fake_quant, fake_quant_scalar


def _rand(shape, seed=0, lo=-4.0, hi=4.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(8, 16), (64, 96), (256, 512), (1, 1), (3, 7)])
def test_matches_ref(bits, shape):
    x = _rand(shape, seed=bits)
    scale, zp = ref.qparams(float(x.min()), float(x.max()), bits)
    out = fake_quant_scalar(x, float(scale), float(zp), bits)
    exp = ref.fake_quant_bits_ref(x, scale, zp, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_idempotent(bits):
    """fq(fq(x)) == fq(x): quantization is a projection."""
    x = _rand((32, 48), seed=11)
    scale, zp = ref.qparams(float(x.min()), float(x.max()), bits)
    once = fake_quant_scalar(x, float(scale), float(zp), bits)
    twice = fake_quant_scalar(once, float(scale), float(zp), bits)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-6)


def test_codebook_size():
    """INT-b fake-quant emits at most 2^b distinct values."""
    x = _rand((128, 128), seed=5)
    for bits in (2, 4):
        scale, zp = ref.qparams(float(x.min()), float(x.max()), bits)
        out = np.asarray(fake_quant_scalar(x, float(scale), float(zp), bits))
        assert len(np.unique(out)) <= 2**bits


def test_outlier_crushes_resolution():
    """The paper's §1 motivating example: one huge outlier collapses the rest."""
    base = np.array([[-1000.0, -500.0, 0.0, 500.0, 1000.0]], np.float32)
    x_clean = jnp.asarray(base)
    x_dirty = jnp.asarray(np.array([[-1000.0, -500.0, 0.0, 500.0, 1e8]], np.float32))
    bits = 4
    s1, z1 = ref.qparams(float(x_clean.min()), float(x_clean.max()), bits)
    s2, z2 = ref.qparams(float(x_dirty.min()), float(x_dirty.max()), bits)
    clean = np.unique(np.asarray(fake_quant_scalar(x_clean, float(s1), float(z1), bits)))
    dirty = np.asarray(fake_quant_scalar(x_dirty, float(s2), float(z2), bits))[0]
    assert len(clean) == 5  # all distinct without the outlier
    # with the outlier the four small values collapse onto <= 2 codes
    assert len(np.unique(dirty[:4])) <= 2


def test_degenerate_range():
    """Constant tensors quantize without inf/nan (span widened to 1e-8)."""
    x = jnp.full((4, 4), 1.234, jnp.float32)
    scale, zp = ref.qparams(1.234, 1.234, 8)
    out = np.asarray(fake_quant_scalar(x, float(scale), float(zp), 8))
    assert np.isfinite(out).all()


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 65),
    cols=st.integers(1, 130),
    bits=st.sampled_from([2, 3, 4, 8]),
    lo=st.floats(-100.0, -0.01),
    span=st.floats(0.02, 1000.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(rows, cols, bits, lo, span, seed):
    """Property sweep: arbitrary shapes / ranges / bit-widths match the oracle
    and stay inside the dequantized codebook range."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(lo, lo + span, size=(rows, cols)).astype(np.float32))
    scale, zp = ref.qparams(float(x.min()), float(x.max()), bits)
    out = fake_quant_scalar(x, float(scale), float(zp), bits)
    exp = ref.fake_quant_bits_ref(x, scale, zp, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)
    qmin, qmax = ref.qrange(bits)
    lo_dq = (qmin - float(zp)) / float(scale)
    hi_dq = (qmax - float(zp)) / float(scale)
    assert np.asarray(out).min() >= lo_dq - 1e-4
    assert np.asarray(out).max() <= hi_dq + 1e-4


@settings(max_examples=15, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantization_error_bound(bits, seed):
    """In-range values reconstruct within half a quantization step."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-2.0, 2.0, size=(16, 16)).astype(np.float32))
    scale, zp = ref.qparams(float(x.min()), float(x.max()), bits)
    out = np.asarray(fake_quant_scalar(x, float(scale), float(zp), bits))
    step = 1.0 / float(scale)
    # interior values (not clipped) are within step/2 (+ float slack)
    err = np.abs(out - np.asarray(x))
    assert err.max() <= step / 2 + step * 1e-3
