"""L2 model tests: shapes, training dynamics, activation-split identities."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.config import BertConfig, act_sites, chunk_bounds
from compile import model as M
from compile.kernels import ref

TINY = BertConfig(vocab_size=64, hidden=16, layers=2, heads=2, ffn=32, max_len=12, num_classes=4)


def init_params(cfg: BertConfig, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in cfg.param_order():
        if name.endswith(".gamma"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".beta", ".bias")):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.normal(0, 0.05, size=shape).astype(np.float32)))
    return out


def batch(cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, cfg.max_len)).astype(np.int32))
    lens = rng.integers(3, cfg.max_len + 1, size=b)
    mask = np.zeros((b, cfg.max_len), np.float32)
    for i, l in enumerate(lens):
        mask[i, :l] = 1.0
    labels = jnp.asarray(rng.integers(0, cfg.num_classes, size=(b,)).astype(np.int32))
    return ids, jnp.asarray(mask), labels


def test_forward_shape_and_finite():
    p = init_params(TINY)
    ids, mask, _ = batch(TINY, 5)
    (logits,) = M.bert_forward(TINY, p, ids, mask)
    assert logits.shape == (5, TINY.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_forward_ignores_padding_tokens():
    """Changing token ids under the padding mask must not change logits."""
    p = init_params(TINY)
    ids, mask, _ = batch(TINY, 4, seed=3)
    (logits1,) = M.bert_forward(TINY, p, ids, mask)
    noise = np.asarray(ids).copy()
    m = np.asarray(mask) == 0.0
    noise[m] = (noise[m] + 17) % TINY.vocab_size
    (logits2,) = M.bert_forward(TINY, p, jnp.asarray(noise), mask)
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2), atol=1e-4)


def test_train_step_reduces_loss():
    """A few Adam steps on a fixed batch must drive the loss down hard."""
    cfg = TINY
    p = init_params(cfg, seed=1)
    m = [jnp.zeros_like(t) for t in p]
    v = [jnp.zeros_like(t) for t in p]
    ids, mask, labels = batch(cfg, 8, seed=2)
    lr = jnp.asarray([5e-3], jnp.float32)
    losses = []
    for step in range(30):
        out = M.bert_train_step(cfg, p, m, v, jnp.asarray([step], jnp.int32), ids, mask, labels, lr)
        n = len(p)
        p = list(out[:n])
        m = list(out[n : 2 * n])
        v = list(out[2 * n : 3 * n])
        losses.append(float(out[-1][0]))
    assert losses[-1] < losses[0] * 0.25, losses
    assert all(np.isfinite(l) for l in losses)


def test_actquant_equal_triples_match_per_tensor_ref():
    """Equal (scale, zp) triples at a site == per-tensor fake-quant of the
    whole activation: the baseline path is exactly recoverable (§4.2)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(6, 15)).astype(np.float32))
    scale, zp = ref.qparams(float(x.min()), float(x.max()), 4)
    bounds = chunk_bounds(15)
    scales = jnp.full((3,), scale, jnp.float32)
    zps = jnp.full((3,), zp, jnp.float32)
    qmin, qmax = ref.qrange(4)
    out = ref.chunked_fake_quant_ref(x, scales, zps, float(qmin), float(qmax), bounds)
    exp = ref.fake_quant_bits_ref(x, scale, zp, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


def test_actquant_graph_runs_and_fq_actually_bites():
    p = init_params(TINY, seed=4)
    ids, mask, _ = batch(TINY, 3, seed=5)
    S = len(act_sites(TINY))
    # generous ranges -> near-identity at 8 bits; tight INT2 must differ
    scales8 = jnp.full((S, 3), (2**8 - 1) / 20.0, jnp.float32)
    zps = jnp.zeros((S, 3), jnp.float32)
    (plain,) = M.bert_forward(TINY, p, ids, mask)

    def run(bits, scales):
        qmin = jnp.asarray([float(-(2 ** (bits - 1)))], jnp.float32)
        qmax = jnp.asarray([float(2 ** (bits - 1) - 1)], jnp.float32)
        (lq,) = M.bert_forward_actquant(TINY, p, ids, mask, scales, zps, qmin, qmax)
        return np.asarray(lq)

    l8 = run(8, scales8)
    np.testing.assert_allclose(l8, np.asarray(plain), atol=0.2)
    scales2 = jnp.full((S, 3), (2**2 - 1) / 20.0, jnp.float32)
    l2 = run(2, scales2)
    assert not np.allclose(l2, np.asarray(plain), atol=0.05)


def test_chunk_bounds():
    assert chunk_bounds(128) == [43, 86]
    assert chunk_bounds(512) == [171, 342]
    assert chunk_bounds(3) == [1, 2]
    # reconstructed sizes differ by at most 1
    for n in (3, 7, 16, 43, 128, 512, 513):
        b = chunk_bounds(n)
        sizes = np.diff([0] + b + [n])
        assert sizes.sum() == n and sizes.max() - sizes.min() <= 1


def test_param_order_is_stable():
    """The flat parameter ABI shared with Rust must never silently change."""
    cfg = BertConfig()
    order = cfg.param_order()
    assert len(order) == 40
    assert order[0] == ("embeddings.token", (8192, 128))
    assert order[-1] == ("classifier.bias", (6,))
    total = sum(int(np.prod(s)) for _, s in order)
    assert total == 1_470_854, total
