"""Kernel-vs-oracle tests for the 1-D k-means assignment kernel."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.cluster_assign import cluster_assign


@pytest.mark.parametrize("shape", [(8, 8), (128, 128), (3, 5), (1, 1)])
@pytest.mark.parametrize("k", [2, 3, 5])
def test_matches_ref(shape, k):
    rng = np.random.default_rng(shape[0] * 31 + k)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    cents = jnp.asarray(np.sort(rng.normal(size=(1, k)).astype(np.float32), axis=1))
    out = cluster_assign(x, cents)
    exp = ref.cluster_assign_ref(x, cents[0])
    assert np.array_equal(np.asarray(out), np.asarray(exp))


def test_tie_breaks_to_lowest_index():
    """Value equidistant from two centroids goes to the lower index, like
    jnp.argmin and the Rust kmeans."""
    x = jnp.asarray(np.array([[0.0]], np.float32))
    cents = jnp.asarray(np.array([[-1.0, 1.0]], np.float32))
    out = np.asarray(cluster_assign(x, cents))
    assert out[0, 0] == 0


def test_sorted_centroids_give_monotone_assignment():
    """With sorted centroids, assignments are monotone in the value — this is
    the lower/middle/upper cluster structure SplitQuant relies on (§4.1)."""
    x = jnp.asarray(np.linspace(-3, 3, 256, dtype=np.float32).reshape(1, 256))
    cents = jnp.asarray(np.array([[-2.0, 0.0, 2.0]], np.float32))
    out = np.asarray(cluster_assign(x, cents))[0]
    assert (np.diff(out) >= 0).all()
    assert set(np.unique(out)) == {0, 1, 2}


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 40),
    cols=st.integers(1, 70),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(rows, cols, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-10, 10, size=(rows, cols)).astype(np.float32))
    cents = jnp.asarray(rng.uniform(-10, 10, size=(1, k)).astype(np.float32))
    out = np.asarray(cluster_assign(x, cents))
    exp = np.asarray(ref.cluster_assign_ref(x, cents[0]))
    assert np.array_equal(out, exp)
    # invariant: every element is genuinely nearest to its assigned centroid
    c = np.asarray(cents)[0]
    xn = np.asarray(x)
    d_assigned = (xn - c[out]) ** 2
    d_all = (xn[..., None] - c) ** 2
    assert (d_assigned <= d_all.min(axis=-1) + 1e-12).all()
