"""Exporter machinery tests: HLO text emission + manifest bookkeeping on a
trivial function (fast — no model lowering)."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot
from compile.aot import Exporter, spec, to_hlo_text
from compile.kernels.fake_quant import _pick_block


def test_pick_block_divides():
    for n in (1, 7, 64, 100, 2048):
        for target in (1, 32, 128, 512):
            b = _pick_block(n, target)
            assert n % b == 0
            assert 1 <= b <= max(1, min(n, target))


def test_to_hlo_text_produces_parseable_module():
    import jax

    lowered = jax.jit(lambda x: (x * 2.0,)).lower(spec((2, 3)))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    # return_tuple=True: the root is a tuple
    assert "tuple" in text.lower()


def test_exporter_writes_files_and_manifest(tmp_path):
    ex = Exporter(str(tmp_path))
    ins = [("x", spec((2, 2)))]
    outs = [("y", spec((2, 2)))]
    ex.export("double", lambda x: (x + x,), ins, outs, meta={"kind": "demo"})
    ex.finish({"extra": {"a": 1}})

    assert (tmp_path / "double.hlo.txt").exists()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["executables"]["double"]["file"] == "double.hlo.txt"
    assert man["executables"]["double"]["inputs"] == [
        {"name": "x", "shape": [2, 2], "dtype": "f32"}
    ]
    assert man["executables"]["double"]["meta"]["kind"] == "demo"
    assert man["extra"] == {"a": 1}


def test_exporter_dtype_names(tmp_path):
    ex = Exporter(str(tmp_path))
    ins = [
        ("a", spec((2,), jnp.int32)),
        ("b", spec((2, 2), jnp.int8)),
        ("c", spec((1,), jnp.float32)),
    ]
    outs = [("y", spec((2,), jnp.int32))]
    ex.export(
        "mixed",
        lambda a, b, c: (a + jnp.sum(b.astype(jnp.int32), axis=0) + c.astype(jnp.int32),),
        ins,
        outs,
    )
    ex.finish({})
    man = json.loads((tmp_path / "manifest.json").read_text())
    dts = [i["dtype"] for i in man["executables"]["mixed"]["inputs"]]
    assert dts == ["i32", "i8", "f32"]


def test_bert_param_specs_match_config():
    from compile.config import BertConfig

    cfg = BertConfig()
    specs = aot.bert_param_specs(cfg)
    assert len(specs) == len(cfg.param_order())
    for (name, s), (n2, shape) in zip(specs, cfg.param_order()):
        assert name == n2
        assert s.shape == tuple(shape)
        assert s.dtype == jnp.float32
