"""Kernel-vs-oracle tests for the split-dequant matmul (the SplitQuant hot path)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.split_matmul import split_matmul


def _mk(seed, m, k, n, clusters=3, bits=2):
    rng = np.random.default_rng(seed)
    qmin, qmax = ref.qrange(bits)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    qw = jnp.asarray(rng.integers(qmin, qmax + 1, size=(k, n)).astype(np.int8))
    cid = jnp.asarray(rng.integers(0, clusters, size=(k, n)).astype(np.int8))
    scales = jnp.asarray(rng.uniform(0.3, 5.0, size=(1, clusters)).astype(np.float32))
    zps = jnp.asarray(rng.integers(qmin, qmax + 1, size=(1, clusters)).astype(np.float32))
    return x, qw, cid, scales, zps


@pytest.mark.parametrize("mkn", [(4, 8, 8), (32, 128, 128), (32, 128, 512), (1, 16, 3)])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_matches_ref(mkn, bits):
    m, k, n = mkn
    x, qw, cid, scales, zps = _mk(bits * 1000 + m, m, k, n, bits=bits)
    out = split_matmul(x, qw, cid, scales, zps)
    exp = ref.split_matmul_ref(x, qw, cid, scales[0], zps[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-3, rtol=1e-4)


def test_equivalent_to_three_zero_padded_layers():
    """Figure 2 identity: on-the-fly cluster dequant == materializing the
    paper's three zero-padded split layers and summing their outputs."""
    m, k, n = 8, 32, 16
    x, qw, cid, scales, zps = _mk(7, m, k, n)
    out = np.asarray(split_matmul(x, qw, cid, scales, zps))

    total = np.zeros((m, n), np.float32)
    qwf = np.asarray(qw, np.float32)
    cidn = np.asarray(cid)
    for c in range(3):
        w_c = np.where(cidn == c, (qwf - float(zps[0, c])) / float(scales[0, c]), 0.0)
        total += np.asarray(x) @ w_c  # one split layer, zeros injected
    np.testing.assert_allclose(out, total, atol=1e-3, rtol=1e-4)


def test_single_cluster_is_plain_dequant_matmul():
    """k=1 degenerates to ordinary per-tensor dequant + matmul."""
    m, k, n = 8, 16, 8
    x, qw, _, _, _ = _mk(3, m, k, n, clusters=1)
    cid = jnp.zeros((k, n), jnp.int8)
    scales = jnp.asarray([[2.5]], jnp.float32)
    zps = jnp.asarray([[-1.0]], jnp.float32)
    out = split_matmul(x, qw, cid, scales, zps)
    w = (np.asarray(qw, np.float32) - (-1.0)) / 2.5
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) @ w, atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    clusters=st.integers(1, 5),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(m, k, n, clusters, bits, seed):
    x, qw, cid, scales, zps = _mk(seed, m, k, n, clusters=clusters, bits=bits)
    out = split_matmul(x, qw, cid, scales, zps)
    exp = ref.split_matmul_ref(x, qw, cid, scales[0], zps[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3, rtol=1e-3)
