"""CNN (conv + BN) model tests — the Figure 3 / BN-folding substrate."""

import numpy as np
import jax.numpy as jnp

from compile.config import CnnConfig
from compile import cnn as C

CFG = CnnConfig()


def init_params(cfg: CnnConfig, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in cfg.param_order():
        if name.endswith((".gamma",)) or name.endswith(".var"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".beta", ".bias")) or name.endswith(".mean"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.normal(0, 0.1, size=shape).astype(np.float32)))
    return out


def images(b, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, CFG.in_ch, CFG.image, CFG.image)).astype(np.float32))


def test_forward_shape():
    p = init_params(CFG)
    (logits,) = C.cnn_forward(CFG, p, images(7))
    assert logits.shape == (7, CFG.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_flat_dim():
    assert CFG.flat == 16 * 4 * 4


def test_bn_folding_identity():
    """BN folded into the preceding conv (paper §4.1) == original network.

    fold: w' = w * gamma / sqrt(var + eps) (per out-channel),
          b' = (b - mean) * gamma / sqrt(var + eps) + beta.
    Evaluated through the SAME eval-mode graph with identity BN params.
    """
    rng = np.random.default_rng(42)
    p = init_params(CFG, seed=1)
    order = [n for n, _ in CFG.param_order()]

    def idx(n):
        return order.index(n)

    # randomize BN params so folding is non-trivial
    for bn in ("bn1", "bn2"):
        ch = CFG.ch1 if bn == "bn1" else CFG.ch2
        p[idx(f"{bn}.gamma")] = jnp.asarray(rng.uniform(0.5, 2.0, ch).astype(np.float32))
        p[idx(f"{bn}.beta")] = jnp.asarray(rng.normal(0, 0.3, ch).astype(np.float32))
        p[idx(f"{bn}.mean")] = jnp.asarray(rng.normal(0, 0.5, ch).astype(np.float32))
        p[idx(f"{bn}.var")] = jnp.asarray(rng.uniform(0.2, 3.0, ch).astype(np.float32))

    x = images(5, seed=3)
    (orig,) = C.cnn_forward(CFG, p, x)

    folded = list(p)
    for conv, bn in (("conv1", "bn1"), ("conv2", "bn2")):
        w = np.asarray(p[idx(f"{conv}.weight")])
        b = np.asarray(p[idx(f"{conv}.bias")])
        g = np.asarray(p[idx(f"{bn}.gamma")])
        be = np.asarray(p[idx(f"{bn}.beta")])
        mu = np.asarray(p[idx(f"{bn}.mean")])
        var = np.asarray(p[idx(f"{bn}.var")])
        s = g / np.sqrt(var + CFG.bn_eps)
        folded[idx(f"{conv}.weight")] = jnp.asarray(w * s[:, None, None, None])
        folded[idx(f"{conv}.bias")] = jnp.asarray((b - mu) * s + be)
        ch = len(g)
        folded[idx(f"{bn}.gamma")] = jnp.ones(ch, jnp.float32)
        folded[idx(f"{bn}.beta")] = jnp.zeros(ch, jnp.float32)
        folded[idx(f"{bn}.mean")] = jnp.zeros(ch, jnp.float32)
        folded[idx(f"{bn}.var")] = jnp.full(ch, 1.0 - CFG.bn_eps, jnp.float32)

    (fold,) = C.cnn_forward(CFG, folded, x)
    np.testing.assert_allclose(np.asarray(orig), np.asarray(fold), atol=1e-4, rtol=1e-4)


def test_train_step_reduces_loss_and_updates_stats():
    p = init_params(CFG, seed=2)
    m = [jnp.zeros_like(t) for t in p]
    v = [jnp.zeros_like(t) for t in p]
    rng = np.random.default_rng(9)
    x = images(16, seed=8)
    labels = jnp.asarray(rng.integers(0, CFG.num_classes, size=(16,)).astype(np.int32))
    lr = jnp.asarray([1e-2], jnp.float32)
    order = [n for n, _ in CFG.param_order()]
    mean_before = np.asarray(p[order.index("bn1.mean")]).copy()
    losses = []
    for step in range(25):
        out = C.cnn_train_step(CFG, p, m, v, jnp.asarray([step], jnp.int32), x, labels, lr)
        n = len(p)
        p = list(out[:n])
        m = list(out[n : 2 * n])
        v = list(out[2 * n : 3 * n])
        losses.append(float(out[-1][0]))
    assert losses[-1] < losses[0] * 0.5, losses
    mean_after = np.asarray(p[order.index("bn1.mean")])
    assert not np.allclose(mean_before, mean_after)
