"""Shared model configuration for the SplitQuant reproduction.

This module is the single source of truth for model hyper-parameters and the
deterministic flat parameter ordering.  The same ordering is exported to
``artifacts/manifest.json`` so the Rust coordinator (L3) can build, feed and
update parameter lists without ever importing Python at runtime.

BERT-Tiny follows Turc et al. (2019): 2 layers, hidden 128, 2 heads, FFN 512.
The vocabulary is the synthetic hash-tokenizer vocabulary used by the Rust
data generators (see ``rust/src/data/tokenizer.rs``).
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class BertConfig:
    vocab_size: int = 8192
    hidden: int = 128
    layers: int = 2
    heads: int = 2
    ffn: int = 512
    max_len: int = 64
    num_classes: int = 6  # emotion has 6; spam uses the first 2 logits
    ln_eps: float = 1e-12

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def param_order(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Deterministic flat (name, shape) list — the ABI between L2 and L3."""
        h, f, v, l, c = self.hidden, self.ffn, self.vocab_size, self.max_len, self.num_classes
        out: List[Tuple[str, Tuple[int, ...]]] = [
            ("embeddings.token", (v, h)),
            ("embeddings.position", (l, h)),
            ("embeddings.ln.gamma", (h,)),
            ("embeddings.ln.beta", (h,)),
        ]
        for i in range(self.layers):
            p = f"encoder.{i}"
            out += [
                (f"{p}.attn.q.weight", (h, h)),
                (f"{p}.attn.q.bias", (h,)),
                (f"{p}.attn.k.weight", (h, h)),
                (f"{p}.attn.k.bias", (h,)),
                (f"{p}.attn.v.weight", (h, h)),
                (f"{p}.attn.v.bias", (h,)),
                (f"{p}.attn.out.weight", (h, h)),
                (f"{p}.attn.out.bias", (h,)),
                (f"{p}.attn.ln.gamma", (h,)),
                (f"{p}.attn.ln.beta", (h,)),
                (f"{p}.ffn.in.weight", (h, f)),
                (f"{p}.ffn.in.bias", (f,)),
                (f"{p}.ffn.out.weight", (f, h)),
                (f"{p}.ffn.out.bias", (h,)),
                (f"{p}.ffn.ln.gamma", (h,)),
                (f"{p}.ffn.ln.beta", (h,)),
            ]
        out += [
            ("pooler.weight", (h, h)),
            ("pooler.bias", (h,)),
            ("classifier.weight", (h, c)),
            ("classifier.bias", (c,)),
        ]
        return out


@dataclass(frozen=True)
class CnnConfig:
    """Tiny CNN for the conv-splitting / BN-folding experiments (Figure 3)."""

    image: int = 16
    in_ch: int = 1
    ch1: int = 8
    ch2: int = 16
    kernel: int = 3
    num_classes: int = 4
    bn_eps: float = 1e-5

    @property
    def flat(self) -> int:
        # two stride-2 max-pools: 16 -> 8 -> 4
        return self.ch2 * (self.image // 4) * (self.image // 4)

    def param_order(self) -> List[Tuple[str, Tuple[int, ...]]]:
        k = self.kernel
        out: List[Tuple[str, Tuple[int, ...]]] = [
            ("conv1.weight", (self.ch1, self.in_ch, k, k)),
            ("conv1.bias", (self.ch1,)),
            ("bn1.gamma", (self.ch1,)),
            ("bn1.beta", (self.ch1,)),
            ("bn1.mean", (self.ch1,)),
            ("bn1.var", (self.ch1,)),
            ("conv2.weight", (self.ch2, self.ch1, k, k)),
            ("conv2.bias", (self.ch2,)),
            ("bn2.gamma", (self.ch2,)),
            ("bn2.beta", (self.ch2,)),
            ("bn2.mean", (self.ch2,)),
            ("bn2.var", (self.ch2,)),
            ("fc.weight", (self.flat, self.num_classes)),
            ("fc.bias", (self.num_classes,)),
        ]
        return out


# Activation fake-quant sites in the exported act-quant forward, in order.
# Each site gets 3 chunks (SplitQuant activation splitting, paper §4.2) with an
# independent (scale, zero_point) pair per chunk.  Equal triples reproduce the
# per-tensor baseline exactly.
def act_sites(cfg: BertConfig) -> List[Tuple[str, int]]:
    """(site name, channel width) for every activation quantization point."""
    sites: List[Tuple[str, int]] = [("embeddings.out", cfg.hidden)]
    for i in range(cfg.layers):
        sites += [
            (f"encoder.{i}.attn.out", cfg.hidden),
            (f"encoder.{i}.ffn.gelu", cfg.ffn),
            (f"encoder.{i}.ffn.out", cfg.hidden),
        ]
    sites.append(("pooler.out", cfg.hidden))
    return sites


def chunk_bounds(n: int, parts: int = 3) -> List[int]:
    """Split points for positional activation splitting (paper §4.2).

    Returns the interior boundaries for ``jnp.split`` /  Rust chunking such
    that chunk sizes differ by at most one element.
    """
    base, rem = divmod(n, parts)
    sizes = [base + (1 if i < rem else 0) for i in range(parts)]
    bounds, acc = [], 0
    for s in sizes[:-1]:
        acc += s
        bounds.append(acc)
    return bounds
