"""L2: BERT-Tiny forward / training / activation-quantized graphs in JAX.

Everything here runs ONCE at build time (`make artifacts`): the functions are
jitted, lowered to HLO text by ``aot.py`` and executed from Rust through PJRT.
Parameters are passed as a flat list of arrays in the deterministic order of
``config.BertConfig.param_order()`` so the Rust coordinator can own parameter
storage, initialization, checkpointing and quantization.

Three graphs are exported:
  * ``bert_forward``       — logits for evaluation/serving.
  * ``bert_train_step``    — fused fwd+bwd+Adam; Rust drives the loop.
  * ``bert_forward_actquant`` — forward with chunked activation fake-quant
    (the L1 Pallas kernel) at every activation site: 3 chunks per site with
    independent (scale, zp) == the paper's §4.2 activation splitting; equal
    triples == the per-tensor baseline.
"""

from typing import Dict, List

import jax
import jax.numpy as jnp

from .config import BertConfig, act_sites, chunk_bounds
from .kernels.fake_quant import fake_quant

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
NEG_INF = -1e9


def params_to_dict(cfg: BertConfig, flat: List[jax.Array]) -> Dict[str, jax.Array]:
    order = cfg.param_order()
    assert len(flat) == len(order), (len(flat), len(order))
    return {name: arr for (name, _), arr in zip(order, flat)}


def _layer_norm(x, gamma, beta, eps):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def _gelu(x):
    # tanh approximation; the Rust executor uses the same formula and the
    # cross-runtime tolerance is asserted in integration tests.
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def _attention(cfg: BertConfig, p: Dict[str, jax.Array], prefix: str, x, mask):
    b, l, h = x.shape
    a, hd = cfg.heads, cfg.head_dim

    def proj(name):
        w = p[f"{prefix}.attn.{name}.weight"]
        bb = p[f"{prefix}.attn.{name}.bias"]
        y = jnp.einsum("blh,hd->bld", x, w) + bb
        return y.reshape(b, l, a, hd).transpose(0, 2, 1, 3)  # (B, A, L, hd)

    q, k, v = proj("q"), proj("k"), proj("v")
    scores = jnp.einsum("bald,bamd->balm", q, k) / jnp.sqrt(float(hd))
    # mask: f32[B, L], 1 for real tokens, 0 for padding
    scores = scores + (1.0 - mask)[:, None, None, :] * NEG_INF
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("balm,bamd->bald", attn, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, l, h)
    out = jnp.einsum("blh,hd->bld", ctx, p[f"{prefix}.attn.out.weight"])
    out = out + p[f"{prefix}.attn.out.bias"]
    return out


def _fq_site(x, scales3, zps3, qmin, qmax, bounds):
    """Chunked activation fake-quant through the L1 Pallas kernel.

    x: f32[..., n]; scales3/zps3: f32[3]; bounds: static chunk boundaries.
    Reshapes to 2-D (pallas kernels are 2-D), quantizes each chunk with its
    own (scale, zp) and concatenates — the paper's activation split.
    """
    shape = x.shape
    n = shape[-1]
    x2 = x.reshape(-1, n)
    lo = [0] + list(bounds)
    hi = list(bounds) + [n]
    outs = []
    for i, (a, b) in enumerate(zip(lo, hi)):
        chunk = x2[:, a:b]
        outs.append(
            fake_quant(
                chunk,
                scales3[i].reshape(1, 1),
                zps3[i].reshape(1, 1),
                qmin.reshape(1, 1),
                qmax.reshape(1, 1),
            )
        )
    return jnp.concatenate(outs, axis=-1).reshape(shape)


def _bert_body(cfg: BertConfig, p: Dict[str, jax.Array], ids, mask, fq=None):
    """Shared forward body; ``fq(site_index, x)`` optionally fake-quants."""
    b, l = ids.shape
    x = p["embeddings.token"][ids] + p["embeddings.position"][None, :l, :]
    x = _layer_norm(x, p["embeddings.ln.gamma"], p["embeddings.ln.beta"], cfg.ln_eps)
    site = 0
    if fq is not None:
        x = fq(site, x)
    site += 1
    for i in range(cfg.layers):
        prefix = f"encoder.{i}"
        attn = _attention(cfg, p, prefix, x, mask)
        x = _layer_norm(
            x + attn, p[f"{prefix}.attn.ln.gamma"], p[f"{prefix}.attn.ln.beta"], cfg.ln_eps
        )
        if fq is not None:
            x = fq(site, x)
        site += 1
        hmid = _gelu(
            jnp.einsum("blh,hf->blf", x, p[f"{prefix}.ffn.in.weight"])
            + p[f"{prefix}.ffn.in.bias"]
        )
        if fq is not None:
            hmid = fq(site, hmid)
        site += 1
        ff = (
            jnp.einsum("blf,fh->blh", hmid, p[f"{prefix}.ffn.out.weight"])
            + p[f"{prefix}.ffn.out.bias"]
        )
        x = _layer_norm(
            x + ff, p[f"{prefix}.ffn.ln.gamma"], p[f"{prefix}.ffn.ln.beta"], cfg.ln_eps
        )
        if fq is not None:
            x = fq(site, x)
        site += 1
    pooled = jnp.tanh(
        jnp.einsum("bh,hd->bd", x[:, 0, :], p["pooler.weight"]) + p["pooler.bias"]
    )
    if fq is not None:
        pooled = fq(site, pooled)
    site += 1
    logits = jnp.einsum("bd,dc->bc", pooled, p["classifier.weight"]) + p["classifier.bias"]
    return logits


def bert_forward(cfg: BertConfig, flat_params: List[jax.Array], ids, mask):
    """logits f32[B, C] = f(params, ids i32[B,L], mask f32[B,L])."""
    p = params_to_dict(cfg, flat_params)
    return (_bert_body(cfg, p, ids, mask),)


def bert_forward_actquant(
    cfg: BertConfig,
    flat_params: List[jax.Array],
    ids,
    mask,
    act_scales,  # f32[S, 3]
    act_zps,  # f32[S, 3]
    qmin,  # f32[1]
    qmax,  # f32[1]
):
    """Forward with per-site chunked activation fake-quant (paper §4.2).

    ``act_scales[s, c]``/``act_zps[s, c]`` are the quantization parameters of
    chunk ``c`` at activation site ``s`` (sites enumerated by
    ``config.act_sites``).  Rust computes them from calibration data — equal
    per-site triples reproduce per-tensor activation quantization (baseline),
    distinct triples implement SplitQuant activation splitting.
    """
    p = params_to_dict(cfg, flat_params)
    sites = act_sites(cfg)
    qmin2 = qmin.reshape(1)[0]
    qmax2 = qmax.reshape(1)[0]

    def fq(site_idx, x):
        width = sites[site_idx][1]
        assert x.shape[-1] == width, (site_idx, x.shape, width)
        bounds = tuple(chunk_bounds(width))
        return _fq_site(x, act_scales[site_idx], act_zps[site_idx], qmin2, qmax2, bounds)

    return (_bert_body(cfg, p, ids, mask, fq=fq),)


def _loss(cfg: BertConfig, flat_params, ids, mask, labels):
    logits = bert_forward(cfg, flat_params, ids, mask)[0]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def bert_train_step(
    cfg: BertConfig,
    flat_params: List[jax.Array],
    adam_m: List[jax.Array],
    adam_v: List[jax.Array],
    step,  # i32[1], 0-based step count BEFORE this update
    ids,
    mask,
    labels,  # i32[B]
    lr,  # f32[1]
):
    """One fused fwd+bwd+Adam update.  Returns (params', m', v', loss[1])."""
    loss, grads = jax.value_and_grad(lambda fp: _loss(cfg, fp, ids, mask, labels))(
        list(flat_params)
    )
    t = (step.reshape(()) + 1).astype(jnp.float32)
    lr_s = lr.reshape(())
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    new_p, new_m, new_v = [], [], []
    for pi, mi, vi, gi in zip(flat_params, adam_m, adam_v, grads):
        m2 = ADAM_B1 * mi + (1.0 - ADAM_B1) * gi
        v2 = ADAM_B2 * vi + (1.0 - ADAM_B2) * gi * gi
        mhat = m2 / bc1
        vhat = v2 / bc2
        new_p.append(pi - lr_s * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss.reshape(1),)
