"""L2: tiny CNN for the conv-splitting (Figure 3) and BN-folding (§4.1) path.

conv1(3x3) → BN → ReLU → maxpool2 → conv2(3x3) → BN → ReLU → maxpool2 → FC.

Two graphs are exported:
  * ``cnn_forward``    — eval-mode forward (BN uses running statistics).
    BN params are ordinary inputs, so Rust can evaluate both the original
    model and the BN-folded model through the SAME executable (folded models
    pass gamma=1, beta=0, mean=0, var=1-eps').
  * ``cnn_train_step`` — fwd+bwd+Adam with batch-stat BN; running stats are
    updated with momentum 0.9 inside the graph and returned.
"""

from typing import Dict, List

import jax
import jax.numpy as jnp

from .config import CnnConfig
from .model import ADAM_B1, ADAM_B2, ADAM_EPS

BN_MOMENTUM = 0.9


def params_to_dict(cfg: CnnConfig, flat: List[jax.Array]) -> Dict[str, jax.Array]:
    order = cfg.param_order()
    assert len(flat) == len(order), (len(flat), len(order))
    return {name: arr for (name, _), arr in zip(order, flat)}


def _conv(x, w, b):
    # x: NCHW, w: OIHW, SAME padding, stride 1
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def _bn_eval(x, gamma, beta, mean, var, eps):
    inv = jax.lax.rsqrt(var + eps)[None, :, None, None]
    return (x - mean[None, :, None, None]) * inv * gamma[None, :, None, None] + beta[
        None, :, None, None
    ]


def _bn_train(x, gamma, beta, eps):
    """Batch-stat BN; returns (y, batch_mean, batch_var)."""
    mean = jnp.mean(x, axis=(0, 2, 3))
    var = jnp.mean((x - mean[None, :, None, None]) ** 2, axis=(0, 2, 3))
    inv = jax.lax.rsqrt(var + eps)[None, :, None, None]
    y = (x - mean[None, :, None, None]) * inv * gamma[None, :, None, None] + beta[
        None, :, None, None
    ]
    return y, mean, var


def cnn_forward(cfg: CnnConfig, flat_params: List[jax.Array], images):
    """logits f32[B, C] = f(params, images f32[B, 1, 16, 16]); eval-mode BN."""
    p = params_to_dict(cfg, flat_params)
    x = _conv(images, p["conv1.weight"], p["conv1.bias"])
    x = _bn_eval(x, p["bn1.gamma"], p["bn1.beta"], p["bn1.mean"], p["bn1.var"], cfg.bn_eps)
    x = jax.nn.relu(x)
    x = _maxpool2(x)
    x = _conv(x, p["conv2.weight"], p["conv2.bias"])
    x = _bn_eval(x, p["bn2.gamma"], p["bn2.beta"], p["bn2.mean"], p["bn2.var"], cfg.bn_eps)
    x = jax.nn.relu(x)
    x = _maxpool2(x)
    b = x.shape[0]
    flat = x.reshape(b, -1)
    logits = flat @ p["fc.weight"] + p["fc.bias"]
    return (logits,)


def _cnn_train_forward(cfg: CnnConfig, p: Dict[str, jax.Array], images):
    x = _conv(images, p["conv1.weight"], p["conv1.bias"])
    x, m1, v1 = _bn_train(x, p["bn1.gamma"], p["bn1.beta"], cfg.bn_eps)
    x = jax.nn.relu(x)
    x = _maxpool2(x)
    x = _conv(x, p["conv2.weight"], p["conv2.bias"])
    x, m2, v2 = _bn_train(x, p["bn2.gamma"], p["bn2.beta"], cfg.bn_eps)
    x = jax.nn.relu(x)
    x = _maxpool2(x)
    b = x.shape[0]
    logits = x.reshape(b, -1) @ p["fc.weight"] + p["fc.bias"]
    return logits, (m1, v1, m2, v2)


def cnn_train_step(
    cfg: CnnConfig,
    flat_params: List[jax.Array],
    adam_m: List[jax.Array],
    adam_v: List[jax.Array],
    step,  # i32[1]
    images,  # f32[B, 1, 16, 16]
    labels,  # i32[B]
    lr,  # f32[1]
):
    """One fused fwd+bwd+Adam update with BN running-stat tracking.

    BN running mean/var receive zero gradient (batch-stat BN is used in the
    loss), pass through Adam unchanged, and are then overwritten by the
    momentum update — mirroring torch.nn.BatchNorm2d semantics.
    """
    order = [name for name, _ in cfg.param_order()]
    stat_idx = {order.index(n) for n in ("bn1.mean", "bn1.var", "bn2.mean", "bn2.var")}

    def loss_fn(fp):
        p = params_to_dict(cfg, fp)
        logits, stats = _cnn_train_forward(cfg, p, images)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(nll), stats

    (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(list(flat_params))
    m1, v1, m2, v2 = stats
    t = (step.reshape(()) + 1).astype(jnp.float32)
    lr_s = lr.reshape(())
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    new_p, new_m, new_v = [], [], []
    for idx, (pi, mi, vi, gi) in enumerate(zip(flat_params, adam_m, adam_v, grads)):
        if idx in stat_idx:
            new_p.append(pi)  # replaced below
            new_m.append(mi)
            new_v.append(vi)
            continue
        m2_ = ADAM_B1 * mi + (1.0 - ADAM_B1) * gi
        v2_ = ADAM_B2 * vi + (1.0 - ADAM_B2) * gi * gi
        new_p.append(pi - lr_s * (m2_ / bc1) / (jnp.sqrt(v2_ / bc2) + ADAM_EPS))
        new_m.append(m2_)
        new_v.append(v2_)
    # running-stat momentum update
    upd = {
        "bn1.mean": m1, "bn1.var": v1, "bn2.mean": m2, "bn2.var": v2,
    }
    for name, val in upd.items():
        i = order.index(name)
        new_p[i] = BN_MOMENTUM * flat_params[i] + (1.0 - BN_MOMENTUM) * val
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss.reshape(1),)
