"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

The quantization math follows the paper's Eq. (1)-(3):

    S = (2^b - 1) / (alpha - beta)
    Z = -2^(b-1) - INT(S * beta)
    Q(x) = clip(INT(S*x) + Z,  -2^(b-1),  2^(b-1) - 1)
    dq(q) = (q - Z) / S

``INT`` is round-half-to-even (jnp.round), matching the Rust implementation
(`f32::round_ties_even`).
"""

import jax.numpy as jnp


def qrange(bits: int):
    """(qmin, qmax) for signed b-bit integers."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def qparams(beta, alpha, bits: int):
    """Affine quantization parameters for original range [beta, alpha].

    Degenerate ranges (alpha == beta, e.g. a constant tensor) are widened to
    1e-8 so the scale stays finite; this matches `quant::affine` on the Rust
    side bit-for-bit.
    """
    span = jnp.maximum(alpha - beta, 1e-8)
    scale = (2.0**bits - 1.0) / span
    zp = -(2.0 ** (bits - 1)) - jnp.round(scale * beta)
    return scale, zp


def fake_quant_ref(x, scale, zp, qmin, qmax):
    """Quantize-dequantize (PTQ simulation): dq(Q(x))."""
    q = jnp.clip(jnp.round(scale * x) + zp, qmin, qmax)
    return (q - zp) / scale


def fake_quant_bits_ref(x, scale, zp, bits: int):
    qmin, qmax = qrange(bits)
    return fake_quant_ref(x, scale, zp, float(qmin), float(qmax))


def split_dequant_ref(qw, cid, scales, zps):
    """Per-element dequant through the cluster-id plane.

    ``qw`` int8 codes, ``cid`` int8 cluster ids in [0, k), ``scales``/``zps``
    f32[k].  Equivalent to materializing the paper's three zero-padded split
    layers and summing them — without ever materializing the zeros.
    """
    k = scales.shape[0]
    qf = qw.astype(jnp.float32)
    cidf = cid.astype(jnp.int32)
    w = jnp.zeros_like(qf)
    for c in range(k):
        w = w + jnp.where(cidf == c, (qf - zps[c]) / scales[c], 0.0)
    return w


def split_matmul_ref(x, qw, cid, scales, zps):
    """y = x @ dq_split(qw)  — the SplitQuant deployment hot path."""
    w = split_dequant_ref(qw, cid, scales, zps)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def cluster_assign_ref(x, centroids):
    """1-D k-means assignment: nearest centroid index (ties -> lowest index)."""
    d = (x[..., None] - centroids) ** 2
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def chunked_fake_quant_ref(x, scales, zps, qmin, qmax, bounds):
    """Activation splitting (paper §4.2) as per-chunk fake-quant on last dim.

    Splitting an activation layer of width n into 3 layers and concatenating
    the results is mathematically identical to quantizing 3 chunks with
    independent (scale, zp); this is the oracle for that identity.
    """
    chunks = jnp.split(x, bounds, axis=-1)
    outs = [
        fake_quant_ref(c, scales[i], zps[i], qmin, qmax) for i, c in enumerate(chunks)
    ]
    return jnp.concatenate(outs, axis=-1)
