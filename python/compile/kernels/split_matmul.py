"""L1 Pallas kernel: SplitQuant deployment hot path — on-the-fly split dequant + matmul.

The paper splits a linear layer into three zero-padded layers (Figure 2).
Materializing those zeros triples the weight memory (paper §6).  On TPU we
instead keep ONE int8 code plane ``qw``, ONE int8 cluster-id plane ``cid`` and
k scale/zero-point scalars; the kernel reconstructs

    w_eff[k,n] = (qw[k,n] - zp[cid[k,n]]) / scale[cid[k,n]]

inside VMEM and immediately contracts it on the MXU:

    y = x @ w_eff

This is mathematically identical to running the paper's three split layers and
adding their outputs — the equivalence is asserted against ``ref.py`` in
``python/tests/test_split_matmul.py`` and again on the Rust side.

TPU mapping: grid = (M/Bm, N/Bn); x tile (Bm, K) and weight tiles (K, Bn) are
staged in VMEM; the cluster-select is VPU work (k compare+FMA passes, k=3)
fused ahead of a (Bm×K)·(K×Bn) MXU contraction with f32 accumulation.
``interpret=True`` for CPU-PJRT execution (see fake_quant.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _split_matmul_kernel(k_clusters, x_ref, qw_ref, cid_ref, scales_ref, zps_ref, o_ref):
    x = x_ref[...]
    qf = qw_ref[...].astype(jnp.float32)
    cid = cid_ref[...].astype(jnp.int32)
    w = jnp.zeros_like(qf)
    # k is static (=3 for SplitQuant): unrolled compare+select, VPU-friendly,
    # no gather.
    for c in range(k_clusters):
        scale = scales_ref[0, c]
        zp = zps_ref[0, c]
        w = w + jnp.where(cid == c, (qf - zp) / scale, 0.0)
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def split_matmul(x, qw, cid, scales, zps, *, block_m: int = 128, block_n: int = 128):
    """y = x @ split_dequant(qw, cid, scales, zps).

    Args:
      x: f32[M, K] activations.
      qw: int8[K, N] quantized weight codes (INT2/4/8 all stored as int8
        codes here; bit-packing is a storage-layer concern handled in Rust).
      cid: int8[K, N] cluster id per element, in [0, k).
      scales, zps: f32[1, k] per-cluster quantization parameters.

    Returns: f32[M, N].
    """
    m, kk = x.shape
    k2, n = qw.shape
    assert kk == k2, (x.shape, qw.shape)
    assert cid.shape == qw.shape
    k_clusters = scales.shape[1]
    assert zps.shape == scales.shape

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    kernel = functools.partial(_split_matmul_kernel, k_clusters)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kk), lambda i, j: (i, 0)),
            pl.BlockSpec((kk, bn), lambda i, j: (0, j)),
            pl.BlockSpec((kk, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, k_clusters), lambda i, j: (0, 0)),
            pl.BlockSpec((1, k_clusters), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, qw, cid, scales, zps)
