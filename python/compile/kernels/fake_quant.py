"""L1 Pallas kernel: tiled quantize-dequantize (fake quantization).

This is the PTQ-simulation primitive: ``dq(Q(x))`` with runtime scale /
zero-point / clip range, so one compiled executable serves INT2 / INT4 / INT8.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the tensor is streamed
HBM→VMEM in (block_rows × block_cols) tiles via ``BlockSpec``; the body is
pure VPU elementwise work (mul, round, clip, sub, div).  The scalar
parameters ride along as (1,1) blocks that every grid step maps to the same
origin — on real hardware they would live in SMEM.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path and real-TPU
performance is estimated analytically (DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fake_quant_kernel(x_ref, scale_ref, zp_ref, qmin_ref, qmax_ref, o_ref):
    x = x_ref[...]
    scale = scale_ref[0, 0]
    zp = zp_ref[0, 0]
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    q = jnp.clip(jnp.round(scale * x) + zp, qmin, qmax)
    o_ref[...] = (q - zp) / scale


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps the grid exact)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def fake_quant(x, scale, zp, qmin, qmax, *, block_rows: int = 256, block_cols: int = 512):
    """Quantize-dequantize a 2-D f32 tensor.

    Args:
      x: f32[R, C].
      scale, zp, qmin, qmax: f32[1, 1] runtime quantization parameters
        (paper Eq. 1-3; qmin/qmax select the bit-width).
      block_rows/block_cols: VMEM tile shape (clamped to divisors of R/C).

    Returns: f32[R, C], ``dq(Q(x))``.
    """
    r, c = x.shape
    br = _pick_block(r, block_rows)
    bc = _pick_block(c, block_cols)
    grid = (r // br, c // bc)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        _fake_quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            scalar_spec,
            scalar_spec,
            scalar_spec,
            scalar_spec,
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(x, scale, zp, qmin, qmax)


def fake_quant_scalar(x, scale: float, zp: float, bits: int):
    """Convenience wrapper with python-scalar parameters (tests)."""
    qmin = float(-(2 ** (bits - 1)))
    qmax = float(2 ** (bits - 1) - 1)
    one = lambda v: jnp.full((1, 1), v, jnp.float32)
    return fake_quant(x, one(scale), one(zp), one(qmin), one(qmax))
