"""L1 Pallas kernel: 1-D k-means assignment step.

SplitQuant clusters each layer's weights/biases into lower / middle / upper
groups (paper §4.1).  The assignment step — nearest centroid per element — is
the data-parallel half of Lloyd's algorithm and the only part worth a kernel
(the k centroid updates are tiny reductions).

k is static and small (=3), so the argmin is an unrolled compare+select chain
(ties break to the lowest index, matching ``jnp.argmin`` and the Rust
implementation).  ``interpret=True`` as everywhere (see fake_quant.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cluster_assign_kernel(k_clusters, x_ref, cent_ref, o_ref):
    x = x_ref[...]
    best_d = jnp.full(x.shape, jnp.inf, jnp.float32)
    best_i = jnp.zeros(x.shape, jnp.int32)
    for c in range(k_clusters):
        d = (x - cent_ref[0, c]) ** 2
        better = d < best_d
        best_i = jnp.where(better, c, best_i)
        best_d = jnp.where(better, d, best_d)
    o_ref[...] = best_i


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def cluster_assign(x, centroids, *, block_rows: int = 256, block_cols: int = 512):
    """Nearest-centroid assignment for a 2-D value plane.

    Args:
      x: f32[R, C] values (a weight tensor viewed 2-D).
      centroids: f32[1, k] current cluster centers.

    Returns: int32[R, C] cluster index per element.
    """
    r, c = x.shape
    k_clusters = centroids.shape[1]
    br = _pick_block(r, block_rows)
    bc = _pick_block(c, block_cols)
    grid = (r // br, c // bc)
    kernel = functools.partial(_cluster_assign_kernel, k_clusters)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, k_clusters), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        interpret=True,
    )(x, centroids)
