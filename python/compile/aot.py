"""AOT export: lower every L2 graph to HLO *text* + a JSON manifest.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust ``xla`` crate) rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

This module runs ONCE (`make artifacts`).  The manifest gives the Rust side
everything it needs to allocate, feed and interpret executables without
importing Python: input/output names, shapes, dtypes, the flat parameter
ordering, activation-site table and model hyper-parameters.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import BertConfig, CnnConfig, act_sites, chunk_bounds
from . import model as M
from . import cnn as C
from .kernels.fake_quant import fake_quant
from .kernels.split_matmul import split_matmul
from .kernels.cluster_assign import cluster_assign

F32, I32, I8 = jnp.float32, jnp.int32, jnp.int8
_DTYPE_NAME = {jnp.dtype("float32"): "f32", jnp.dtype("int32"): "i32", jnp.dtype("int8"): "i8"}


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _io_entry(name: str, s: jax.ShapeDtypeStruct):
    return {"name": name, "shape": list(s.shape), "dtype": _DTYPE_NAME[s.dtype]}


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"executables": {}}
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, fn, inputs: List[Tuple[str, jax.ShapeDtypeStruct]],
               outputs: List[Tuple[str, jax.ShapeDtypeStruct]], meta=None):
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*[s for _, s in inputs])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        entry = {
            "file": f"{name}.hlo.txt",
            "inputs": [_io_entry(n, s) for n, s in inputs],
            "outputs": [_io_entry(n, s) for n, s in outputs],
        }
        if meta:
            entry["meta"] = meta
        self.manifest["executables"][name] = entry
        print(f"  {name}: {len(text)} chars, {len(inputs)} inputs, {len(outputs)} outputs")

    def finish(self, extra):
        self.manifest.update(extra)
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"  manifest.json written ({len(self.manifest['executables'])} executables)")


def bert_param_specs(cfg: BertConfig):
    return [(name, spec(shape)) for name, shape in cfg.param_order()]


def cnn_param_specs(cfg: CnnConfig):
    return [(name, spec(shape)) for name, shape in cfg.param_order()]


def export_bert(ex: Exporter, cfg: BertConfig, fwd_batches, train_batch, actquant_batch):
    P = bert_param_specs(cfg)
    nclasses = cfg.num_classes
    L = cfg.max_len

    # ---- forward (eval / serving) at several batch sizes
    for b in fwd_batches:
        ins = P + [("input_ids", spec((b, L), I32)), ("attention_mask", spec((b, L)))]
        outs = [("logits", spec((b, nclasses)))]
        fn = functools.partial(_bert_fwd_entry, cfg, len(P))
        ex.export(f"bert_fwd_b{b}", fn, ins, outs, meta={"kind": "bert_fwd", "batch": b})

    # ---- fused train step
    b = train_batch
    ins = (
        P
        + [(f"adam_m.{n}", s) for n, s in P]
        + [(f"adam_v.{n}", s) for n, s in P]
        + [
            ("step", spec((1,), I32)),
            ("input_ids", spec((b, L), I32)),
            ("attention_mask", spec((b, L))),
            ("labels", spec((b,), I32)),
            ("lr", spec((1,))),
        ]
    )
    outs = (
        [(f"new.{n}", s) for n, s in P]
        + [(f"new_m.{n}", s) for n, s in P]
        + [(f"new_v.{n}", s) for n, s in P]
        + [("loss", spec((1,)))]
    )
    fn = functools.partial(_bert_train_entry, cfg, len(P))
    ex.export(f"bert_train_step_b{b}", fn, ins, outs, meta={"kind": "bert_train", "batch": b})

    # ---- activation-quantized forward (chunked scales = §4.2 act splitting)
    if not actquant_batch:
        return
    b = actquant_batch
    S = len(act_sites(cfg))
    ins = P + [
        ("input_ids", spec((b, L), I32)),
        ("attention_mask", spec((b, L))),
        ("act_scales", spec((S, 3))),
        ("act_zps", spec((S, 3))),
        ("qmin", spec((1,))),
        ("qmax", spec((1,))),
    ]
    outs = [("logits", spec((b, nclasses)))]
    fn = functools.partial(_bert_actquant_entry, cfg, len(P))
    ex.export(
        f"bert_fwd_actquant_b{b}", fn, ins, outs,
        meta={"kind": "bert_fwd_actquant", "batch": b, "num_sites": S},
    )


def _bert_fwd_entry(cfg, nparams, *args):
    return M.bert_forward(cfg, list(args[:nparams]), args[nparams], args[nparams + 1])


def _bert_train_entry(cfg, nparams, *args):
    p = list(args[:nparams])
    m = list(args[nparams : 2 * nparams])
    v = list(args[2 * nparams : 3 * nparams])
    step, ids, mask, labels, lr = args[3 * nparams :]
    return M.bert_train_step(cfg, p, m, v, step, ids, mask, labels, lr)


def _bert_actquant_entry(cfg, nparams, *args):
    p = list(args[:nparams])
    ids, mask, scales, zps, qmin, qmax = args[nparams:]
    return M.bert_forward_actquant(cfg, p, ids, mask, scales, zps, qmin, qmax)


def export_cnn(ex: Exporter, cfg: CnnConfig, batch: int):
    P = cnn_param_specs(cfg)
    img = spec((batch, cfg.in_ch, cfg.image, cfg.image))

    ins = P + [("images", img)]
    outs = [("logits", spec((batch, cfg.num_classes)))]
    ex.export(
        f"cnn_fwd_b{batch}",
        functools.partial(_cnn_fwd_entry, cfg, len(P)),
        ins, outs, meta={"kind": "cnn_fwd", "batch": batch},
    )

    ins = (
        P
        + [(f"adam_m.{n}", s) for n, s in P]
        + [(f"adam_v.{n}", s) for n, s in P]
        + [
            ("step", spec((1,), I32)),
            ("images", img),
            ("labels", spec((batch,), I32)),
            ("lr", spec((1,))),
        ]
    )
    outs = (
        [(f"new.{n}", s) for n, s in P]
        + [(f"new_m.{n}", s) for n, s in P]
        + [(f"new_v.{n}", s) for n, s in P]
        + [("loss", spec((1,)))]
    )
    ex.export(
        f"cnn_train_step_b{batch}",
        functools.partial(_cnn_train_entry, cfg, len(P)),
        ins, outs, meta={"kind": "cnn_train", "batch": batch},
    )


def _cnn_fwd_entry(cfg, nparams, *args):
    return C.cnn_forward(cfg, list(args[:nparams]), args[nparams])


def _cnn_train_entry(cfg, nparams, *args):
    p = list(args[:nparams])
    m = list(args[nparams : 2 * nparams])
    v = list(args[2 * nparams : 3 * nparams])
    step, images, labels, lr = args[3 * nparams :]
    return C.cnn_train_step(cfg, p, m, v, step, images, labels, lr)


def export_kernels(ex: Exporter):
    """Standalone kernel executables for the serving hot path + benches."""
    # fake_quant over a 2-D plane, runtime bit-width
    r, c = 256, 512
    ins = [
        ("x", spec((r, c))),
        ("scale", spec((1, 1))),
        ("zp", spec((1, 1))),
        ("qmin", spec((1, 1))),
        ("qmax", spec((1, 1))),
    ]
    ex.export(
        "fake_quant_256x512",
        lambda x, s, z, lo, hi: (fake_quant(x, s, z, lo, hi),),
        ins,
        [("y", spec((r, c)))],
        meta={"kind": "fake_quant"},
    )

    # split matmul hot path at the two BERT-Tiny linear shapes
    for (m, k, n) in [(32, 128, 128), (32, 128, 512)]:
        ins = [
            ("x", spec((m, k))),
            ("qw", spec((k, n), I8)),
            ("cid", spec((k, n), I8)),
            ("scales", spec((1, 3))),
            ("zps", spec((1, 3))),
        ]
        ex.export(
            f"split_linear_{m}x{k}x{n}",
            lambda x, qw, cid, s, z: (split_matmul(x, qw, cid, s, z),),
            ins,
            [("y", spec((m, n)))],
            meta={"kind": "split_linear", "m": m, "k": k, "n": n},
        )

    # k-means assignment plane
    r, c = 128, 128
    ins = [("x", spec((r, c))), ("centroids", spec((1, 3)))]
    ex.export(
        "cluster_assign_128x128",
        lambda x, cent: (cluster_assign(x, cent),),
        ins,
        [("cid", spec((r, c), I32))],
        meta={"kind": "cluster_assign"},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fwd-batches", default="1,8,32")
    ap.add_argument("--train-batch", type=int, default=32)
    ap.add_argument("--skip-actquant", action="store_true")
    args = ap.parse_args()

    bert = BertConfig()
    cnn = CnnConfig()
    ex = Exporter(args.out_dir)

    print("[aot] exporting BERT graphs...")
    fwd_batches = [int(b) for b in args.fwd_batches.split(",")]
    export_bert(ex, bert, fwd_batches, args.train_batch,
                actquant_batch=0 if args.skip_actquant else 32)
    print("[aot] exporting CNN graphs...")
    export_cnn(ex, cnn, batch=32)
    print("[aot] exporting standalone kernels...")
    export_kernels(ex)

    sites = act_sites(bert)
    ex.finish(
        {
            "bert_config": {
                "vocab_size": bert.vocab_size,
                "hidden": bert.hidden,
                "layers": bert.layers,
                "heads": bert.heads,
                "ffn": bert.ffn,
                "max_len": bert.max_len,
                "num_classes": bert.num_classes,
                "ln_eps": bert.ln_eps,
            },
            "cnn_config": {
                "image": cnn.image,
                "in_ch": cnn.in_ch,
                "ch1": cnn.ch1,
                "ch2": cnn.ch2,
                "kernel": cnn.kernel,
                "num_classes": cnn.num_classes,
                "bn_eps": cnn.bn_eps,
            },
            "bert_param_order": [[n, list(s)] for n, s in bert.param_order()],
            "cnn_param_order": [[n, list(s)] for n, s in cnn.param_order()],
            "act_sites": [
                {"name": n, "width": w, "bounds": chunk_bounds(w)} for n, w in sites
            ],
            "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
        }
    )
    print("[aot] done.")


if __name__ == "__main__":
    main()
