//! Activation splitting (paper §4.2).
//!
//! Activation values are unknown at quantization time, so the layer is split
//! *positionally*: the width-n activation becomes three width-n/3 chunks,
//! each quantized with its own scale, then concatenated. Even when the
//! global max/min land in the same chunk, the other chunks' resolution still
//! improves.
//!
//! The calibrator records per-chunk ranges through the executor's activation
//! hook (or from PJRT-fetched activations) and produces:
//! * per-tensor parameters (baseline: all three chunks share one range), or
//! * per-chunk parameters (SplitQuant activation splitting).

use crate::error::Result;
use crate::model::config::{chunk_spans, BertConfig};
use crate::quant::{Observer, QParams};
use crate::tensor::Tensor;

/// Activation quantization mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ActQuantMode {
    /// One range per site (the paper's baseline act quant).
    PerTensor,
    /// Per-chunk ranges (SplitQuant §4.2).
    Split,
}

/// Per-site, per-chunk activation quantization parameters.
#[derive(Debug, Clone)]
pub struct ActQuantParams {
    /// `[site][chunk]` parameters; 3 chunks per site.
    pub per_site: Vec<[QParams; 3]>,
    pub bits: u8,
}

impl ActQuantParams {
    /// Flatten to the (scales, zps) arrays the AOT act-quant executable
    /// expects: f32[S, 3] each.
    pub fn to_arrays(&self) -> (Tensor, Tensor) {
        let s = self.per_site.len();
        let mut scales = Vec::with_capacity(s * 3);
        let mut zps = Vec::with_capacity(s * 3);
        for site in &self.per_site {
            for p in site {
                scales.push(p.scale);
                zps.push(p.zp);
            }
        }
        (
            Tensor::new(&[s, 3], scales).unwrap(),
            Tensor::new(&[s, 3], zps).unwrap(),
        )
    }

    /// Executor hook applying chunked fake-quant in place — the pure-Rust
    /// twin of the AOT act-quant graph.
    pub fn hook<'a>(
        &'a self,
        cfg: &BertConfig,
    ) -> impl FnMut(usize, &mut Tensor) + 'a {
        let sites = cfg.act_sites();
        move |site: usize, t: &mut Tensor| {
            let width = sites[site].1;
            let (_r, c) = t.as_2d();
            debug_assert_eq!(c, width);
            let spans = chunk_spans(width, 3);
            let d = t.data_mut();
            let rows = d.len() / c;
            for r in 0..rows {
                let row_start = r * c;
                for (ci, &(lo, hi)) in spans.iter().enumerate() {
                    let p = &self.per_site[site][ci];
                    for v in &mut d[row_start + lo..row_start + hi] {
                        *v = p.fake(*v);
                    }
                }
            }
        }
    }
}

/// Collects per-site / per-chunk min-max ranges from calibration batches.
#[derive(Debug, Clone)]
pub struct ActCalibrator {
    sites: Vec<(String, usize)>,
    /// `[site][chunk] -> (min, max)`
    ranges: Vec<[(f32, f32); 3]>,
    samples_seen: usize,
}

impl ActCalibrator {
    pub fn new(cfg: &BertConfig) -> Self {
        let sites = cfg.act_sites();
        let ranges = vec![[(f32::INFINITY, f32::NEG_INFINITY); 3]; sites.len()];
        ActCalibrator { sites, ranges, samples_seen: 0 }
    }

    /// Executor hook that records ranges (no mutation).
    pub fn hook(&mut self) -> impl FnMut(usize, &mut Tensor) + '_ {
        move |site: usize, t: &mut Tensor| {
            let width = self.sites[site].1;
            let (_r, c) = t.as_2d();
            debug_assert_eq!(c, width);
            let spans = chunk_spans(width, 3);
            let d = t.data();
            for row in d.chunks(c) {
                for (ci, &(lo, hi)) in spans.iter().enumerate() {
                    let e = &mut self.ranges[site][ci];
                    for &v in &row[lo..hi] {
                        e.0 = e.0.min(v);
                        e.1 = e.1.max(v);
                    }
                }
            }
            if site == 0 {
                self.samples_seen += t.as_2d().0;
            }
        }
    }

    pub fn samples_seen(&self) -> usize {
        self.samples_seen
    }

    /// Produce quantization parameters. `PerTensor` merges the three chunk
    /// ranges per site (== calibrating without splitting); `Split` keeps
    /// them separate. Optionally clip with a percentile-style observer is
    /// not supported here (min-max calibration, as in the paper's setup).
    pub fn to_params(&self, bits: u8, mode: ActQuantMode) -> ActQuantParams {
        let per_site = self
            .ranges
            .iter()
            .map(|chunks| {
                match mode {
                    ActQuantMode::PerTensor => {
                        let lo = chunks.iter().map(|c| c.0).fold(f32::INFINITY, f32::min);
                        let hi =
                            chunks.iter().map(|c| c.1).fold(f32::NEG_INFINITY, f32::max);
                        let p = QParams::from_range(lo.min(0.0), hi.max(0.0), bits);
                        [p, p, p]
                    }
                    ActQuantMode::Split => {
                        let mk = |c: &(f32, f32)| {
                            QParams::from_range(c.0.min(0.0), c.1.max(0.0), bits)
                        };
                        [mk(&chunks[0]), mk(&chunks[1]), mk(&chunks[2])]
                    }
                }
            })
            .collect();
        ActQuantParams { per_site, bits }
    }

    /// Observer-based variant over pooled chunk samples is intentionally not
    /// implemented: min-max matches the AOT graph semantics exactly.
    pub fn chunk_ranges(&self) -> &[[(f32, f32); 3]] {
        &self.ranges
    }

    /// Merge ranges from another calibrator (parallel calibration shards).
    pub fn merge(&mut self, other: &ActCalibrator) {
        assert_eq!(self.sites.len(), other.sites.len());
        for (a, b) in self.ranges.iter_mut().zip(&other.ranges) {
            for (x, y) in a.iter_mut().zip(b) {
                x.0 = x.0.min(y.0);
                x.1 = x.1.max(y.1);
            }
        }
        self.samples_seen += other.samples_seen;
    }
}

/// Percentile-clipped activation params from raw samples (ablation A3
/// baseline variant): pools every chunk's samples per site.
pub fn params_from_samples(
    samples: &[Vec<f32>], // [site] -> pooled values
    bits: u8,
    observer: Observer,
) -> Result<Vec<QParams>> {
    samples
        .iter()
        .map(|vals| {
            let (lo, hi) = observer.range(vals, bits)?;
            Ok(QParams::from_range(lo.min(0.0), hi.max(0.0), bits))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::bert::BertModel;
    use crate::model::params::ParamStore;
    use crate::tensor::IntTensor;
    use crate::util::rng::Rng;

    fn tiny() -> (BertConfig, BertModel) {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 12,
            layers: 1,
            heads: 2,
            ffn: 24,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let params = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        (cfg.clone(), BertModel::new(cfg, params).unwrap())
    }

    fn batch(cfg: &BertConfig, b: usize, seed: u64) -> (IntTensor, Tensor) {
        let mut rng = Rng::new(seed);
        let l = cfg.max_len;
        let ids: Vec<i32> = (0..b * l).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let mask = vec![1.0f32; b * l];
        (IntTensor::new(&[b, l], ids).unwrap(), Tensor::new(&[b, l], mask).unwrap())
    }

    #[test]
    fn calibration_collects_finite_ranges() {
        let (cfg, m) = tiny();
        let mut cal = ActCalibrator::new(&cfg);
        let (ids, mask) = batch(&cfg, 4, 1);
        {
            let mut hook = cal.hook();
            m.forward_hooked(&ids, &mask, Some(&mut hook));
        }
        assert_eq!(cal.samples_seen(), 4 * cfg.max_len);
        for site in cal.chunk_ranges() {
            for (lo, hi) in site {
                assert!(lo.is_finite() && hi.is_finite() && lo <= hi);
            }
        }
    }

    #[test]
    fn per_tensor_mode_shares_params_across_chunks() {
        let (cfg, m) = tiny();
        let mut cal = ActCalibrator::new(&cfg);
        let (ids, mask) = batch(&cfg, 4, 2);
        {
            let mut hook = cal.hook();
            m.forward_hooked(&ids, &mask, Some(&mut hook));
        }
        let pt = cal.to_params(4, ActQuantMode::PerTensor);
        for site in &pt.per_site {
            assert_eq!(site[0], site[1]);
            assert_eq!(site[1], site[2]);
        }
        let sp = cal.to_params(4, ActQuantMode::Split);
        // split params generally differ across chunks somewhere
        assert!(sp
            .per_site
            .iter()
            .any(|s| s[0] != s[1] || s[1] != s[2]));
    }

    #[test]
    fn split_scales_never_worse_than_per_tensor() {
        // each chunk's range ⊆ site range ⇒ per-chunk scale >= per-tensor scale
        let (cfg, m) = tiny();
        let mut cal = ActCalibrator::new(&cfg);
        let (ids, mask) = batch(&cfg, 8, 3);
        {
            let mut hook = cal.hook();
            m.forward_hooked(&ids, &mask, Some(&mut hook));
        }
        let pt = cal.to_params(2, ActQuantMode::PerTensor);
        let sp = cal.to_params(2, ActQuantMode::Split);
        for (a, b) in pt.per_site.iter().zip(&sp.per_site) {
            for c in 0..3 {
                assert!(b[c].scale >= a[c].scale - 1e-6);
            }
        }
    }

    #[test]
    fn hook_applies_fake_quant() {
        let (cfg, m) = tiny();
        let mut cal = ActCalibrator::new(&cfg);
        let (ids, mask) = batch(&cfg, 3, 4);
        {
            let mut hook = cal.hook();
            m.forward_hooked(&ids, &mask, Some(&mut hook));
        }
        let base = m.forward(&ids, &mask);
        let params = cal.to_params(2, ActQuantMode::Split);
        let mut h = params.hook(&cfg);
        let quant = m.forward_hooked(&ids, &mask, Some(&mut h));
        assert!(base.max_abs_diff(&quant) > 1e-4, "INT2 act quant must bite");
        let params8 = cal.to_params(8, ActQuantMode::Split);
        let mut h8 = params8.hook(&cfg);
        let quant8 = m.forward_hooked(&ids, &mask, Some(&mut h8));
        assert!(base.max_abs_diff(&quant8) < base.max_abs_diff(&quant));
    }

    #[test]
    fn arrays_shape() {
        let (cfg, m) = tiny();
        let mut cal = ActCalibrator::new(&cfg);
        let (ids, mask) = batch(&cfg, 2, 5);
        {
            let mut hook = cal.hook();
            m.forward_hooked(&ids, &mask, Some(&mut hook));
        }
        let p = cal.to_params(4, ActQuantMode::Split);
        let (s, z) = p.to_arrays();
        assert_eq!(s.shape(), &[cfg.act_sites().len(), 3]);
        assert_eq!(z.shape(), s.shape());
    }

    #[test]
    fn merge_combines_ranges() {
        let (cfg, m) = tiny();
        let mut a = ActCalibrator::new(&cfg);
        let mut b = ActCalibrator::new(&cfg);
        let (i1, m1) = batch(&cfg, 2, 6);
        let (i2, m2) = batch(&cfg, 2, 7);
        {
            let mut h = a.hook();
            m.forward_hooked(&i1, &m1, Some(&mut h));
        }
        {
            let mut h = b.hook();
            m.forward_hooked(&i2, &m2, Some(&mut h));
        }
        let mut merged = a.clone();
        merged.merge(&b);
        for site in 0..merged.chunk_ranges().len() {
            let rm = &merged.chunk_ranges()[site];
            let ra = &a.chunk_ranges()[site];
            let rb = &b.chunk_ranges()[site];
            for c in 0..3 {
                assert!(rm[c].0 <= ra[c].0.min(rb[c].0) + 1e-9);
                assert!(rm[c].1 >= ra[c].1.max(rb[c].1) - 1e-9);
            }
        }
        assert_eq!(merged.samples_seen(), a.samples_seen() + b.samples_seen());
    }
}
