//! BatchNorm folding (paper §4.1: "It is better to fold batch normalization
//! layers into preceding linear and convolution layers before applying
//! SplitQuant").
//!
//! For eval-mode BN with running statistics (μ, σ²) and affine (γ, β):
//!
//! ```text
//! s   = γ / √(σ² + ε)              (per out-channel)
//! W'  = W · s                      (broadcast over the out-channel axis)
//! b'  = (b − μ) · s + β
//! BN' = identity (γ=1, β=0, μ=0, σ²=1−ε)
//! ```

use crate::error::Result;
use crate::model::params::ParamStore;
use crate::tensor::Tensor;

/// Fold `bn` into the preceding conv/linear `conv` inside a [`ParamStore`].
/// Conv weights are OIHW (out-channel leading); linear weights of shape
/// (in, out) use the trailing axis. The BN parameters are reset to identity
/// so the same graph stays valid.
pub fn fold_bn(store: &mut ParamStore, conv: &str, bn: &str, eps: f32) -> Result<()> {
    let gamma = store.get(&format!("{bn}.gamma"))?.clone();
    let beta = store.get(&format!("{bn}.beta"))?.clone();
    let mean = store.get(&format!("{bn}.mean"))?.clone();
    let var = store.get(&format!("{bn}.var"))?.clone();
    let ch = gamma.numel();

    let s: Vec<f32> = (0..ch)
        .map(|c| gamma.data()[c] / (var.data()[c] + eps).sqrt())
        .collect();

    // weight: scale along the out-channel axis
    {
        let w = store.get_mut(&format!("{conv}.weight"))?;
        let shape = w.shape().to_vec();
        if shape[0] == ch {
            // OIHW conv (or out-leading linear)
            let inner: usize = shape[1..].iter().product();
            for c in 0..ch {
                for v in &mut w.data_mut()[c * inner..(c + 1) * inner] {
                    *v *= s[c];
                }
            }
        } else if *shape.last().unwrap() == ch {
            // (in, out) linear
            let cols = ch;
            for row in w.data_mut().chunks_mut(cols) {
                for (v, &sc) in row.iter_mut().zip(&s) {
                    *v *= sc;
                }
            }
        } else {
            return Err(crate::error::Error::Model(format!(
                "fold_bn: {conv}.weight shape {shape:?} has no axis of {ch} channels"
            )));
        }
    }

    // bias
    {
        let b = store.get_mut(&format!("{conv}.bias"))?;
        for c in 0..ch {
            let v = b.data()[c];
            b.data_mut()[c] = (v - mean.data()[c]) * s[c] + beta.data()[c];
        }
    }

    // reset BN to identity (graph unchanged, BN now a no-op)
    store.set(&format!("{bn}.gamma"), Tensor::ones(&[ch]))?;
    store.set(&format!("{bn}.beta"), Tensor::zeros(&[ch]))?;
    store.set(&format!("{bn}.mean"), Tensor::zeros(&[ch]))?;
    store.set(&format!("{bn}.var"), Tensor::full(&[ch], 1.0 - eps))?;
    Ok(())
}

/// Fold both BN layers of the standard CNN.
pub fn fold_cnn(store: &mut ParamStore, eps: f32) -> Result<()> {
    fold_bn(store, "conv1", "bn1", eps)?;
    fold_bn(store, "conv2", "bn2", eps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cnn::CnnModel;
    use crate::model::config::CnnConfig;
    use crate::util::rng::Rng;

    fn randomized_cnn(seed: u64) -> (CnnConfig, ParamStore) {
        let cfg = CnnConfig::default();
        let mut rng = Rng::new(seed);
        let mut store = ParamStore::init_cnn(&cfg.param_order(), &mut rng);
        // randomize BN stats so folding is non-trivial
        for bn in ["bn1", "bn2"] {
            let ch = store.get(&format!("{bn}.gamma")).unwrap().numel();
            let mk = |rng: &mut Rng, lo: f32, hi: f32| {
                Tensor::new(
                    &[ch],
                    (0..ch).map(|_| lo + rng.f32() * (hi - lo)).collect(),
                )
                .unwrap()
            };
            store.set(&format!("{bn}.gamma"), mk(&mut rng, 0.5, 2.0)).unwrap();
            store.set(&format!("{bn}.beta"), mk(&mut rng, -0.3, 0.3)).unwrap();
            store.set(&format!("{bn}.mean"), mk(&mut rng, -0.5, 0.5)).unwrap();
            store.set(&format!("{bn}.var"), mk(&mut rng, 0.2, 3.0)).unwrap();
        }
        (cfg, store)
    }

    #[test]
    fn folded_cnn_is_equivalent() {
        let (cfg, store) = randomized_cnn(0);
        let mut folded = store.clone();
        fold_cnn(&mut folded, cfg.bn_eps).unwrap();

        let m0 = CnnModel::new(cfg.clone(), store).unwrap();
        let m1 = CnnModel::new(cfg.clone(), folded).unwrap();
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&[4, 1, 16, 16], 0.0, 1.0, &mut rng);
        let d = m0.forward(&x).max_abs_diff(&m1.forward(&x));
        assert!(d < 1e-3, "fold diverged: {d}");
    }

    #[test]
    fn folding_reduces_quantizable_tensor_count() {
        // after folding, BN params are identity -> only conv/fc remain "real"
        let (cfg, mut store) = randomized_cnn(1);
        fold_cnn(&mut store, cfg.bn_eps).unwrap();
        let g = store.get("bn1.gamma").unwrap();
        assert!(g.data().iter().all(|&v| v == 1.0));
        assert!(store.get("bn2.mean").unwrap().data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn linear_trailing_axis_fold() {
        // emulate a linear (in=3, out=2) followed by a "bn" over out features
        let order = vec![
            ("fc.weight".to_string(), vec![3usize, 2]),
            ("fc.bias".to_string(), vec![2usize]),
            ("norm.gamma".to_string(), vec![2usize]),
            ("norm.beta".to_string(), vec![2usize]),
            ("norm.mean".to_string(), vec![2usize]),
            ("norm.var".to_string(), vec![2usize]),
        ];
        let mut store = ParamStore::zeros(&order);
        store.set("fc.weight", Tensor::new(&[3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap()).unwrap();
        store.set("fc.bias", Tensor::new(&[2], vec![0.5, -0.5]).unwrap()).unwrap();
        store.set("norm.gamma", Tensor::new(&[2], vec![2.0, 0.5]).unwrap()).unwrap();
        store.set("norm.beta", Tensor::new(&[2], vec![1.0, -1.0]).unwrap()).unwrap();
        store.set("norm.mean", Tensor::new(&[2], vec![0.1, 0.2]).unwrap()).unwrap();
        store.set("norm.var", Tensor::new(&[2], vec![1.0, 4.0]).unwrap()).unwrap();
        let eps = 0.0;
        // manual expectation for x = [1, 1, 1]
        let x = [1.0f32, 1.0, 1.0];
        let pre: Vec<f32> = (0..2)
            .map(|j| x.iter().enumerate().map(|(i, &v)| v * [1., 2., 3., 4., 5., 6.][i * 2 + j]).sum::<f32>() + [0.5, -0.5][j])
            .collect();
        let expect: Vec<f32> = (0..2)
            .map(|j| {
                let s = [2.0, 0.5][j] / ([1.0f32, 4.0][j] + eps).sqrt();
                (pre[j] - [0.1, 0.2][j]) * s + [1.0, -1.0][j]
            })
            .collect();
        fold_bn(&mut store, "fc", "norm", eps).unwrap();
        let w = store.get("fc.weight").unwrap();
        let b = store.get("fc.bias").unwrap();
        let got: Vec<f32> = (0..2)
            .map(|j| {
                x.iter().enumerate().map(|(i, &v)| v * w.at2(i, j)).sum::<f32>() + b.data()[j]
            })
            .collect();
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-5, "{got:?} vs {expect:?}");
        }
    }
}
