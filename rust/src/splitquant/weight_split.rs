//! Weight/bias splitting via 1-D k-means (paper §4.1, Figure 2/3).
//!
//! Every element of a layer's weight (and bias) is assigned to the lower /
//! middle / upper cluster; each cluster gets its own affine quantization
//! parameters computed over `cluster_range ∪ {0}`. Including 0 in the range
//! (a) is exactly what quantizing the paper's zero-injected split layers
//! does, and (b) guarantees the injected zeros reconstruct *exactly*
//! (`dq(Q(0)) == 0` whenever 0 is inside the range — asserted in
//! `quant::scheme` tests), so the fused codes+cid representation used here
//! is bit-identical to materializing three layers and summing.

use crate::error::Result;
use crate::quant::{QParams, QTensor};
use crate::tensor::packing::Packed;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use crate::clustering;

use super::SplitQuantConfig;

/// One split-quantized tensor plus its clustering metadata.
#[derive(Debug, Clone)]
pub struct SplitTensor {
    pub qtensor: QTensor,
    pub centroids: Vec<f32>,
    /// Per-element cluster assignment (original order) — kept for
    /// materialization/equivalence checks and the sparse executor.
    pub assignment: Vec<u8>,
}

/// Smallest packing width (1/2/4/8) that can hold ids `0..k`.
pub fn cid_bits(k: usize) -> u8 {
    match k {
        0..=2 => 1,
        3..=4 => 2,
        5..=16 => 4,
        _ => 8,
    }
}

/// Per-cluster quantization parameters over `range ∪ {0}`.
fn cluster_params(
    values: &[f32],
    assignment: &[u8],
    k: usize,
    bits: u8,
) -> Vec<QParams> {
    let mut lo = vec![0.0f32; k]; // start at 0: range always includes 0
    let mut hi = vec![0.0f32; k];
    for (&v, &a) in values.iter().zip(assignment) {
        let c = a as usize;
        lo[c] = lo[c].min(v);
        hi[c] = hi[c].max(v);
    }
    (0..k).map(|c| QParams::from_range(lo[c], hi[c], bits)).collect()
}

fn encode(
    values: &[f32],
    assignment: &[u8],
    params: &[QParams],
    bits: u8,
    k: usize,
) -> Result<(Packed, Packed)> {
    let codes: Vec<i8> = values
        .iter()
        .zip(assignment)
        .map(|(&v, &a)| params[a as usize].quantize(v))
        .collect();
    let codes = Packed::pack(&codes, bits)?;
    let cid = Packed::pack_unsigned(assignment, cid_bits(k))?;
    Ok((codes, cid))
}

/// Split-quantize a single tensor (no companion bias).
pub fn split_quantize(t: &Tensor, cfg: &SplitQuantConfig, rng: &mut Rng) -> Result<SplitTensor> {
    let km = clustering::cluster(t.data(), cfg.k, cfg.max_iter, rng);
    let params = cluster_params(t.data(), &km.assignment, cfg.k, cfg.bits);
    let (codes, cid) = encode(t.data(), &km.assignment, &params, cfg.bits, cfg.k)?;
    Ok(SplitTensor {
        qtensor: QTensor::from_split(t.shape(), codes, cid, params)?,
        centroids: km.centroids,
        assignment: km.assignment,
    })
}

/// Split-quantize with an **externally supplied** assignment (ablation A2:
/// equal-width / quantile splits instead of k-means). Assignment values must
/// lie in `[0, k)`.
pub fn split_quantize_with_assignment(
    t: &Tensor,
    assignment: Vec<u8>,
    k: usize,
    bits: u8,
) -> Result<SplitTensor> {
    assert_eq!(assignment.len(), t.numel());
    let params = cluster_params(t.data(), &assignment, k, bits);
    let (codes, cid) = encode(t.data(), &assignment, &params, bits, k)?;
    Ok(SplitTensor {
        qtensor: QTensor::from_split(t.shape(), codes, cid, params)?,
        centroids: vec![],
        assignment,
    })
}

/// Equal-width range partition (ablation A2 baseline splitter).
pub fn assign_equal_width(values: &[f32], k: usize) -> Vec<u8> {
    let (lo, hi) = crate::util::stats::min_max(values);
    let span = (hi - lo).max(1e-12);
    values
        .iter()
        .map(|&v| (((v - lo) / span * k as f32) as usize).min(k - 1) as u8)
        .collect()
}

/// Quantile partition: equal population per cluster (ablation A2 splitter).
pub fn assign_quantile(values: &[f32], k: usize) -> Vec<u8> {
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    idx.sort_by(|&a, &b| values[a as usize].partial_cmp(&values[b as usize]).unwrap());
    let mut out = vec![0u8; values.len()];
    for (rank, &orig) in idx.iter().enumerate() {
        out[orig as usize] = ((rank * k) / values.len()).min(k - 1) as u8;
    }
    out
}

/// Split-quantize a weight and its bias **jointly**: one k-means over the
/// concatenated values (Figure 2: weight and bias of a layer share the same
/// three split layers), then separate packed tensors.
pub fn split_quantize_pair(
    weight: &Tensor,
    bias: Option<&Tensor>,
    cfg: &SplitQuantConfig,
    rng: &mut Rng,
) -> Result<(SplitTensor, Option<SplitTensor>)> {
    let Some(bias) = bias else {
        return Ok((split_quantize(weight, cfg, rng)?, None));
    };
    let nw = weight.numel();
    let mut values = Vec::with_capacity(nw + bias.numel());
    values.extend_from_slice(weight.data());
    values.extend_from_slice(bias.data());

    let km = clustering::cluster(&values, cfg.k, cfg.max_iter, rng);
    let params = cluster_params(&values, &km.assignment, cfg.k, cfg.bits);

    let (w_codes, w_cid) =
        encode(&values[..nw], &km.assignment[..nw], &params, cfg.bits, cfg.k)?;
    let (b_codes, b_cid) =
        encode(&values[nw..], &km.assignment[nw..], &params, cfg.bits, cfg.k)?;

    let wt = SplitTensor {
        qtensor: QTensor::from_split(weight.shape(), w_codes, w_cid, params.clone())?,
        centroids: km.centroids.clone(),
        assignment: km.assignment[..nw].to_vec(),
    };
    let bt = SplitTensor {
        qtensor: QTensor::from_split(bias.shape(), b_codes, b_cid, params)?,
        centroids: km.centroids,
        assignment: km.assignment[nw..].to_vec(),
    };
    Ok((wt, Some(bt)))
}

/// Materialize the paper's zero-padded split branches from an assignment:
/// branch `c` holds the original values where `assignment == c`, 0 elsewhere.
pub fn materialize_branches(t: &Tensor, assignment: &[u8], k: usize) -> Vec<Tensor> {
    assert_eq!(t.numel(), assignment.len());
    let mut out = vec![Tensor::zeros(t.shape()); k];
    for (i, (&v, &a)) in t.data().iter().zip(assignment).enumerate() {
        out[a as usize].data_mut()[i] = v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen_values_with_outliers};

    fn cfg(bits: u8) -> SplitQuantConfig {
        SplitQuantConfig::new(bits)
    }

    #[test]
    fn split_reconstruction_beats_per_tensor_at_int2() {
        let mut rng = Rng::new(0);
        let vals = gen_values_with_outliers(&mut rng, 4096, 0.01);
        let t = Tensor::new(&[64, 64], vals).unwrap();
        let st = split_quantize(&t, &cfg(2), &mut rng).unwrap();
        let deq = st.qtensor.dequantize();
        let mse_split: f64 = t
            .data()
            .iter()
            .zip(deq.data())
            .map(|(&o, &d)| ((o - d) as f64).powi(2))
            .sum();
        let base =
            crate::quant::qtensor::fake_quant_tensor(&t, &crate::quant::QConfig::baseline(2))
                .unwrap();
        let mse_base: f64 = t
            .data()
            .iter()
            .zip(base.data())
            .map(|(&o, &d)| ((o - d) as f64).powi(2))
            .sum();
        // with 1% scattered outliers the win is solid but not dramatic
        // (k=3 cannot isolate ~40 outliers individually); the single-outlier
        // case below shows the dramatic regime
        assert!(mse_split < mse_base * 0.8, "split {mse_split} base {mse_base}");
    }

    #[test]
    fn outliers_survive_splitquant() {
        // paper's core claim: the outlier is kept AND the bulk keeps resolution
        let mut rng = Rng::new(1);
        let mut vals = gen_values_with_outliers(&mut rng, 2047, 0.0);
        vals.push(500.0); // one enormous outlier
        let t = Tensor::new(&[2048], vals.clone()).unwrap();
        let st = split_quantize(&t, &cfg(2), &mut rng).unwrap();
        let deq = st.qtensor.dequantize();
        // outlier reconstructed well (its own cluster, not clipped away)
        let out_err = (deq.data()[2047] - 500.0).abs();
        assert!(out_err < 100.0, "outlier err {out_err}");
        // bulk resolution: INT2 per-tensor min-max would give step ~167;
        // split's bulk cluster step must be tiny in comparison
        let bulk_params = st.qtensor.params()[st.assignment[0] as usize];
        assert!(bulk_params.step() < 10.0, "bulk step {}", bulk_params.step());
    }

    #[test]
    fn joint_bias_shares_clusters() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[32, 16], 0.0, 0.05, &mut rng);
        let b = Tensor::randn(&[16], 0.0, 0.05, &mut rng);
        let (wt, bt) = split_quantize_pair(&w, Some(&b), &cfg(4), &mut rng).unwrap();
        let bt = bt.unwrap();
        assert_eq!(wt.qtensor.params(), bt.qtensor.params());
        assert_eq!(wt.centroids, bt.centroids);
        assert_eq!(bt.assignment.len(), 16);
    }

    #[test]
    fn cid_bits_choice() {
        assert_eq!(cid_bits(2), 1);
        assert_eq!(cid_bits(3), 2);
        assert_eq!(cid_bits(4), 2);
        assert_eq!(cid_bits(5), 4);
    }

    #[test]
    fn materialized_branches_sum_to_original() {
        check("Σ branches == original", 30, |rng| {
            let n = rng.range(1, 400);
            let vals = gen_values_with_outliers(rng, n, 0.05);
            let t = Tensor::new(&[n], vals).unwrap();
            let st = split_quantize(&t, &cfg(4), rng).unwrap();
            let branches = materialize_branches(&t, &st.assignment, 3);
            let mut sum = Tensor::zeros(t.shape());
            for b in &branches {
                sum.add_assign(b);
            }
            assert!(t.max_abs_diff(&sum) == 0.0, "exact FP32 identity expected");
        });
    }

    #[test]
    fn fused_dequant_equals_branchwise_fake_quant_sum() {
        // dequantize(Split QTensor) == Σ_c fake_quant_c(branch_c)
        check("fused == branch-wise", 25, |rng| {
            let n = rng.range(2, 300);
            let vals = gen_values_with_outliers(rng, n, 0.1);
            let t = Tensor::new(&[n], vals).unwrap();
            let st = split_quantize(&t, &cfg(2), rng).unwrap();
            let fused = st.qtensor.dequantize();
            let branches = materialize_branches(&t, &st.assignment, 3);
            let params = st.qtensor.params();
            let mut sum = Tensor::zeros(t.shape());
            for (c, b) in branches.iter().enumerate() {
                for (i, &v) in b.data().iter().enumerate() {
                    // zero-injected entries reconstruct exactly to 0, so only
                    // the owned entries contribute — same as the fused path
                    if st.assignment[i] as usize == c {
                        sum.data_mut()[i] += params[c].fake(v);
                    } else {
                        assert_eq!(params[c].fake(v), 0.0); // v == 0 here
                    }
                }
            }
            assert!(fused.max_abs_diff(&sum) < 1e-6);
        });
    }

    #[test]
    fn k1_equals_per_tensor_baseline_with_zero_extension() {
        // k=1 degenerates to per-tensor quant over range ∪ {0}
        let mut rng = Rng::new(5);
        let vals: Vec<f32> = (0..256).map(|_| rng.normal_f32(1.0, 0.1)).collect();
        let t = Tensor::new(&[256], vals).unwrap();
        let c = SplitQuantConfig { k: 1, ..cfg(4) };
        let st = split_quantize(&t, &c, &mut rng).unwrap();
        assert_eq!(st.qtensor.params().len(), 1);
        let p = st.qtensor.params()[0];
        // range [0, max] (all values positive here)
        let (lo, hi) = t.min_max();
        let expect = QParams::from_range(0.0f32.min(lo), hi.max(0.0), 4);
        assert_eq!(p, expect);
    }

    #[test]
    fn assignment_is_monotone_lower_middle_upper() {
        let mut rng = Rng::new(6);
        let vals = gen_values_with_outliers(&mut rng, 3000, 0.02);
        let t = Tensor::new(&[3000], vals.clone()).unwrap();
        let st = split_quantize(&t, &cfg(4), &mut rng).unwrap();
        let mut pairs: Vec<(f32, u8)> = vals.into_iter().zip(st.assignment).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert!(pairs.windows(2).all(|w| w[0].1 <= w[1].1));
    }
}
