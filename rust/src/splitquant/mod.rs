//! **SplitQuant** (the paper's contribution): split each quantizable layer
//! into three mathematically equivalent layers so each gets its own
//! quantization scale (paper §4).
//!
//! * Weights & biases: 1-D k-means (k=3, greedy k-means++) clusters values
//!   into lower/middle/upper groups; each group is quantized with its own
//!   affine parameters ([`weight_split`]). The fused representation (codes +
//!   cluster-id plane) is *mathematically identical* to the paper's three
//!   zero-padded layers summed ([`equivalence`] proves it) while never
//!   materializing the zeros.
//! * Activations: positionally split into three chunks, each with its own
//!   scale, concatenated back ([`activation_split`]).
//! * BatchNorm is folded into preceding conv/linear layers before splitting
//!   (§4.1, [`bn_fold`]).
//!
//! ## Pass-pipeline API
//!
//! Whole-model quantization is expressed as composable passes over a shared
//! [`crate::quant::pipeline::ModelArtifact`]: BN folding, the SplitQuant
//! weight/bias split, activation calibration and the baselines are each a
//! [`crate::quant::pipeline::QuantPass`], chained with
//! [`crate::quant::pipeline::QuantPipeline`] — including per-layer
//! [`SplitQuantConfig`] overrides for mixed-precision bit-widths. The
//! [`quantize_store`] entry point below is a thin wrapper over a single-pass
//! pipeline, kept for the `(eval_store, qmodel)` tuple shape the benches and
//! examples grew up with.

pub mod activation_split;
pub mod analysis;
pub mod bn_fold;
pub mod equivalence;
pub mod weight_split;

use std::collections::BTreeMap;

use crate::error::Result;
use crate::model::params::ParamStore;
use crate::quant::pipeline::{QuantPipeline, SplitQuantPass};
use crate::quant::QTensor;

pub use activation_split::{params_from_samples, ActCalibrator, ActQuantMode, ActQuantParams};
pub use weight_split::{split_quantize, split_quantize_pair, SplitTensor};

/// SplitQuant configuration.
#[derive(Debug, Clone, Copy)]
pub struct SplitQuantConfig {
    /// Cluster count (paper: 3 = lower/middle/upper).
    pub k: usize,
    /// Target integer bit-width.
    pub bits: u8,
    /// Lloyd iteration cap.
    pub max_iter: usize,
    /// Cluster weight and bias values jointly in one k-means (one split per
    /// layer). Default **false**: ablation A2b shows joint clustering hurts
    /// badly when bias magnitudes differ from weight magnitudes (e.g. after
    /// BN folding) — the weight mass owns the centroids and biases land at
    /// cluster edges with large error. Clustering biases separately gives
    /// each its own lower/middle/upper split, matching Figure 2's structure
    /// while preserving accuracy (see EXPERIMENTS.md §A2b).
    pub joint_bias: bool,
    /// Seed for k-means++ (deterministic runs).
    pub seed: u64,
}

impl SplitQuantConfig {
    pub fn new(bits: u8) -> Self {
        SplitQuantConfig { k: 3, bits, max_iter: 50, joint_bias: false, seed: 0xC10C }
    }

    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }
}

/// A whole model quantized with SplitQuant: per-parameter Split-layout
/// tensors plus the names deliberately kept FP32.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedModel {
    pub tensors: BTreeMap<String, QTensor>,
    pub fp32_names: Vec<String>,
    pub bits: u8,
}

impl QuantizedModel {
    /// Packed size of the quantized parameters (paper-§6 accounting).
    pub fn quantized_bytes(&self) -> usize {
        self.tensors.values().map(|q| q.byte_size()).sum()
    }
}

/// Parameter names the PTQ passes quantize, mirroring the paper's scope
/// (linear/conv layers incl. the token embedding; normalization parameters
/// are *not* quantized — §4.1 notes PyTorch stores LN gamma as "weight" but
/// they are semantically not weights, and BN is folded instead).
pub fn default_quantizable(store: &ParamStore) -> Vec<String> {
    store
        .names()
        .iter()
        .filter(|n| {
            let n = n.as_str();
            let is_wb = n.ends_with(".weight") || n.ends_with(".bias");
            let is_norm = n.contains(".ln.")
                || n.starts_with("bn")
                || n.contains(".bn")
                || n.ends_with(".gamma")
                || n.ends_with(".beta")
                || n.ends_with(".mean")
                || n.ends_with(".var");
            let is_emb = n == "embeddings.token";
            (is_wb && !is_norm) || is_emb
        })
        .cloned()
        .collect()
}

/// Apply SplitQuant PTQ to every quantizable parameter of `store`.
///
/// Returns `(eval_store, qmodel)`: `eval_store` carries the dequantized
/// (fake-quant) weights for accuracy evaluation through any executor
/// (copy-on-write shared with `store` — untouched tensors are never
/// copied), and `qmodel` the packed representation for size accounting /
/// deployment. Thin wrapper over a single
/// [`crate::quant::pipeline::SplitQuantPass`] pipeline; use the pipeline
/// directly to compose with BN folding, activation calibration, or
/// per-layer mixed-precision overrides.
pub fn quantize_store(
    store: &ParamStore,
    quantizable: &[String],
    cfg: &SplitQuantConfig,
) -> Result<(ParamStore, QuantizedModel)> {
    let artifact = QuantPipeline::new()
        .pass(SplitQuantPass::with_config(*cfg).quantizable(quantizable.to_vec()))
        .run(store)?;
    Ok(artifact.into_parts())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;
    use crate::util::rng::Rng;

    fn tiny_store() -> (BertConfig, ParamStore) {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        (cfg, store)
    }

    #[test]
    fn quantizable_set_excludes_norms_and_position() {
        let (_, store) = tiny_store();
        let q = default_quantizable(&store);
        assert!(q.contains(&"embeddings.token".to_string()));
        assert!(q.contains(&"encoder.0.attn.q.weight".to_string()));
        assert!(q.contains(&"encoder.0.ffn.in.bias".to_string()));
        assert!(q.contains(&"classifier.weight".to_string()));
        assert!(!q.iter().any(|n| n.contains(".ln.")));
        assert!(!q.contains(&"embeddings.position".to_string()));
    }

    #[test]
    fn quantize_store_roundtrip_shapes() {
        let (cfg, store) = tiny_store();
        let quantizable = default_quantizable(&store);
        let sq = SplitQuantConfig::new(4);
        let (eval_store, qmodel) = quantize_store(&store, &quantizable, &sq).unwrap();
        eval_store.check_order(&cfg.param_order()).unwrap();
        assert_eq!(qmodel.tensors.len(), quantizable.len());
        // LN params untouched
        assert_eq!(
            eval_store.get("encoder.0.attn.ln.gamma").unwrap().data(),
            store.get("encoder.0.attn.ln.gamma").unwrap().data()
        );
        // quantized weights differ but are close at 4 bits
        let orig = store.get("encoder.0.attn.q.weight").unwrap();
        let deq = eval_store.get("encoder.0.attn.q.weight").unwrap();
        let diff = orig.max_abs_diff(deq);
        assert!(diff > 0.0 && diff < 0.05, "diff {diff}");
    }

    #[test]
    fn int2_split_reconstruction_beats_baseline() {
        // aggregate reconstruction MSE over a whole store: SplitQuant must
        // beat the per-tensor min-max baseline at INT2
        let (_, store) = tiny_store();
        let quantizable = default_quantizable(&store);
        let sq = SplitQuantConfig::new(2);
        let (eval_sq, _) = quantize_store(&store, &quantizable, &sq).unwrap();
        let base_cfg = crate::quant::QConfig::baseline(2);
        let mut mse_sq = 0.0f64;
        let mut mse_base = 0.0f64;
        for name in &quantizable {
            let orig = store.get(name).unwrap();
            let sq_t = eval_sq.get(name).unwrap();
            let base_t =
                crate::quant::qtensor::fake_quant_tensor(orig, &base_cfg).unwrap();
            for ((&o, &s), &b) in
                orig.data().iter().zip(sq_t.data()).zip(base_t.data())
            {
                mse_sq += ((s - o) as f64).powi(2);
                mse_base += ((b - o) as f64).powi(2);
            }
        }
        assert!(mse_sq < mse_base * 0.5, "split {mse_sq} vs base {mse_base}");
    }
}
