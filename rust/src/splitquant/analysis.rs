//! Diagnostics: per-tensor clustering / quantization analysis.
//!
//! Powers the `splitquant analyze` CLI subcommand and the EXPERIMENTS.md
//! narrative: for every quantizable tensor it reports the value range, the
//! outlier mass, the per-cluster sub-ranges and the **resolution gain** —
//! the ratio between the baseline quantization step and the
//! population-weighted mean split step, which is exactly the quantity the
//! paper's §4 argument says SplitQuant improves.

use crate::error::Result;
use crate::model::params::ParamStore;
use crate::quant::QParams;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats;

use super::weight_split::split_quantize;
use super::SplitQuantConfig;

/// Analysis of one tensor.
#[derive(Debug, Clone)]
pub struct TensorAnalysis {
    pub name: String,
    pub numel: usize,
    pub range: (f32, f32),
    pub std: f64,
    /// fraction of values with |v − µ| > 4σ (the outlier mass)
    pub outlier_frac: f64,
    /// per-cluster (population, lo, hi, step)
    pub clusters: Vec<ClusterStat>,
    /// baseline per-tensor quantization step at the analysis bit-width
    pub baseline_step: f32,
    /// population-weighted mean step across clusters
    pub split_step: f32,
}

#[derive(Debug, Clone)]
pub struct ClusterStat {
    pub population: usize,
    pub lo: f32,
    pub hi: f32,
    pub step: f32,
}

impl TensorAnalysis {
    /// How much finer the split resolution is vs the baseline (≥ 1 in
    /// practice; equals 1 only when clustering cannot shrink any range).
    pub fn resolution_gain(&self) -> f64 {
        self.baseline_step as f64 / self.split_step.max(f32::MIN_POSITIVE) as f64
    }
}

/// Analyze one tensor under a SplitQuant config.
pub fn analyze_tensor(
    name: &str,
    t: &Tensor,
    cfg: &SplitQuantConfig,
    rng: &mut Rng,
) -> Result<TensorAnalysis> {
    let (lo, hi) = t.min_max();
    let mean = stats::mean(t.data());
    let std = stats::std_dev(t.data());
    let outliers = t
        .data()
        .iter()
        .filter(|&&v| (v as f64 - mean).abs() > 4.0 * std)
        .count();

    let st = split_quantize(t, cfg, rng)?;
    let sizes = {
        let mut s = vec![0usize; cfg.k];
        for &a in &st.assignment {
            s[a as usize] += 1;
        }
        s
    };
    let ranges = {
        let mut r = vec![(f32::INFINITY, f32::NEG_INFINITY); cfg.k];
        for (&v, &a) in t.data().iter().zip(&st.assignment) {
            let e = &mut r[a as usize];
            e.0 = e.0.min(v);
            e.1 = e.1.max(v);
        }
        r
    };
    let clusters: Vec<ClusterStat> = (0..cfg.k)
        .map(|c| ClusterStat {
            population: sizes[c],
            lo: ranges[c].0,
            hi: ranges[c].1,
            step: st.qtensor.params()[c].step(),
        })
        .collect();

    let baseline_step = QParams::from_range(lo, hi, cfg.bits).step();
    let total: usize = sizes.iter().sum();
    let split_step = clusters
        .iter()
        .map(|c| c.step * c.population as f32 / total.max(1) as f32)
        .sum();

    Ok(TensorAnalysis {
        name: name.to_string(),
        numel: t.numel(),
        range: (lo, hi),
        std,
        outlier_frac: outliers as f64 / t.numel().max(1) as f64,
        clusters,
        baseline_step,
        split_step,
    })
}

/// Analyze every quantizable tensor of a model.
pub fn analyze_store(
    store: &ParamStore,
    quantizable: &[String],
    cfg: &SplitQuantConfig,
) -> Result<Vec<TensorAnalysis>> {
    let mut rng = Rng::new(cfg.seed);
    quantizable
        .iter()
        .map(|n| analyze_tensor(n, store.get(n)?, cfg, &mut rng))
        .collect()
}

/// Render analyses as a report table.
pub fn render_report(analyses: &[TensorAnalysis]) -> crate::report::Table {
    let mut t = crate::report::Table::new(
        "SplitQuant per-tensor analysis",
        &["tensor", "numel", "range", "4σ-outliers", "cluster pops", "base step", "split step", "gain"],
    );
    for a in analyses {
        t.row(vec![
            a.name.clone(),
            a.numel.to_string(),
            format!("[{:.3}, {:.3}]", a.range.0, a.range.1),
            format!("{:.2}%", a.outlier_frac * 100.0),
            format!("{:?}", a.clusters.iter().map(|c| c.population).collect::<Vec<_>>()),
            format!("{:.2e}", a.baseline_step),
            format!("{:.2e}", a.split_step),
            format!("{:.1}x", a.resolution_gain()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::gen_values_with_outliers;

    #[test]
    fn analysis_basics() {
        let mut rng = Rng::new(0);
        let vals = gen_values_with_outliers(&mut rng, 5000, 0.005);
        let t = Tensor::new(&[5000], vals).unwrap();
        let a = analyze_tensor("w", &t, &SplitQuantConfig::new(2), &mut rng).unwrap();
        assert_eq!(a.numel, 5000);
        assert_eq!(a.clusters.len(), 3);
        assert_eq!(a.clusters.iter().map(|c| c.population).sum::<usize>(), 5000);
        assert!(a.outlier_frac > 0.0);
        // SplitQuant must improve the effective resolution with outliers present
        assert!(a.resolution_gain() > 2.0, "gain {}", a.resolution_gain());
    }

    #[test]
    fn gaussian_without_outliers_still_gains() {
        // §4: even without outliers, splitting narrows ranges (the OCS
        // contrast: SplitQuant helps in the no-outlier regime too)
        let mut rng = Rng::new(1);
        let vals: Vec<f32> = (0..4000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = Tensor::new(&[4000], vals).unwrap();
        let a = analyze_tensor("w", &t, &SplitQuantConfig::new(2), &mut rng).unwrap();
        assert!(a.resolution_gain() > 1.5, "gain {}", a.resolution_gain());
    }

    #[test]
    fn store_level_report_renders() {
        let cfg = crate::model::config::BertConfig {
            vocab_size: 64,
            hidden: 8,
            layers: 1,
            heads: 2,
            ffn: 16,
            max_len: 8,
            num_classes: 2,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(2);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let quantizable = super::super::default_quantizable(&store);
        let analyses =
            analyze_store(&store, &quantizable, &SplitQuantConfig::new(2)).unwrap();
        assert_eq!(analyses.len(), quantizable.len());
        let rendered = render_report(&analyses).render();
        assert!(rendered.contains("gain"));
        assert!(rendered.lines().count() > quantizable.len());
    }
}
