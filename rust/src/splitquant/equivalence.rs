//! Structural equivalence checks (Figures 1–3): the split graph computes the
//! same function as the original, before and after quantization of each
//! branch.
//!
//! These are the runnable form of the paper's "mathematically equivalent"
//! claim and back the `equivalence` bench and integration tests.

use crate::model::graph::{ActKind, Layer, LinearPart};
use crate::quant::QParams;
use crate::tensor::ops;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::weight_split::{materialize_branches, SplitTensor};
use super::SplitQuantConfig;

/// Build the paper's literal three-branch split linear layer (zero-padded
/// weights/biases per cluster) from clustering results.
pub fn split_linear_layer(
    weight: &Tensor,
    bias: Option<&Tensor>,
    w_split: &SplitTensor,
    b_split: Option<&SplitTensor>,
    k: usize,
) -> Layer {
    let w_branches = materialize_branches(weight, &w_split.assignment, k);
    let b_branches = match (bias, b_split) {
        (Some(b), Some(bs)) => Some(materialize_branches(b, &bs.assignment, k)),
        _ => None,
    };
    let parts = (0..k)
        .map(|c| LinearPart {
            weight: w_branches[c].clone(),
            bias: b_branches.as_ref().map(|bb| bb[c].clone()),
        })
        .collect();
    Layer::SplitLinear { parts }
}

/// Fake-quantize each branch of a split linear layer with its own cluster
/// parameters (what a downstream per-tensor quantizer would do to the
/// reshaped model — this is how SplitQuant "helps other quantizers").
pub fn quantize_branches(layer: &Layer, params: &[QParams]) -> Layer {
    let Layer::SplitLinear { parts } = layer else {
        panic!("quantize_branches expects a SplitLinear layer");
    };
    let parts = parts
        .iter()
        .zip(params)
        .map(|(p, qp)| {
            let mut w = p.weight.clone();
            for v in w.data_mut() {
                *v = qp.fake(*v);
            }
            let bias = p.bias.as_ref().map(|b| {
                let mut b = b.clone();
                for v in b.data_mut() {
                    *v = qp.fake(*v);
                }
                b
            });
            LinearPart { weight: w, bias }
        })
        .collect();
    Layer::SplitLinear { parts }
}

/// Report of one equivalence experiment.
#[derive(Debug, Clone)]
pub struct EquivalenceReport {
    /// max |original − split| in FP32 (must be ~0: exact identity).
    pub fp32_gap: f32,
    /// max |fused dequant path − materialized 3-layer quantized path|.
    pub fused_vs_branches_gap: f32,
    /// max |original − quantized split| (the actual quantization error).
    pub quant_error_split: f32,
    /// max |original − per-tensor-quantized| (baseline error, for context).
    pub quant_error_baseline: f32,
}

/// Run the full Figure-2 experiment on a random linear layer.
pub fn check_linear_equivalence(
    n_in: usize,
    n_out: usize,
    batch: usize,
    cfg: &SplitQuantConfig,
    rng: &mut Rng,
) -> EquivalenceReport {
    let weight = Tensor::randn(&[n_in, n_out], 0.0, 0.5, rng);
    let bias = Tensor::randn(&[n_out], 0.0, 0.5, rng);
    let x = Tensor::randn(&[batch, n_in], 0.0, 1.0, rng);

    let (ws, bs) = super::split_quantize_pair(&weight, Some(&bias), cfg, rng).unwrap();
    let bs = bs.unwrap();

    // (1) FP32: split-with-zeros == original
    let orig = Layer::Linear { weight: weight.clone(), bias: Some(bias.clone()) };
    let split = split_linear_layer(&weight, Some(&bias), &ws, Some(&bs), cfg.k);
    let y_orig = orig.forward(&x);
    let y_split = split.forward(&x);
    let fp32_gap = y_orig.max_abs_diff(&y_split);

    // (2) quantized: fused dequant == branch-wise quantized materialization
    let fused = Layer::Linear {
        weight: ws.qtensor.dequantize(),
        bias: Some(bs.qtensor.dequantize()),
    };
    let qsplit = quantize_branches(&split, ws.qtensor.params());
    let y_fused = fused.forward(&x);
    let y_qsplit = qsplit.forward(&x);
    let fused_vs_branches_gap = y_fused.max_abs_diff(&y_qsplit);

    // (3) error vs baseline per-tensor quant
    let quant_error_split = y_orig.max_abs_diff(&y_fused);
    let bl = crate::quant::QConfig::baseline(cfg.bits);
    let wq = crate::quant::qtensor::fake_quant_tensor(&weight, &bl).unwrap();
    let bq = crate::quant::qtensor::fake_quant_tensor(&bias, &bl).unwrap();
    let y_base =
        Layer::Linear { weight: wq, bias: Some(bq) }.forward(&x);
    let quant_error_baseline = y_orig.max_abs_diff(&y_base);

    EquivalenceReport { fp32_gap, fused_vs_branches_gap, quant_error_split, quant_error_baseline }
}

/// Figure-1(D) experiment: split activation == plain activation in FP32.
pub fn check_activation_equivalence(width: usize, batch: usize, rng: &mut Rng) -> f32 {
    let x = Tensor::randn(&[batch, width], 0.0, 2.0, rng);
    let spans = crate::model::config::chunk_spans(width, 3);
    let plain = Layer::Activation(ActKind::Gelu).forward(&x);
    let split = Layer::SplitActivation { kind: ActKind::Gelu, spans }.forward(&x);
    plain.max_abs_diff(&split)
}

/// Figure-3 experiment: conv splitting via the im2col-free elementwise path —
/// conv weights are split like any other tensor; we validate on the CNN
/// executor by comparing fused-dequant conv weights against branch-sum.
pub fn check_conv_equivalence(cfg: &SplitQuantConfig, rng: &mut Rng) -> f32 {
    let w = Tensor::randn(&[8, 4, 3, 3], 0.0, 0.5, rng);
    let b = Tensor::randn(&[8], 0.0, 0.5, rng);
    let x = Tensor::randn(&[2, 4, 10, 10], 0.0, 1.0, rng);
    let (ws, bs) = super::split_quantize_pair(&w, Some(&b), cfg, rng).unwrap();
    let bs = bs.unwrap();

    // branch-wise: conv with each zero-padded branch, then sum (bias once,
    // split across branches)
    let wb = materialize_branches(&w, &ws.assignment, cfg.k);
    let bb = materialize_branches(&b, &bs.assignment, cfg.k);
    let params = ws.qtensor.params();
    let mut acc: Option<Tensor> = None;
    for c in 0..cfg.k {
        let mut wq = wb[c].clone();
        for v in wq.data_mut() {
            *v = params[c].fake(*v);
        }
        let mut bq = bb[c].clone();
        for v in bq.data_mut() {
            *v = params[c].fake(*v);
        }
        let y = ops::conv2d_same(&x, &wq, &bq);
        match &mut acc {
            None => acc = Some(y),
            Some(a) => a.add_assign(&y),
        }
    }
    // fused path
    let y_fused = ops::conv2d_same(&x, &ws.qtensor.dequantize(), &bs.qtensor.dequantize());
    acc.unwrap().max_abs_diff(&y_fused)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_split_is_equivalent_and_better() {
        let mut rng = Rng::new(0);
        let cfg = SplitQuantConfig::new(2);
        let r = check_linear_equivalence(64, 32, 8, &cfg, &mut rng);
        assert!(r.fp32_gap < 1e-4, "fp32 gap {}", r.fp32_gap);
        assert!(r.fused_vs_branches_gap < 1e-4, "fused gap {}", r.fused_vs_branches_gap);
        assert!(
            r.quant_error_split < r.quant_error_baseline,
            "split {} vs baseline {}",
            r.quant_error_split,
            r.quant_error_baseline
        );
    }

    #[test]
    fn activation_split_exact() {
        let mut rng = Rng::new(1);
        for width in [12usize, 128, 512, 7] {
            let gap = check_activation_equivalence(width, 5, &mut rng);
            assert!(gap < 1e-6, "width {width}: {gap}");
        }
    }

    #[test]
    fn conv_split_fused_equals_branches() {
        let mut rng = Rng::new(2);
        for bits in [2u8, 4, 8] {
            let cfg = SplitQuantConfig::new(bits);
            let gap = check_conv_equivalence(&cfg, &mut rng);
            assert!(gap < 1e-4, "bits {bits}: {gap}");
        }
    }

    #[test]
    fn equivalence_holds_across_bit_widths() {
        let mut rng = Rng::new(3);
        for bits in [2u8, 4, 8] {
            let cfg = SplitQuantConfig::new(bits);
            let r = check_linear_equivalence(32, 16, 4, &cfg, &mut rng);
            assert!(r.fp32_gap < 1e-4);
            assert!(r.fused_vs_branches_gap < 1e-4, "bits {bits}: {}", r.fused_vs_branches_gap);
        }
    }
}
