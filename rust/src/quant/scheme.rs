//! Affine quantization math — paper §3, Eq. (1)–(3), bit-exact with the
//! jnp oracle in `python/compile/kernels/ref.py`:
//!
//! ```text
//! S = (2^b − 1) / (α − β)
//! Z = −2^(b−1) − INT(S·β)
//! Q(x) = clip(INT(S·x) + Z, −2^(b−1), 2^(b−1)−1)
//! dq(q) = (q − Z) / S
//! ```
//!
//! `INT` is round-half-to-even (`f32::round_ties_even`, = `jnp.round`).

/// (qmin, qmax) of signed `bits`-wide integers.
pub fn qrange(bits: u8) -> (i32, i32) {
    let h = 1i32 << (bits - 1);
    (-h, h - 1)
}

/// Quantization parameters for one scale group (tensor / channel / cluster).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zp: f32,
    pub bits: u8,
}

impl QParams {
    /// Parameters for original value range `[beta, alpha]` (asymmetric).
    ///
    /// Degenerate spans are widened to 1e-8, matching the oracle, so constant
    /// tensors stay finite.
    pub fn from_range(beta: f32, alpha: f32, bits: u8) -> QParams {
        debug_assert!(alpha >= beta, "range [{beta}, {alpha}] inverted");
        let span = (alpha - beta).max(1e-8);
        let scale = ((1u64 << bits) - 1) as f32 / span;
        let zp = -((1i64 << (bits - 1)) as f32) - (scale * beta).round_ties_even();
        QParams { scale, zp, bits }
    }

    /// Symmetric parameters: range `[-a, a]` with `a = max(|beta|, |alpha|)`.
    /// The zero-point lands on 0 by construction.
    pub fn symmetric_from_range(beta: f32, alpha: f32, bits: u8) -> QParams {
        let a = beta.abs().max(alpha.abs());
        QParams::from_range(-a, a, bits)
    }

    /// Quantize one value to its integer code (fits i8 for bits ≤ 8).
    #[inline]
    pub fn quantize(&self, x: f32) -> i8 {
        let (qmin, qmax) = qrange(self.bits);
        let q = (self.scale * x).round_ties_even() + self.zp;
        (q.clamp(qmin as f32, qmax as f32)) as i8
    }

    /// Dequantize a code.
    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        (q as f32 - self.zp) / self.scale
    }

    /// Quantize-dequantize (the PTQ simulation primitive).
    #[inline]
    pub fn fake(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// Width of one quantization step in original units (the resolution the
    /// paper's argument is about: SplitQuant shrinks this).
    #[inline]
    pub fn step(&self) -> f32 {
        1.0 / self.scale
    }

    /// Representable dequantized interval.
    pub fn dequant_range(&self) -> (f32, f32) {
        let (qmin, qmax) = qrange(self.bits);
        (self.dequantize(qmin as i8), self.dequantize(qmax as i8))
    }
}

/// Quantize a slice into codes.
pub fn quantize_slice(values: &[f32], p: &QParams) -> Vec<i8> {
    values.iter().map(|&v| p.quantize(v)).collect()
}

/// Fake-quantize a slice in place.
pub fn fake_quant_slice(values: &mut [f32], p: &QParams) {
    for v in values.iter_mut() {
        *v = p.fake(*v);
    }
}

/// Mean squared quantization error of a slice under params.
pub fn quant_mse(values: &[f32], p: &QParams) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values
        .iter()
        .map(|&v| {
            let d = (p.fake(v) - v) as f64;
            d * d
        })
        .sum::<f64>()
        / values.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn qrange_matches_paper() {
        assert_eq!(qrange(2), (-2, 1));
        assert_eq!(qrange(4), (-8, 7));
        assert_eq!(qrange(8), (-128, 127));
    }

    #[test]
    fn zero_reconstructs_exactly_when_in_range() {
        // critical SplitQuant property: injected zeros quantize losslessly
        for bits in [2u8, 4, 8] {
            for &(beta, alpha) in &[(-3.0f32, 5.0), (0.0, 7.0), (-9.0, 0.0), (-0.5, 0.25)] {
                let p = QParams::from_range(beta, alpha, bits);
                assert_eq!(p.fake(0.0), 0.0, "bits={bits} range=[{beta},{alpha}]");
            }
        }
    }

    #[test]
    fn symmetric_zero_point_is_zero() {
        for bits in [2u8, 4, 8] {
            let p = QParams::symmetric_from_range(-3.0, 2.0, bits);
            assert_eq!(p.zp, 0.0, "bits={bits}");
        }
    }

    #[test]
    fn int8_spans_range() {
        let p = QParams::from_range(-1.0, 1.0, 8);
        assert!((p.fake(-1.0) + 1.0).abs() < 0.01);
        assert!((p.fake(1.0) - 1.0).abs() < 0.01);
        assert!(p.fake(0.37).abs() - 0.37 < 0.01);
    }

    #[test]
    fn int2_has_four_codes() {
        let p = QParams::from_range(-2.0, 1.0, 2);
        let mut codes: Vec<i8> = (-20..=20).map(|i| p.quantize(i as f32 * 0.1)).collect();
        codes.sort();
        codes.dedup();
        assert!(codes.len() <= 4);
    }

    #[test]
    fn matches_paper_example_resolution_collapse() {
        // §1: outlier crushes 4 values onto one code at low bits
        let vals = [-1000.0f32, -500.0, 0.0, 500.0];
        let with_outlier = QParams::from_range(-1000.0, 1e8, 4);
        let codes: Vec<i8> = vals.iter().map(|&v| with_outlier.quantize(v)).collect();
        let uniq: std::collections::HashSet<i8> = codes.iter().copied().collect();
        assert!(uniq.len() <= 2, "{codes:?}");
        let without = QParams::from_range(-1000.0, 1000.0, 4);
        let codes2: Vec<i8> = vals.iter().map(|&v| without.quantize(v)).collect();
        let uniq2: std::collections::HashSet<i8> = codes2.iter().copied().collect();
        assert_eq!(uniq2.len(), 4, "{codes2:?}");
    }

    #[test]
    fn degenerate_range_finite() {
        let p = QParams::from_range(1.234, 1.234, 8);
        assert!(p.scale.is_finite());
        assert!(p.fake(1.234).is_finite());
    }

    #[test]
    fn property_error_bounded_by_half_step() {
        check("in-range quant error <= step/2", 60, |rng| {
            let bits = [2u8, 4, 8][rng.below(3)];
            let beta = rng.normal_f32(0.0, 10.0);
            let span = rng.range_f64(0.01, 100.0) as f32;
            let alpha = beta + span;
            let p = QParams::from_range(beta, alpha, bits);
            for _ in 0..50 {
                let x = beta + rng.f32() * span;
                let err = (p.fake(x) - x).abs();
                assert!(
                    err <= p.step() * 0.5 + p.step() * 1e-3,
                    "x={x} err={err} step={}",
                    p.step()
                );
            }
        });
    }

    #[test]
    fn property_codes_clip_to_range() {
        check("codes stay in [qmin,qmax]", 50, |rng| {
            let bits = [2u8, 3, 4, 8][rng.below(4)];
            let p = QParams::from_range(-1.0, 1.0, bits);
            let (qmin, qmax) = qrange(bits);
            for _ in 0..50 {
                let x = rng.normal_f32(0.0, 100.0); // mostly out of range
                let q = p.quantize(x) as i32;
                assert!(q >= qmin && q <= qmax);
            }
        });
    }

    #[test]
    fn mse_decreases_with_bits() {
        let mut rng = crate::util::rng::Rng::new(0);
        let values: Vec<f32> = (0..1000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (lo, hi) = crate::util::stats::min_max(&values);
        let mses: Vec<f64> = [2u8, 4, 8]
            .iter()
            .map(|&b| quant_mse(&values, &QParams::from_range(lo, hi, b)))
            .collect();
        assert!(mses[0] > mses[1] && mses[1] > mses[2], "{mses:?}");
    }
}
