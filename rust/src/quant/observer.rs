//! Range observers: how the quantizer decides `[β, α]` from data.
//!
//! * [`Observer::MinMax`] — keep everything, outliers included (paper's
//!   "keep outliers" horn of the dilemma).
//! * [`Observer::Percentile`] — the de-facto outlier-clipping baseline
//!   (paper §1: "often 99% is used in practice"); two-sided clip.
//! * [`Observer::MseSearch`] — shrink the min-max range over a grid and keep
//!   the one minimizing reconstruction MSE (a stronger classical baseline).

use crate::error::{Error, Result};
use crate::util::stats;

use super::scheme::{quant_mse, QParams};

/// Strategy for turning sample values into a quantization range.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Observer {
    /// Full min–max range.
    MinMax,
    /// Two-sided percentile clipping: range = [pct(100−p), pct(p)].
    Percentile { pct: f64 },
    /// Grid search over symmetric shrink factors of the min-max range,
    /// minimizing fake-quant MSE.
    MseSearch { steps: usize },
    /// Histogram/entropy calibration (TensorRT-style): build a `bins`-bin
    /// histogram, try clip thresholds, keep the one minimizing the KL
    /// divergence between the clipped distribution and its quantized
    /// re-expansion.
    Entropy { bins: usize },
}

impl Observer {
    /// Compute the quantization range `[beta, alpha]` for `values`.
    ///
    /// Errors deterministically — instead of returning a garbage range —
    /// on an empty slice (an empty calibration batch) and on any NaN/±inf
    /// value: every observer reduces the data through min/max, sorting, or
    /// histogramming, all of which silently poison the range under
    /// non-finite input.
    pub fn range(&self, values: &[f32], bits: u8) -> Result<(f32, f32)> {
        if values.is_empty() {
            return Err(Error::Quant(format!(
                "{} observer on empty calibration data",
                self.label()
            )));
        }
        if let Some(bad) = values.iter().find(|v| !v.is_finite()) {
            return Err(Error::Quant(format!(
                "{} observer on non-finite calibration value {bad}",
                self.label()
            )));
        }
        Ok(match *self {
            Observer::MinMax => stats::min_max(values),
            Observer::Percentile { pct } => {
                let mut sorted: Vec<f32> = values.to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let lo = stats::percentile_sorted(&sorted, 100.0 - pct);
                let hi = stats::percentile_sorted(&sorted, pct);
                if lo <= hi {
                    (lo, hi)
                } else {
                    (hi, lo)
                }
            }
            Observer::MseSearch { steps } => {
                let (lo, hi) = stats::min_max(values);
                let mut best = (lo, hi);
                let mut best_mse = f64::INFINITY;
                for s in 0..steps {
                    // log grid 1.0 .. 1e-3: outliers can be many orders of
                    // magnitude above the bulk, a linear grid cannot reach them
                    let f = 10f32.powf(-3.0 * s as f32 / (steps.max(2) - 1) as f32);
                    let (b, a) = (lo * f, hi * f);
                    let p = QParams::from_range(b, a, bits);
                    let mse = quant_mse(values, &p);
                    if mse < best_mse {
                        best_mse = mse;
                        best = (b, a);
                    }
                }
                best
            }
            Observer::Entropy { bins } => entropy_range(values, bits, bins),
        })
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            Observer::MinMax => "minmax".into(),
            Observer::Percentile { pct } => format!("pct{pct}"),
            Observer::MseSearch { steps } => format!("mse{steps}"),
            Observer::Entropy { bins } => format!("kl{bins}"),
        }
    }
}

/// Online drift probe for a deployed range: `(clipped, min, max)` of
/// `values` against the calibrated `[lo, hi]` — `clipped` counts values a
/// [`QParams::quantize`] built on that range would saturate (strictly
/// outside it; NaNs count as clipped, since they quantize meaninglessly).
/// One fused pass, used by [`crate::qhealth`] at dispatch granularity and
/// by its ground-truth reconciliation tests.
pub fn clip_stats(values: &[f32], lo: f32, hi: f32) -> (u64, f32, f32) {
    let mut clipped = 0u64;
    let mut omin = f32::INFINITY;
    let mut omax = f32::NEG_INFINITY;
    for &v in values {
        if v.is_nan() {
            clipped += 1;
            continue;
        }
        omin = omin.min(v);
        omax = omax.max(v);
        if v < lo || v > hi {
            clipped += 1;
        }
    }
    (clipped, omin, omax)
}

/// TensorRT-style entropy calibration on |values| (symmetric clip search).
///
/// For each candidate clip `c` (a histogram-bin edge), the reference
/// distribution is the histogram with out-of-clip mass folded into the edge
/// bin, and the candidate distribution is that histogram collapsed onto
/// `2^bits` quantization buckets and re-expanded. The clip minimizing
/// KL(ref ‖ cand) wins; the returned range is `[-c, c]` intersected with
/// the data's sign support.
fn entropy_range(values: &[f32], bits: u8, bins: usize) -> (f32, f32) {
    let bins = bins.max(64);
    let (lo, hi) = stats::min_max(values);
    let max_abs = lo.abs().max(hi.abs()).max(1e-12);
    // |v| histogram
    let mut hist = vec![0f64; bins];
    for &v in values {
        let b = ((v.abs() / max_abs) * bins as f32) as usize;
        hist[b.min(bins - 1)] += 1.0;
    }
    let levels = (1usize << bits).max(2) / 2; // positive-side buckets
    let start = levels.max(bins / 16).min(bins - 1);
    let mut best_bin = bins;
    let mut best_kl = f64::INFINITY;
    // reference: the FULL |v| histogram — clipping away real mass must cost
    // divergence (a clipped-only reference lets the smallest clip win with
    // KL = 0, the classic pitfall)
    let psum: f64 = hist.iter().sum::<f64>().max(1e-12);
    for clip in start..=bins {
        // candidate: kept bins collapsed into `levels` buckets and
        // re-expanded; clipped bins dequantize onto the edge level
        let mut q = vec![0f64; bins];
        let per = clip as f64 / levels as f64;
        let mut edge_density = 0.0f64;
        for lvl in 0..levels {
            let a = (lvl as f64 * per).floor() as usize;
            let b = (((lvl + 1) as f64 * per).ceil() as usize).min(clip);
            let mass: f64 = hist[a..b].iter().sum();
            let nonzero = hist[a..b].iter().filter(|&&x| x > 0.0).count().max(1);
            let d = mass / nonzero as f64;
            for i in a..b {
                if hist[i] > 0.0 {
                    q[i] = d;
                }
            }
            if lvl == levels - 1 {
                edge_density = d;
            }
        }
        for i in clip..bins {
            if hist[i] > 0.0 {
                // clipped values reconstruct at the edge — approximate their
                // modelled density by the edge level's (spread thin, so real
                // tail mass out here costs KL)
                q[i] = (edge_density / (1 + i - clip) as f64).max(1e-12);
            }
        }
        let qsum: f64 = q.iter().sum::<f64>().max(1e-12);
        let mut kl = 0.0;
        for (pi, qi) in hist.iter().zip(&q) {
            if *pi > 0.0 {
                let pn = pi / psum;
                let qn = (qi / qsum).max(1e-12);
                kl += pn * (pn / qn).ln();
            }
        }
        if kl < best_kl {
            best_kl = kl;
            best_bin = clip;
        }
    }
    let c = max_abs * best_bin as f32 / bins as f32;
    // respect the data's sign support (all-positive data keeps beta >= 0)
    (lo.max(-c), hi.min(c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn normal_with_outlier(n: usize, outlier: f32) -> Vec<f32> {
        let mut rng = Rng::new(0);
        let mut v: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        v.push(outlier);
        v
    }

    #[test]
    fn minmax_keeps_outlier() {
        let v = normal_with_outlier(1000, 500.0);
        let (lo, hi) = Observer::MinMax.range(&v, 8).unwrap();
        assert_eq!(hi, 500.0);
        assert!(lo < 0.0);
    }

    #[test]
    fn percentile_clips_outlier() {
        let v = normal_with_outlier(1000, 500.0);
        let (lo, hi) = Observer::Percentile { pct: 99.0 }.range(&v, 8).unwrap();
        assert!(hi < 10.0, "hi={hi}");
        assert!(lo > -10.0);
        assert!(lo < hi);
    }

    #[test]
    fn percentile_100_equals_minmax() {
        let v = normal_with_outlier(500, 42.0);
        let a = Observer::Percentile { pct: 100.0 }.range(&v, 8).unwrap();
        let b = Observer::MinMax.range(&v, 8).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn mse_search_beats_minmax_with_outliers() {
        // moderate outlier: clipping it pays off in aggregate MSE (a single
        // extreme outlier would dominate the MSE and min-max would win —
        // which is exactly the paper's point about clipping losing signal)
        let v = normal_with_outlier(2000, 20.0);
        let bits = 4;
        let (lo_m, hi_m) = Observer::MinMax.range(&v, bits).unwrap();
        let (lo_s, hi_s) = Observer::MseSearch { steps: 40 }.range(&v, bits).unwrap();
        let mse_m = quant_mse(&v, &QParams::from_range(lo_m, hi_m, bits));
        let mse_s = quant_mse(&v, &QParams::from_range(lo_s, hi_s, bits));
        assert!(mse_s < mse_m, "search {mse_s} vs minmax {mse_m}");
    }

    #[test]
    fn labels() {
        assert_eq!(Observer::MinMax.label(), "minmax");
        assert_eq!(Observer::Percentile { pct: 99.0 }.label(), "pct99");
        assert_eq!(Observer::Entropy { bins: 512 }.label(), "kl512");
    }

    #[test]
    fn entropy_clips_outlier_but_keeps_bulk() {
        let v = normal_with_outlier(4000, 100.0);
        let (lo, hi) = Observer::Entropy { bins: 512 }.range(&v, 4).unwrap();
        // the clip must land far below the outlier but cover the bulk
        assert!(hi < 50.0, "hi={hi}");
        assert!(hi > 2.0, "hi={hi}");
        assert!(lo < -2.0, "lo={lo}");
    }

    #[test]
    fn entropy_without_outliers_keeps_most_of_the_range() {
        let mut rng = Rng::new(3);
        let v: Vec<f32> = (0..4000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (lo, hi) = Observer::Entropy { bins: 512 }.range(&v, 8).unwrap();
        let (mlo, mhi) = Observer::MinMax.range(&v, 8).unwrap();
        assert!(hi >= mhi * 0.5, "hi {hi} vs minmax {mhi}");
        assert!(lo <= mlo * 0.5, "lo {lo} vs minmax {mlo}");
    }

    #[test]
    fn entropy_beats_minmax_on_bulk_reconstruction() {
        // KL calibration optimizes distribution fidelity: with an extreme
        // outlier it clips (sacrificing the outlier — the paper's §1
        // trade-off) and reconstructs the *bulk* far better than min-max
        let v = normal_with_outlier(4000, 200.0);
        let bits = 4;
        let (l1, h1) = Observer::MinMax.range(&v, bits).unwrap();
        let (l2, h2) = Observer::Entropy { bins: 512 }.range(&v, bits).unwrap();
        let bulk = &v[..4000]; // outlier excluded
        let m1 = quant_mse(bulk, &QParams::from_range(l1, h1, bits));
        let m2 = quant_mse(bulk, &QParams::from_range(l2, h2, bits));
        assert!(m2 < m1 * 0.25, "entropy bulk {m2} vs minmax bulk {m1}");
    }

    #[test]
    fn empty_calibration_data_is_a_deterministic_error() {
        for obs in [
            Observer::MinMax,
            Observer::Percentile { pct: 99.0 },
            Observer::MseSearch { steps: 10 },
            Observer::Entropy { bins: 128 },
        ] {
            let err = obs.range(&[], 8).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains("empty calibration data"), "{obs:?}: {msg}");
            assert!(msg.contains(&obs.label()), "{obs:?}: {msg}");
        }
    }

    #[test]
    fn non_finite_calibration_values_are_a_deterministic_error() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for obs in [
                Observer::MinMax,
                Observer::Percentile { pct: 99.0 },
                Observer::MseSearch { steps: 10 },
                Observer::Entropy { bins: 128 },
            ] {
                let mut v = normal_with_outlier(50, 3.0);
                v[17] = bad;
                let err = obs.range(&v, 8).unwrap_err();
                assert!(
                    err.to_string().contains("non-finite calibration value"),
                    "{obs:?} on {bad}: {err}"
                );
            }
        }
    }

    #[test]
    fn entropy_all_positive_data_keeps_positive_beta() {
        let mut rng = Rng::new(4);
        let v: Vec<f32> = (0..1000).map(|_| rng.f32() * 5.0 + 1.0).collect();
        let (lo, _hi) = Observer::Entropy { bins: 256 }.range(&v, 8).unwrap();
        assert!(lo >= 0.99, "lo={lo}");
    }

    #[test]
    fn clip_stats_counts_saturating_values() {
        let (c, lo, hi) = clip_stats(&[0.0, 0.5, -0.5, 1.0, -1.0], -1.0, 1.0);
        assert_eq!(c, 0, "range endpoints are representable, not clipped");
        assert_eq!((lo, hi), (-1.0, 1.0));
        let (c, lo, hi) = clip_stats(&[2.0, -3.0, 0.1], -1.0, 1.0);
        assert_eq!(c, 2);
        assert_eq!((lo, hi), (-3.0, 2.0));
        // NaN clips without poisoning the observed min/max
        let (c, lo, hi) = clip_stats(&[f32::NAN, 0.5], -1.0, 1.0);
        assert_eq!(c, 1);
        assert_eq!((lo, hi), (0.5, 0.5));
        // agrees with a per-value QParams saturation oracle
        let mut rng = Rng::new(9);
        let v: Vec<f32> = (0..500).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let (c, _, _) = clip_stats(&v, -1.0, 1.0);
        let oracle = v.iter().filter(|&&x| x < -1.0 || x > 1.0).count() as u64;
        assert_eq!(c, oracle);
    }
}
