//! Bit-packed quantized tensors with per-tensor, per-channel or per-cluster
//! (SplitQuant) scale layouts.

use crate::error::{Error, Result};
use crate::tensor::packing::Packed;
use crate::tensor::Tensor;

use super::qconfig::{Granularity, QConfig};
use super::scheme::QParams;

/// How quantization parameters map onto elements.
#[derive(Debug, Clone, PartialEq)]
pub enum QLayout {
    /// `params[0]` applies to every element.
    PerTensor,
    /// `params[c]` applies to slice `c` along `axis` (0 or trailing).
    PerChannel { axis: usize },
    /// SplitQuant: a 2-bit-packed cluster-id plane selects `params[cid]` per
    /// element — the fused form of the paper's three split layers.
    Split { cid: Packed },
}

/// A quantized tensor: packed codes + scale groups.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    shape: Vec<usize>,
    codes: Packed,
    params: Vec<QParams>,
    layout: QLayout,
}

impl QTensor {
    /// Quantize a dense tensor under `cfg` (PerTensor / PerChannel layouts;
    /// the Split layout is built by [`crate::splitquant`]).
    pub fn quantize(t: &Tensor, cfg: &QConfig) -> Result<QTensor> {
        match cfg.granularity {
            Granularity::PerTensor => {
                let (beta, alpha) = cfg.observer.range(t.data(), cfg.bits)?;
                let p = mk_params(beta, alpha, cfg);
                let codes: Vec<i8> = t.data().iter().map(|&v| p.quantize(v)).collect();
                Ok(QTensor {
                    shape: t.shape().to_vec(),
                    codes: Packed::pack(&codes, cfg.bits)?,
                    params: vec![p],
                    layout: QLayout::PerTensor,
                })
            }
            Granularity::PerChannel { axis } => {
                let (nch, get_ch) = channel_map(t.shape(), axis)?;
                let mut groups: Vec<Vec<f32>> = vec![Vec::new(); nch];
                for (i, &v) in t.data().iter().enumerate() {
                    groups[get_ch(i)].push(v);
                }
                let params: Vec<QParams> = groups
                    .iter()
                    .map(|g| {
                        let (beta, alpha) = cfg.observer.range(g, cfg.bits)?;
                        Ok(mk_params(beta, alpha, cfg))
                    })
                    .collect::<Result<_>>()?;
                let codes: Vec<i8> = t
                    .data()
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| params[get_ch(i)].quantize(v))
                    .collect();
                Ok(QTensor {
                    shape: t.shape().to_vec(),
                    codes: Packed::pack(&codes, cfg.bits)?,
                    params,
                    layout: QLayout::PerChannel { axis },
                })
            }
        }
    }

    /// Reconstruct a PerTensor / PerChannel tensor from raw parts
    /// (deserialization; validation mirrors `from_split`).
    pub fn from_parts(
        shape: &[usize],
        codes: Packed,
        params: Vec<QParams>,
        axis: Option<usize>,
    ) -> Result<QTensor> {
        let numel: usize = shape.iter().product();
        if codes.len() != numel {
            return Err(Error::Quant(format!(
                "from_parts: shape {shape:?} wants {numel} codes, got {}",
                codes.len()
            )));
        }
        let layout = match axis {
            None => {
                if params.len() != 1 {
                    return Err(Error::Quant(format!(
                        "per-tensor layout wants 1 param group, got {}",
                        params.len()
                    )));
                }
                QLayout::PerTensor
            }
            Some(a) => {
                let (nch, _) = channel_map(shape, a)?;
                if params.len() != nch {
                    return Err(Error::Quant(format!(
                        "per-channel axis {a} wants {nch} param groups, got {}",
                        params.len()
                    )));
                }
                QLayout::PerChannel { axis: a }
            }
        };
        Ok(QTensor { shape: shape.to_vec(), codes, params, layout })
    }

    /// Build a Split-layout tensor from precomputed codes/ids (SplitQuant).
    pub fn from_split(
        shape: &[usize],
        codes: Packed,
        cid: Packed,
        params: Vec<QParams>,
    ) -> Result<QTensor> {
        let numel: usize = shape.iter().product();
        if codes.len() != numel || cid.len() != numel {
            return Err(Error::Quant(format!(
                "split tensor: shape {shape:?} wants {numel} elements, codes {} cid {}",
                codes.len(),
                cid.len()
            )));
        }
        let k = params.len();
        if k == 0 || k > (1usize << cid.bits()) {
            return Err(Error::Quant(format!(
                "split tensor: {k} params do not fit {}-bit cluster ids",
                cid.bits()
            )));
        }
        Ok(QTensor { shape: shape.to_vec(), codes, params, layout: QLayout::Split { cid } })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn bits(&self) -> u8 {
        self.codes.bits()
    }

    pub fn params(&self) -> &[QParams] {
        &self.params
    }

    pub fn layout(&self) -> &QLayout {
        &self.layout
    }

    pub fn codes(&self) -> &Packed {
        &self.codes
    }

    /// Dequantize to a dense FP32 tensor.
    pub fn dequantize(&self) -> Tensor {
        let codes = self.codes.unpack();
        let data: Vec<f32> = match &self.layout {
            QLayout::PerTensor => {
                let p = self.params[0];
                codes.iter().map(|&q| p.dequantize(q)).collect()
            }
            QLayout::PerChannel { axis } => {
                let (_n, get_ch) = channel_map(&self.shape, *axis).expect("validated");
                codes
                    .iter()
                    .enumerate()
                    .map(|(i, &q)| self.params[get_ch(i)].dequantize(q))
                    .collect()
            }
            QLayout::Split { cid } => {
                let ids = cid.unpack_unsigned();
                codes
                    .iter()
                    .zip(&ids)
                    .map(|(&q, &c)| self.params[c as usize].dequantize(q))
                    .collect()
            }
        };
        Tensor::new(&self.shape, data).expect("shape consistent")
    }

    /// Unpacked `(codes, cid)` planes for the fused matmul path (`cid`
    /// empty for per-tensor layouts). Errors on non-rank-2 weights and on
    /// per-channel layouts, which the fused kernel does not support.
    /// Shared by [`QTensor::matmul_fused`] and the deployment executor's
    /// resident form ([`crate::model::qbert::QLinear`]).
    pub fn fused_planes(&self) -> Result<(Vec<i8>, Vec<u8>)> {
        if self.shape.len() != 2 {
            return Err(Error::Quant(format!(
                "fused matmul expects rank-2 weights, got {:?}",
                self.shape
            )));
        }
        let cid = match &self.layout {
            QLayout::Split { cid } => cid.unpack_unsigned(),
            QLayout::PerTensor => Vec::new(),
            QLayout::PerChannel { .. } => {
                return Err(Error::Quant(
                    "per-channel layout not supported on the fused matmul path".into(),
                ))
            }
        };
        Ok((self.codes.unpack(), cid))
    }

    /// `y = x @ dq(W)` without materializing the FP32 weight matrix:
    /// per-cluster tiles are dequantized on the fly inside the blocked
    /// matmul (see [`crate::parallel::kernels::split_matmul`]), under the
    /// process-wide micro-kernel choice
    /// ([`crate::parallel::kernel_kind`]). Unpacks the code/cid planes per
    /// call — deployment executors that call this in a loop should hold
    /// the unpacked form instead (see [`crate::model::qbert::QLinear`]).
    pub fn matmul_fused(&self, x: &Tensor) -> Result<Tensor> {
        self.matmul_fused_with(x, crate::parallel::kernel_kind())
    }

    /// [`QTensor::matmul_fused`] with an explicit micro-kernel choice —
    /// the engines are bit-identical, so this only matters for benches and
    /// engine-agreement tests.
    pub fn matmul_fused_with(
        &self,
        x: &Tensor,
        kind: crate::parallel::KernelKind,
    ) -> Result<Tensor> {
        if x.shape().len() != 2 || x.shape()[1] != self.shape[0] {
            return Err(Error::Quant(format!(
                "matmul_fused: activations {:?} do not match weights {:?}",
                x.shape(),
                self.shape
            )));
        }
        let (codes, cid) = self.fused_planes()?;
        Ok(crate::parallel::kernels::split_matmul_with(
            x,
            &self.shape,
            &codes,
            &cid,
            &self.params,
            kind,
        ))
    }

    /// Total storage bytes: packed codes + cluster-id plane + scale metadata.
    /// This is the paper-§6 model-size accounting.
    pub fn byte_size(&self) -> usize {
        let meta = self.params.len() * std::mem::size_of::<QParams>();
        let cid = match &self.layout {
            QLayout::Split { cid } => cid.byte_size(),
            _ => 0,
        };
        self.codes.byte_size() + cid + meta
    }

    /// Number of quantized elements.
    pub fn numel(&self) -> usize {
        self.codes.len()
    }
}

/// Fake-quantize a tensor (quantize + dequantize) under `cfg`.
pub fn fake_quant_tensor(t: &Tensor, cfg: &QConfig) -> Result<Tensor> {
    Ok(QTensor::quantize(t, cfg)?.dequantize())
}

fn mk_params(beta: f32, alpha: f32, cfg: &QConfig) -> QParams {
    if cfg.symmetric {
        QParams::symmetric_from_range(beta, alpha, cfg.bits)
    } else {
        QParams::from_range(beta, alpha, cfg.bits)
    }
}

/// (channel count, flat-index → channel) for `axis` = 0 or trailing.
fn channel_map(shape: &[usize], axis: usize) -> Result<(usize, impl Fn(usize) -> usize)> {
    let rank = shape.len();
    if axis != 0 && axis != rank - 1 {
        return Err(Error::Quant(format!(
            "per-channel axis {axis} unsupported for rank-{rank} tensor (use 0 or last)"
        )));
    }
    let nch = shape[axis];
    let inner: usize = if axis == 0 { shape[1..].iter().product() } else { 1 };
    let last = *shape.last().unwrap();
    let is_leading = axis == 0;
    Ok((nch, move |i: usize| if is_leading { i / inner } else { i % last }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn per_tensor_roundtrip_error_bounded() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[32, 16], 0.0, 1.0, &mut rng);
        let cfg = QConfig::baseline(8);
        let q = QTensor::quantize(&t, &cfg).unwrap();
        let d = q.dequantize();
        let step = q.params()[0].step();
        assert!(t.max_abs_diff(&d) <= step * 0.51, "err {}", t.max_abs_diff(&d));
    }

    #[test]
    fn per_channel_beats_per_tensor_on_heterogeneous_channels() {
        // channel 0 tiny values, channel 1 huge: per-channel must reconstruct
        // the tiny channel far better
        let mut data = Vec::new();
        for i in 0..64 {
            data.push(0.01 * (i as f32 / 64.0 - 0.5)); // col 0
            data.push(100.0 * (i as f32 / 64.0 - 0.5)); // col 1
        }
        let t = Tensor::new(&[64, 2], data).unwrap();
        let pt = fake_quant_tensor(&t, &QConfig::baseline(4)).unwrap();
        let pc = fake_quant_tensor(&t, &QConfig::per_channel(4, 1)).unwrap();
        let err = |a: &Tensor| -> f64 {
            a.data()
                .iter()
                .zip(t.data())
                .step_by(2) // only the tiny channel
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum()
        };
        assert!(err(&pc) < err(&pt) * 1e-2, "pc {} pt {}", err(&pc), err(&pt));
    }

    #[test]
    fn per_channel_axis0() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.2, 0.3, 100.0, 200.0, 300.0]).unwrap();
        let q = QTensor::quantize(&t, &QConfig::per_channel(8, 0)).unwrap();
        assert_eq!(q.params().len(), 2);
        let d = q.dequantize();
        assert!(t.max_abs_diff(&d) < 2.0);
        // row 0 reconstructed finely
        assert!((d.at2(0, 0) - 0.1).abs() < 0.01);
    }

    #[test]
    fn byte_size_accounting() {
        let t = Tensor::zeros(&[1000]);
        let q2 = QTensor::quantize(&t, &QConfig::baseline(2)).unwrap();
        assert_eq!(q2.byte_size(), 250 + std::mem::size_of::<QParams>());
    }

    #[test]
    fn split_layout_roundtrip() {
        // two clusters with very different scales
        let values = vec![0.001f32, 0.002, -0.003, 500.0, 600.0, 700.0];
        let ids = vec![0i8, 0, 0, 1, 1, 1];
        let p0 = QParams::from_range(-0.003, 0.002, 4);
        let p1 = QParams::from_range(0.0, 700.0, 4);
        let codes: Vec<i8> = values
            .iter()
            .zip(&ids)
            .map(|(&v, &c)| if c == 0 { p0.quantize(v) } else { p1.quantize(v) })
            .collect();
        let ids_u: Vec<u8> = ids.iter().map(|&i| i as u8).collect();
        let q = QTensor::from_split(
            &[6],
            Packed::pack(&codes, 4).unwrap(),
            Packed::pack_unsigned(&ids_u, 2).unwrap(),
            vec![p0, p1],
        )
        .unwrap();
        let d = q.dequantize();
        for (got, want) in d.data().iter().zip(&values) {
            let tol = if *want > 1.0 { 50.0 } else { 0.001 };
            assert!((got - want).abs() < tol, "{got} vs {want}");
        }
    }

    #[test]
    fn fused_matmul_matches_dequantized_matmul() {
        let mut rng = Rng::new(9);
        for cfg in [QConfig::baseline(4), QConfig::baseline(8)] {
            let w = Tensor::randn(&[24, 10], 0.0, 0.5, &mut rng);
            let q = QTensor::quantize(&w, &cfg).unwrap();
            let x = Tensor::randn(&[5, 24], 0.0, 1.0, &mut rng);
            let fused = q.matmul_fused(&x).unwrap();
            let reference = crate::tensor::ops::matmul_serial(&x, &q.dequantize());
            let gap = fused.max_abs_diff(&reference);
            assert!(gap < 1e-4, "fused gap {gap}");
        }
    }

    #[test]
    fn fused_matmul_rejects_per_channel() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.2, 0.3, 100.0, 200.0, 300.0]).unwrap();
        let q = QTensor::quantize(&t, &QConfig::per_channel(8, 0)).unwrap();
        let x = Tensor::ones(&[1, 2]);
        assert!(q.matmul_fused(&x).is_err());
    }

    #[test]
    fn split_rejects_mismatched_sizes() {
        let codes = Packed::pack(&[0, 0], 2).unwrap();
        let cid = Packed::pack(&[0, 0, 0], 2).unwrap();
        assert!(QTensor::from_split(&[2], codes, cid, vec![]).is_err());
    }

    #[test]
    fn property_dequant_within_representable_range() {
        check("dequant stays in dequant_range", 40, |rng| {
            let n = rng.range(1, 200);
            let vals = crate::util::proptest::gen_values_with_outliers(rng, n, 0.05);
            let t = Tensor::new(&[n], vals).unwrap();
            let bits = [2u8, 4, 8][rng.below(3)];
            let q = QTensor::quantize(&t, &QConfig::baseline(bits)).unwrap();
            let (lo, hi) = q.params()[0].dequant_range();
            let d = q.dequantize();
            for &v in d.data() {
                assert!(v >= lo - 1e-4 && v <= hi + 1e-4, "{v} outside [{lo},{hi}]");
            }
        });
    }

    #[test]
    fn property_idempotent() {
        check("fake quant is a projection", 30, |rng| {
            let n = rng.range(1, 150);
            let vals = crate::util::proptest::gen_values_with_outliers(rng, n, 0.1);
            let t = Tensor::new(&[n], vals).unwrap();
            let cfg = QConfig::baseline([2u8, 4, 8][rng.below(3)]);
            let once = fake_quant_tensor(&t, &cfg).unwrap();
            // re-observe on the quantized values: range shrinks to the used
            // codes, but quantizing with the ORIGINAL params must be stable
            let q = QTensor::quantize(&t, &cfg).unwrap();
            let p = q.params()[0];
            let twice: Vec<f32> = once.data().iter().map(|&v| p.fake(v)).collect();
            for (a, b) in once.data().iter().zip(&twice) {
                assert!((a - b).abs() < 1e-5);
            }
        });
    }
}
