//! Packed quantized-model serialization — the deployment artifact.
//!
//! `ParamStore::save` persists FP32 checkpoints; this module persists the
//! *quantized* model (packed codes, cluster-id planes, per-group parameters
//! and the FP32 remainder) so a server can boot directly into the
//! [`crate::model::QuantizedBert`] deployment path without re-running
//! k-means. The format is versioned little-endian binary:
//!
//! ```text
//! magic "SQQM0001"
//! u8    bits
//! u32   n_quantized
//!   per tensor: name, shape, layout tag (+axis / +cid plane), params, codes
//! u32   n_fp32
//!   per tensor: name, shape, f32 data        (LN, position, …)
//! ```

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::model::params::ParamStore;
use crate::splitquant::QuantizedModel;
use crate::tensor::packing::Packed;
use crate::tensor::Tensor;

use super::qtensor::{QLayout, QTensor};
use super::scheme::QParams;

const MAGIC: &[u8; 8] = b"SQQM0001";

/// A quantized model plus its FP32 remainder — everything needed to serve.
#[derive(Debug, Clone)]
pub struct PackedModel {
    pub qmodel: QuantizedModel,
    /// non-quantized parameters in their original order subset
    pub fp32: Vec<(String, Tensor)>,
}

impl PackedModel {
    /// Assemble from a full store + quantization result.
    pub fn assemble(store: &ParamStore, qmodel: &QuantizedModel) -> PackedModel {
        let fp32 = store
            .iter()
            .filter(|(n, _)| !qmodel.tensors.contains_key(*n))
            .map(|(n, t)| (n.to_string(), t.clone()))
            .collect();
        PackedModel { qmodel: qmodel.clone(), fp32 }
    }

    /// Reconstruct a full FP32 [`ParamStore`] following `order` (evaluation /
    /// fallback path; the deployment path feeds `qmodel` to `QuantizedBert`).
    pub fn to_store(&self, order: &[(String, Vec<usize>)]) -> Result<ParamStore> {
        let mut store = ParamStore::zeros(order);
        for (name, t) in &self.fp32 {
            store.set(name, t.clone())?;
        }
        for (name, q) in &self.qmodel.tensors {
            store.set(name, q.dequantize())?;
        }
        Ok(store)
    }

    /// Total serialized size (quantized + fp32 payloads, without framing).
    pub fn payload_bytes(&self) -> usize {
        self.qmodel.quantized_bytes()
            + self.fp32.iter().map(|(_, t)| t.byte_size()).sum::<usize>()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&[self.qmodel.bits])?;

        f.write_all(&(self.qmodel.tensors.len() as u32).to_le_bytes())?;
        for (name, q) in &self.qmodel.tensors {
            write_str(&mut f, name)?;
            write_shape(&mut f, q.shape())?;
            match q.layout() {
                QLayout::PerTensor => {
                    f.write_all(&[0u8])?;
                }
                QLayout::PerChannel { axis } => {
                    f.write_all(&[1u8])?;
                    f.write_all(&(*axis as u32).to_le_bytes())?;
                }
                QLayout::Split { cid } => {
                    f.write_all(&[2u8])?;
                    write_packed(&mut f, cid)?;
                }
            }
            f.write_all(&(q.params().len() as u32).to_le_bytes())?;
            for p in q.params() {
                f.write_all(&p.scale.to_le_bytes())?;
                f.write_all(&p.zp.to_le_bytes())?;
                f.write_all(&[p.bits])?;
            }
            write_packed(&mut f, q.codes())?;
        }

        f.write_all(&(self.fp32.len() as u32).to_le_bytes())?;
        for (name, t) in &self.fp32 {
            write_str(&mut f, name)?;
            write_shape(&mut f, t.shape())?;
            for &v in t.data() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PackedModel> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Checkpoint(format!("{path:?}: bad magic {magic:?}")));
        }
        let bits = read_u8(&mut f)?;

        let nq = read_u32(&mut f)? as usize;
        let mut tensors = std::collections::BTreeMap::new();
        for _ in 0..nq {
            let name = read_str(&mut f)?;
            let shape = read_shape(&mut f)?;
            let layout_tag = read_u8(&mut f)?;
            let (layout_axis, cid) = match layout_tag {
                0 => (None, None),
                1 => (Some(read_u32(&mut f)? as usize), None),
                2 => (None, Some(read_packed(&mut f)?)),
                t => return Err(Error::Checkpoint(format!("bad layout tag {t}"))),
            };
            let nparams = read_u32(&mut f)? as usize;
            let mut params = Vec::with_capacity(nparams);
            for _ in 0..nparams {
                let scale = read_f32(&mut f)?;
                let zp = read_f32(&mut f)?;
                let b = read_u8(&mut f)?;
                params.push(QParams { scale, zp, bits: b });
            }
            let codes = read_packed(&mut f)?;
            let q = match (layout_axis, cid) {
                (None, Some(cid)) => QTensor::from_split(&shape, codes, cid, params)?,
                (axis, None) => {
                    QTensor::from_parts(&shape, codes, params, axis)?
                }
                _ => unreachable!(),
            };
            tensors.insert(name, q);
        }

        let nf = read_u32(&mut f)? as usize;
        let mut fp32 = Vec::with_capacity(nf);
        for _ in 0..nf {
            let name = read_str(&mut f)?;
            let shape = read_shape(&mut f)?;
            let numel: usize = shape.iter().product();
            let mut buf = vec![0u8; numel * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            fp32.push((name, Tensor::new(&shape, data)?));
        }

        let fp32_names = fp32.iter().map(|(n, _)| n.clone()).collect();
        Ok(PackedModel { qmodel: QuantizedModel { tensors, fp32_names, bits }, fp32 })
    }
}

fn write_str(f: &mut impl Write, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u16).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(f: &mut impl Read) -> Result<String> {
    let n = read_u16(f)? as usize;
    let mut buf = vec![0u8; n];
    f.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|e| Error::Checkpoint(format!("bad name: {e}")))
}

fn write_shape(f: &mut impl Write, shape: &[usize]) -> Result<()> {
    f.write_all(&[shape.len() as u8])?;
    for &d in shape {
        f.write_all(&(d as u32).to_le_bytes())?;
    }
    Ok(())
}

fn read_shape(f: &mut impl Read) -> Result<Vec<usize>> {
    let n = read_u8(f)? as usize;
    (0..n).map(|_| Ok(read_u32(f)? as usize)).collect()
}

fn write_packed(f: &mut impl Write, p: &Packed) -> Result<()> {
    f.write_all(&[p.bits()])?;
    f.write_all(&(p.len() as u32).to_le_bytes())?;
    f.write_all(p.bytes())?;
    Ok(())
}

fn read_packed(f: &mut impl Read) -> Result<Packed> {
    let bits = read_u8(f)?;
    let len = read_u32(f)? as usize;
    let per_byte = 8 / bits.max(1) as usize;
    let mut buf = vec![0u8; len.div_ceil(per_byte)];
    f.read_exact(&mut buf)?;
    Packed::from_raw(bits, len, buf)
}

fn read_u8(f: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    f.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32(f: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;
    use crate::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
    use crate::util::rng::Rng;

    fn tiny() -> (BertConfig, ParamStore, QuantizedModel) {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, qm) = quantize_store(&store, &q, &SplitQuantConfig::new(2)).unwrap();
        (cfg, store, qm)
    }

    #[test]
    fn roundtrip_split_model() {
        let (cfg, store, qm) = tiny();
        let pm = PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_packed_model.sqq");
        pm.save(&path).unwrap();
        let loaded = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(loaded.qmodel.bits, 2);
        assert_eq!(loaded.qmodel.tensors.len(), qm.tensors.len());
        // dequantized stores identical
        let a = pm.to_store(&cfg.param_order()).unwrap();
        let b = loaded.to_store(&cfg.param_order()).unwrap();
        for (name, t) in a.iter() {
            assert_eq!(t.data(), b.get(name).unwrap().data(), "{name}");
        }
    }

    #[test]
    fn roundtrip_per_tensor_model() {
        let cfg = BertConfig {
            vocab_size: 32,
            hidden: 8,
            layers: 1,
            heads: 2,
            ffn: 16,
            max_len: 8,
            num_classes: 2,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(1);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, tensors) = crate::baselines::quantize_store_baseline(
            &store,
            &q,
            &crate::quant::QConfig::baseline(4),
        )
        .unwrap();
        let qm = QuantizedModel { tensors, fp32_names: vec![], bits: 4 };
        let pm = PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_packed_pt.sqq");
        pm.save(&path).unwrap();
        let loaded = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let a = pm.to_store(&cfg.param_order()).unwrap();
        let b = loaded.to_store(&cfg.param_order()).unwrap();
        for (name, t) in a.iter() {
            assert_eq!(t.data(), b.get(name).unwrap().data(), "{name}");
        }
    }

    #[test]
    fn packed_file_much_smaller_than_fp32_checkpoint() {
        let (_cfg, store, qm) = tiny();
        let pm = PackedModel::assemble(&store, &qm);
        let qpath = std::env::temp_dir().join("sq_size_q.sqq");
        let fpath = std::env::temp_dir().join("sq_size_f.bin");
        pm.save(&qpath).unwrap();
        store.save(&fpath).unwrap();
        let qsize = std::fs::metadata(&qpath).unwrap().len();
        let fsize = std::fs::metadata(&fpath).unwrap().len();
        std::fs::remove_file(&qpath).ok();
        std::fs::remove_file(&fpath).ok();
        // quantizable params dominate this model; INT2+cid ≈ 12.5 % of FP32
        assert!(
            (qsize as f64) < fsize as f64 * 0.45,
            "packed {qsize} vs fp32 {fsize}"
        );
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("sq_garbage.sqq");
        std::fs::write(&path, b"not a packed model").unwrap();
        assert!(PackedModel::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deployment_path_boots_from_packed_file() {
        // the full cycle: quantize → save → load → QuantizedBert serves
        let (cfg, store, qm) = tiny();
        let pm = PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_boot.sqq");
        pm.save(&path).unwrap();
        let loaded = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let base = loaded.to_store(&cfg.param_order()).unwrap();
        let qbert =
            crate::model::QuantizedBert::new(cfg.clone(), &base, &loaded.qmodel).unwrap();
        let mut rng = Rng::new(2);
        let ids = crate::tensor::IntTensor::new(
            &[2, cfg.max_len],
            (0..2 * cfg.max_len).map(|_| rng.below(cfg.vocab_size) as i32).collect(),
        )
        .unwrap();
        let mask = Tensor::full(&[2, cfg.max_len], 1.0);
        let logits = qbert.forward(&ids, &mask);
        assert_eq!(logits.shape(), &[2, cfg.num_classes]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }
}
