//! Packed quantized-model serialization — the deployment artifact.
//!
//! `ParamStore::save` persists FP32 checkpoints; this module persists the
//! *quantized* model (packed codes, cluster-id planes, per-group parameters
//! and the FP32 remainder) so a server can boot directly into the
//! [`crate::model::QuantizedBert`] deployment path without re-running
//! k-means. The format is versioned little-endian binary:
//!
//! ```text
//! magic "SQQM0001"
//! u8    bits
//! u32   n_quantized
//!   per tensor: name, shape, layout tag (+axis / +cid plane), params, codes
//! u32   n_fp32
//!   per tensor: name, shape, f32 data        (LN, position, …)
//! ```
//!
//! The per-tensor *record* encoding (everything after the name) is shared
//! with the sharded `SQSH0001` format ([`crate::shardstore`]), which adds a
//! per-tensor offset index in front so any single layer can be read without
//! touching the rest of the file. FP32 payloads go through
//! [`crate::util::io`] in one buffered read/write per tensor rather than
//! one syscall-sized `write_all` per element.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::model::params::ParamStore;
use crate::splitquant::QuantizedModel;
use crate::tensor::packing::Packed;
use crate::tensor::Tensor;
use crate::util::io::{read_f32, read_f32_vec, read_u16, read_u32, read_u8, write_f32_slice};

use super::qtensor::{QLayout, QTensor};
use super::scheme::QParams;

const MAGIC: &[u8; 8] = b"SQQM0001";

/// A quantized model plus its FP32 remainder — everything needed to serve.
#[derive(Debug, Clone)]
pub struct PackedModel {
    pub qmodel: QuantizedModel,
    /// non-quantized parameters in their original order subset
    pub fp32: Vec<(String, Tensor)>,
}

impl PackedModel {
    /// Assemble from a full store + quantization result.
    pub fn assemble(store: &ParamStore, qmodel: &QuantizedModel) -> PackedModel {
        let fp32 = store
            .iter()
            .filter(|(n, _)| !qmodel.tensors.contains_key(*n))
            .map(|(n, t)| (n.to_string(), t.clone()))
            .collect();
        PackedModel { qmodel: qmodel.clone(), fp32 }
    }

    /// Reconstruct a full FP32 [`ParamStore`] following `order` (evaluation /
    /// fallback path; the deployment path feeds `qmodel` to `QuantizedBert`).
    pub fn to_store(&self, order: &[(String, Vec<usize>)]) -> Result<ParamStore> {
        let mut store = ParamStore::zeros(order);
        for (name, t) in &self.fp32 {
            store.set(name, t.clone())?;
        }
        for (name, q) in &self.qmodel.tensors {
            store.set(name, q.dequantize())?;
        }
        Ok(store)
    }

    /// Total serialized size (quantized + fp32 payloads, without framing).
    pub fn payload_bytes(&self) -> usize {
        self.qmodel.quantized_bytes()
            + self.fp32.iter().map(|(_, t)| t.byte_size()).sum::<usize>()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&[self.qmodel.bits])?;

        f.write_all(&(self.qmodel.tensors.len() as u32).to_le_bytes())?;
        for (name, q) in &self.qmodel.tensors {
            write_str(&mut f, name)?;
            write_qtensor_record(&mut f, q)?;
        }

        f.write_all(&(self.fp32.len() as u32).to_le_bytes())?;
        for (name, t) in &self.fp32 {
            write_str(&mut f, name)?;
            write_fp32_record(&mut f, t)?;
        }
        Ok(())
    }

    /// Save in the sharded `SQSH0001` format (per-tensor offset index, so a
    /// [`crate::shardstore::PagedModel`] can fault layers in independently).
    pub fn save_sharded(&self, path: &Path) -> Result<()> {
        crate::shardstore::write_sharded(self, path)
    }

    pub fn load(path: &Path) -> Result<PackedModel> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Checkpoint(format!("{path:?}: bad magic {magic:?}")));
        }
        let bits = read_u8(&mut f)?;

        let nq = read_u32(&mut f)? as usize;
        let mut tensors = std::collections::BTreeMap::new();
        for _ in 0..nq {
            let name = read_str(&mut f)?;
            tensors.insert(name, read_qtensor_record(&mut f)?);
        }

        let nf = read_u32(&mut f)? as usize;
        let mut fp32 = Vec::with_capacity(nf);
        for _ in 0..nf {
            let name = read_str(&mut f)?;
            fp32.push((name, read_fp32_record(&mut f)?));
        }

        let fp32_names = fp32.iter().map(|(n, _)| n.clone()).collect();
        Ok(PackedModel { qmodel: QuantizedModel { tensors, fp32_names, bits }, fp32 })
    }
}

/// Write one quantized tensor record: shape, layout tag (+axis / +cid
/// plane), params, codes. Everything after the tensor's name in `SQQM0001`;
/// the unit of independent access in `SQSH0001`.
pub(crate) fn write_qtensor_record(f: &mut impl Write, q: &QTensor) -> Result<()> {
    write_shape(f, q.shape())?;
    match q.layout() {
        QLayout::PerTensor => {
            f.write_all(&[0u8])?;
        }
        QLayout::PerChannel { axis } => {
            f.write_all(&[1u8])?;
            f.write_all(&(*axis as u32).to_le_bytes())?;
        }
        QLayout::Split { cid } => {
            f.write_all(&[2u8])?;
            write_packed(f, cid)?;
        }
    }
    f.write_all(&(q.params().len() as u32).to_le_bytes())?;
    for p in q.params() {
        f.write_all(&p.scale.to_le_bytes())?;
        f.write_all(&p.zp.to_le_bytes())?;
        f.write_all(&[p.bits])?;
    }
    write_packed(f, q.codes())
}

/// Inverse of [`write_qtensor_record`] (validation happens in
/// `QTensor::from_parts` / `from_split`).
pub(crate) fn read_qtensor_record(f: &mut impl Read) -> Result<QTensor> {
    let shape = read_shape(f)?;
    let layout_tag = read_u8(f)?;
    let (layout_axis, cid) = match layout_tag {
        0 => (None, None),
        1 => (Some(read_u32(f)? as usize), None),
        2 => (None, Some(read_packed(f)?)),
        t => return Err(Error::Checkpoint(format!("bad layout tag {t}"))),
    };
    let nparams = read_u32(f)? as usize;
    let mut params = Vec::with_capacity(nparams);
    for _ in 0..nparams {
        let scale = read_f32(f)?;
        let zp = read_f32(f)?;
        let b = read_u8(f)?;
        params.push(QParams { scale, zp, bits: b });
    }
    let codes = read_packed(f)?;
    match (layout_axis, cid) {
        (None, Some(cid)) => QTensor::from_split(&shape, codes, cid, params),
        (axis, None) => QTensor::from_parts(&shape, codes, params, axis),
        _ => unreachable!(),
    }
}

/// Write one FP32 tensor record: shape + raw little-endian payload.
pub(crate) fn write_fp32_record(f: &mut impl Write, t: &Tensor) -> Result<()> {
    write_shape(f, t.shape())?;
    write_f32_slice(f, t.data())
}

pub(crate) fn read_fp32_record(f: &mut impl Read) -> Result<Tensor> {
    let shape = read_shape(f)?;
    let numel: usize = shape.iter().product();
    let data = read_f32_vec(f, numel)?;
    Tensor::new(&shape, data)
}

pub(crate) fn write_str(f: &mut impl Write, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u16).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

pub(crate) fn read_str(f: &mut impl Read) -> Result<String> {
    let n = read_u16(f)? as usize;
    let mut buf = vec![0u8; n];
    f.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|e| Error::Checkpoint(format!("bad name: {e}")))
}

fn write_shape(f: &mut impl Write, shape: &[usize]) -> Result<()> {
    f.write_all(&[shape.len() as u8])?;
    for &d in shape {
        f.write_all(&(d as u32).to_le_bytes())?;
    }
    Ok(())
}

fn read_shape(f: &mut impl Read) -> Result<Vec<usize>> {
    let n = read_u8(f)? as usize;
    (0..n).map(|_| Ok(read_u32(f)? as usize)).collect()
}

fn write_packed(f: &mut impl Write, p: &Packed) -> Result<()> {
    f.write_all(&[p.bits()])?;
    f.write_all(&(p.len() as u32).to_le_bytes())?;
    f.write_all(p.bytes())?;
    Ok(())
}

fn read_packed(f: &mut impl Read) -> Result<Packed> {
    let bits = read_u8(f)?;
    let len = read_u32(f)? as usize;
    let per_byte = 8 / bits.max(1) as usize;
    let mut buf = vec![0u8; len.div_ceil(per_byte)];
    f.read_exact(&mut buf)?;
    Packed::from_raw(bits, len, buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;
    use crate::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
    use crate::util::rng::Rng;

    fn tiny() -> (BertConfig, ParamStore, QuantizedModel) {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, qm) = quantize_store(&store, &q, &SplitQuantConfig::new(2)).unwrap();
        (cfg, store, qm)
    }

    /// A hand-built model exercising all three [`QLayout`] variants plus an
    /// FP32 remainder tensor.
    fn all_layouts_model() -> PackedModel {
        use crate::quant::QConfig;
        let mut rng = Rng::new(11);
        let mut tensors = std::collections::BTreeMap::new();
        // PerTensor
        let t = Tensor::randn(&[6, 4], 0.0, 1.0, &mut rng);
        tensors.insert(
            "per_tensor.weight".to_string(),
            QTensor::quantize(&t, &QConfig::baseline(8)).unwrap(),
        );
        // PerChannel (axis 0)
        let t = Tensor::randn(&[3, 5], 0.0, 1.0, &mut rng);
        tensors.insert(
            "per_channel.weight".to_string(),
            QTensor::quantize(&t, &QConfig::per_channel(4, 0)).unwrap(),
        );
        // Split
        let values = [0.001f32, 0.002, -0.003, 500.0, 600.0, 700.0];
        let ids: Vec<u8> = vec![0, 0, 0, 1, 1, 1];
        let p0 = QParams::from_range(-0.003, 0.002, 4);
        let p1 = QParams::from_range(0.0, 700.0, 4);
        let codes: Vec<i8> = values
            .iter()
            .zip(&ids)
            .map(|(&v, &c)| if c == 0 { p0.quantize(v) } else { p1.quantize(v) })
            .collect();
        tensors.insert(
            "split.weight".to_string(),
            QTensor::from_split(
                &[6],
                Packed::pack(&codes, 4).unwrap(),
                Packed::pack_unsigned(&ids, 2).unwrap(),
                vec![p0, p1],
            )
            .unwrap(),
        );
        let fp32 = vec![(
            "remainder.gamma".to_string(),
            Tensor::randn(&[7], 0.0, 1.0, &mut rng),
        )];
        let fp32_names = vec!["remainder.gamma".to_string()];
        PackedModel { qmodel: QuantizedModel { tensors, fp32_names, bits: 4 }, fp32 }
    }

    #[test]
    fn roundtrip_split_model() {
        let (cfg, store, qm) = tiny();
        let pm = PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_packed_model.sqq");
        pm.save(&path).unwrap();
        let loaded = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(loaded.qmodel.bits, 2);
        assert_eq!(loaded.qmodel.tensors.len(), qm.tensors.len());
        // dequantized stores identical
        let a = pm.to_store(&cfg.param_order()).unwrap();
        let b = loaded.to_store(&cfg.param_order()).unwrap();
        for (name, t) in a.iter() {
            assert_eq!(t.data(), b.get(name).unwrap().data(), "{name}");
        }
    }

    #[test]
    fn roundtrip_per_tensor_model() {
        let cfg = BertConfig {
            vocab_size: 32,
            hidden: 8,
            layers: 1,
            heads: 2,
            ffn: 16,
            max_len: 8,
            num_classes: 2,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(1);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, tensors) = crate::baselines::quantize_store_baseline(
            &store,
            &q,
            &crate::quant::QConfig::baseline(4),
        )
        .unwrap();
        let qm = QuantizedModel { tensors, fp32_names: vec![], bits: 4 };
        let pm = PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_packed_pt.sqq");
        pm.save(&path).unwrap();
        let loaded = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let a = pm.to_store(&cfg.param_order()).unwrap();
        let b = loaded.to_store(&cfg.param_order()).unwrap();
        for (name, t) in a.iter() {
            assert_eq!(t.data(), b.get(name).unwrap().data(), "{name}");
        }
    }

    #[test]
    fn roundtrip_byte_identity_all_layouts() {
        // save → load → save again must produce byte-identical files for
        // every QLayout variant, and the loaded tensors must compare equal
        let pm = all_layouts_model();
        let p1 = std::env::temp_dir().join("sq_rt_layouts_1.sqq");
        let p2 = std::env::temp_dir().join("sq_rt_layouts_2.sqq");
        pm.save(&p1).unwrap();
        let loaded = PackedModel::load(&p1).unwrap();
        loaded.save(&p2).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        let b2 = std::fs::read(&p2).unwrap();
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
        assert_eq!(b1, b2, "save→load→save is not byte-stable");

        for (name, q) in &pm.qmodel.tensors {
            assert_eq!(loaded.qmodel.tensors[name], *q, "{name}");
        }
        for ((n1, t1), (n2, t2)) in pm.fp32.iter().zip(&loaded.fp32) {
            assert_eq!(n1, n2);
            let same = t1
                .data()
                .iter()
                .zip(t2.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{n1} fp32 payload not bit-identical");
        }
    }

    #[test]
    fn roundtrip_byte_identity_mixed_precision() {
        // a BitPlan-style artifact (different layers at different widths)
        // must reload byte-identically with per-layer bit metadata intact
        use crate::quant::pipeline::{QuantPipeline, SplitQuantPass};
        let (_, store, _) = tiny();
        let artifact = QuantPipeline::new()
            .pass(
                SplitQuantPass::bits(2)
                    .layer_bits("classifier.weight", 8)
                    .layer_bits("classifier.bias", 8)
                    .layer_bits("pooler.weight", 4),
            )
            .run(&store)
            .unwrap();
        let pm = PackedModel::assemble(&store, &artifact.quantized_model());

        let p1 = std::env::temp_dir().join("sq_rt_mixed_1.sqq");
        let p2 = std::env::temp_dir().join("sq_rt_mixed_2.sqq");
        pm.save(&p1).unwrap();
        let loaded = PackedModel::load(&p1).unwrap();
        loaded.save(&p2).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        let b2 = std::fs::read(&p2).unwrap();
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
        assert_eq!(b1, b2, "mixed-precision save→load→save is not byte-stable");

        // every per-layer width (and each param group's bits) survived
        assert_eq!(loaded.qmodel.tensors["classifier.weight"].bits(), 8);
        assert_eq!(loaded.qmodel.tensors["classifier.bias"].bits(), 8);
        assert_eq!(loaded.qmodel.tensors["pooler.weight"].bits(), 4);
        assert_eq!(loaded.qmodel.tensors["encoder.0.attn.q.weight"].bits(), 2);
        for (name, q) in &pm.qmodel.tensors {
            let l = &loaded.qmodel.tensors[name];
            assert_eq!(l, q, "{name}");
            assert!(l.params().iter().all(|p| p.bits == q.params()[0].bits), "{name}");
        }
    }

    #[test]
    fn truncated_files_error() {
        let pm = all_layouts_model();
        let full = std::env::temp_dir().join("sq_trunc_full.sqq");
        pm.save(&full).unwrap();
        let bytes = std::fs::read(&full).unwrap();
        std::fs::remove_file(&full).ok();

        let cut = std::env::temp_dir().join("sq_trunc_cut.sqq");
        // cut at a spread of prefixes, including one byte short of valid
        let mut cuts: Vec<usize> = (0..16).map(|i| i * bytes.len() / 16).collect();
        cuts.push(bytes.len() - 1);
        for n in cuts {
            std::fs::write(&cut, &bytes[..n]).unwrap();
            assert!(
                PackedModel::load(&cut).is_err(),
                "load succeeded on a {n}-byte truncation of a {}-byte file",
                bytes.len()
            );
        }
        std::fs::remove_file(&cut).ok();
    }

    #[test]
    fn bad_magic_and_bad_layout_tag_rejected() {
        let path = std::env::temp_dir().join("sq_bad_tag.sqq");
        // wrong magic
        std::fs::write(&path, b"SQXX9999............").unwrap();
        assert!(PackedModel::load(&path).is_err());
        // right magic, bogus layout tag (7) on the first tensor
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.push(4); // bits
        buf.extend_from_slice(&1u32.to_le_bytes()); // one quantized tensor
        write_str(&mut buf, "w").unwrap();
        buf.push(1); // rank 1
        buf.extend_from_slice(&2u32.to_le_bytes()); // shape [2]
        buf.push(7); // invalid layout tag
        std::fs::write(&path, &buf).unwrap();
        let err = PackedModel::load(&path).unwrap_err();
        assert!(
            err.to_string().contains("bad layout tag"),
            "unexpected error: {err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn packed_file_much_smaller_than_fp32_checkpoint() {
        let (_cfg, store, qm) = tiny();
        let pm = PackedModel::assemble(&store, &qm);
        let qpath = std::env::temp_dir().join("sq_size_q.sqq");
        let fpath = std::env::temp_dir().join("sq_size_f.bin");
        pm.save(&qpath).unwrap();
        store.save(&fpath).unwrap();
        let qsize = std::fs::metadata(&qpath).unwrap().len();
        let fsize = std::fs::metadata(&fpath).unwrap().len();
        std::fs::remove_file(&qpath).ok();
        std::fs::remove_file(&fpath).ok();
        // quantizable params dominate this model; INT2+cid ≈ 12.5 % of FP32
        assert!(
            (qsize as f64) < fsize as f64 * 0.45,
            "packed {qsize} vs fp32 {fsize}"
        );
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("sq_garbage.sqq");
        std::fs::write(&path, b"not a packed model").unwrap();
        assert!(PackedModel::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deployment_path_boots_from_packed_file() {
        // the full cycle: quantize → save → load → QuantizedBert serves
        let (cfg, store, qm) = tiny();
        let pm = PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_boot.sqq");
        pm.save(&path).unwrap();
        let loaded = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let base = loaded.to_store(&cfg.param_order()).unwrap();
        let qbert =
            crate::model::QuantizedBert::new(cfg.clone(), &base, &loaded.qmodel).unwrap();
        let mut rng = Rng::new(2);
        let ids = crate::tensor::IntTensor::new(
            &[2, cfg.max_len],
            (0..2 * cfg.max_len).map(|_| rng.below(cfg.vocab_size) as i32).collect(),
        )
        .unwrap();
        let mask = Tensor::full(&[2, cfg.max_len], 1.0);
        let logits = qbert.forward(&ids, &mask).unwrap();
        assert_eq!(logits.shape(), &[2, cfg.num_classes]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }
}
