//! Post-training-quantization engine: affine quantization (paper §3,
//! Eq. 1–3), range observers (min-max / percentile clipping / MSE search),
//! per-tensor & per-channel granularity, and bit-packed quantized tensors.
//!
//! SplitQuant itself (in [`crate::splitquant`]) is a *model reshaping* pass
//! that feeds this engine narrower ranges; the engine is deliberately
//! independent so baselines and SplitQuant share the identical quantizer —
//! the same property the paper relies on for its comparison.

pub mod observer;
pub mod pipeline;
pub mod qconfig;
pub mod qtensor;
pub mod scheme;
pub mod serialize;

pub use observer::Observer;
pub use pipeline::{
    ActCalibratePass, ActQuantizePass, BaselinePass, BnFold, BnFoldWith, ModelArtifact,
    OcsPass, QuantPass, QuantPipeline, SplitQuantPass,
};
pub use qconfig::{Granularity, QConfig};
pub use qtensor::{QLayout, QTensor};
pub use scheme::{qrange, QParams};
pub use serialize::PackedModel;
