//! Composable quantization pass pipeline over shared-memory model artifacts.
//!
//! The paper frames SplitQuant as a *preprocessing* step: "preprocess DNNs
//! with SplitQuant, then any quantization algorithm benefits". This module
//! makes that framing literal. Every model transformation — BatchNorm
//! folding (§4.1), the SplitQuant weight/bias split, activation calibration
//! (§4.2), the per-tensor baseline quantizer and the OCS related-work
//! baseline — is a [`QuantPass`] applied to one [`ModelArtifact`], and a
//! [`QuantPipeline`] chains them:
//!
//! ```ignore
//! use splitquant::quant::pipeline::{BnFold, QuantPipeline, SplitQuantPass};
//! let artifact = QuantPipeline::new()
//!     .pass(BnFold)                       // fold BN stats (no-op on BERT)
//!     .pass(SplitQuantPass::bits(2)       // paper defaults: k = 3, k-means++
//!         .layer_bits("classifier.weight", 8))  // mixed precision per layer
//!     .run(&store)?;
//! let (eval, qmodel) = artifact.into_parts();
//! ```
//!
//! The artifact's eval view starts as an O(1) [`ParamStore::share`] of the
//! source store, so a pipeline never deep-copies the model: passes
//! copy-on-write only the tensors they actually rewrite, and untouched
//! parameters (LayerNorm, position embeddings, …) stay pointer-shared with
//! the source (asserted in `tests/integration_share`).
//!
//! The legacy entry points ([`crate::splitquant::quantize_store`],
//! [`crate::baselines::quantize_store_baseline`],
//! [`crate::baselines::ocs::quantize_store_ocs`]) are thin wrappers over
//! single-pass pipelines, so both routes produce byte-identical artifacts.

use std::collections::{BTreeMap, HashSet};

use crate::baselines::ocs::ocs_fake_quant;
use crate::error::Result;
use crate::model::config::BertConfig;
use crate::model::params::ParamStore;
use crate::splitquant::bn_fold::fold_bn;
use crate::splitquant::{
    default_quantizable, params_from_samples, split_quantize, split_quantize_pair,
    ActCalibrator, ActQuantMode, ActQuantParams, QuantizedModel, SplitQuantConfig,
};
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

use super::observer::Observer;
use super::qconfig::QConfig;
use super::qtensor::QTensor;

/// The unified model artifact a [`QuantPipeline`] threads through its
/// passes: an evaluation view (fake-quant FP32 weights, copy-on-write shared
/// with the source store), the packed quantized tensors, optional calibrated
/// activation parameters, and the provenance of every applied pass.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    /// Dequantized (fake-quant) weights for accuracy evaluation through any
    /// executor. Starts as an O(1) [`ParamStore::share`] of the source
    /// store; passes copy-on-write only the tensors they touch.
    pub eval: ParamStore,
    /// Packed tensors produced by quantization passes, by parameter name.
    pub tensors: BTreeMap<String, QTensor>,
    /// Calibrated activation parameters ([`ActCalibratePass`]).
    pub act_params: Option<ActQuantParams>,
    /// Names of the applied passes, in order.
    pub provenance: Vec<String>,
    /// Default bit-width recorded by the last quantization pass (32 when no
    /// pass packed a tensor). Per-layer overrides may use other widths —
    /// each [`QTensor`] carries its own.
    pub bits: u8,
}

impl ModelArtifact {
    /// Start an artifact over `store` without copying any tensor.
    pub fn new(store: &ParamStore) -> ModelArtifact {
        ModelArtifact {
            eval: store.share(),
            tensors: BTreeMap::new(),
            act_params: None,
            provenance: Vec::new(),
            bits: 32,
        }
    }

    /// Parameter names still carried in FP32 (not packed by any pass).
    pub fn fp32_names(&self) -> Vec<String> {
        self.eval
            .names()
            .iter()
            .filter(|n| !self.tensors.contains_key(*n))
            .cloned()
            .collect()
    }

    /// Packed [`QuantizedModel`] view (paper-§6 size accounting form).
    pub fn quantized_model(&self) -> QuantizedModel {
        QuantizedModel {
            tensors: self.tensors.clone(),
            fp32_names: self.fp32_names(),
            bits: self.bits,
        }
    }

    /// Decompose into the legacy `(eval_store, qmodel)` pair.
    pub fn into_parts(self) -> (ParamStore, QuantizedModel) {
        let fp32_names = self.fp32_names();
        let qmodel = QuantizedModel { tensors: self.tensors, fp32_names, bits: self.bits };
        (self.eval, qmodel)
    }
}

/// One composable step of a [`QuantPipeline`].
pub trait QuantPass {
    /// Short pass label recorded in [`ModelArtifact::provenance`].
    fn name(&self) -> String;
    /// Apply the pass, mutating the artifact in place.
    fn apply(&self, model: &mut ModelArtifact) -> Result<()>;
}

/// Ordered sequence of [`QuantPass`]es applied to one [`ModelArtifact`].
#[derive(Default)]
pub struct QuantPipeline {
    passes: Vec<Box<dyn QuantPass>>,
}

impl QuantPipeline {
    pub fn new() -> QuantPipeline {
        QuantPipeline { passes: Vec::new() }
    }

    /// Append a pass (builder style).
    pub fn pass(mut self, p: impl QuantPass + 'static) -> QuantPipeline {
        self.passes.push(Box::new(p));
        self
    }

    /// Run every pass in order over a fresh artifact of `store`. The source
    /// store is never mutated and never deep-copied.
    pub fn run(&self, store: &ParamStore) -> Result<ModelArtifact> {
        let mut artifact = ModelArtifact::new(store);
        for p in &self.passes {
            p.apply(&mut artifact)?;
            artifact.provenance.push(p.name());
        }
        Ok(artifact)
    }
}

/// Default ε when folding auto-discovered BN layers (matches `CnnConfig`).
pub const DEFAULT_BN_EPS: f32 = 1e-5;

/// BatchNorm-folding pass (paper §4.1) with convention-based discovery: a
/// parameter group `P.{gamma,beta,mean,var}` (running stats present, so not
/// a LayerNorm) is folded into the conv/linear layer named by replacing
/// `bn` with `conv` in the final segment of `P` (e.g. `bn1` → `conv1`, the
/// repo's CNN naming) when that layer's weight and bias exist. A no-op on BN-free stores such
/// as the BERT models. Use [`BnFoldWith`] for explicit pairs or a custom ε.
#[derive(Debug, Clone, Copy, Default)]
pub struct BnFold;

impl QuantPass for BnFold {
    fn name(&self) -> String {
        "bn_fold".into()
    }

    fn apply(&self, model: &mut ModelArtifact) -> Result<()> {
        for (conv, bn) in discover_bn_pairs(&model.eval) {
            fold_bn(&mut model.eval, &conv, &bn, DEFAULT_BN_EPS)?;
        }
        Ok(())
    }
}

/// Explicit BN-fold pass: fold each `(conv, bn)` pair with a given ε.
#[derive(Debug, Clone)]
pub struct BnFoldWith {
    pub pairs: Vec<(String, String)>,
    pub eps: f32,
}

impl BnFoldWith {
    pub fn new(pairs: Vec<(String, String)>, eps: f32) -> BnFoldWith {
        BnFoldWith { pairs, eps }
    }
}

impl QuantPass for BnFoldWith {
    fn name(&self) -> String {
        "bn_fold".into()
    }

    fn apply(&self, model: &mut ModelArtifact) -> Result<()> {
        for (conv, bn) in &self.pairs {
            fold_bn(&mut model.eval, conv, bn, self.eps)?;
        }
        Ok(())
    }
}

/// `(conv, bn)` pairs by naming convention: a prefix with all four BN stats
/// (`gamma`/`beta`/`mean`/`var`) is a BatchNorm layer (LayerNorms carry no
/// running stats); its fold target is the prefix with `bn` replaced by
/// `conv`, when that layer's weight and bias are present.
fn discover_bn_pairs(store: &ParamStore) -> Vec<(String, String)> {
    let names: HashSet<&str> = store.names().iter().map(|s| s.as_str()).collect();
    let mut pairs = Vec::new();
    for n in store.names() {
        if let Some(prefix) = n.strip_suffix(".mean") {
            let is_bn = ["gamma", "beta", "var"]
                .iter()
                .all(|s| names.contains(format!("{prefix}.{s}").as_str()));
            // rewrite only the final path segment, so an enclosing module
            // path that happens to contain "bn" is left alone
            let (path, leaf) = match prefix.rsplit_once('.') {
                Some((p, l)) => (Some(p), l),
                None => (None, prefix),
            };
            let conv_leaf = leaf.replace("bn", "conv");
            let conv = match path {
                Some(p) => format!("{p}.{conv_leaf}"),
                None => conv_leaf,
            };
            let has_target = conv != prefix
                && names.contains(format!("{conv}.weight").as_str())
                && names.contains(format!("{conv}.bias").as_str());
            if is_bn && has_target {
                pairs.push((conv, prefix.to_string()));
            }
        }
    }
    pairs
}

/// The paper's SplitQuant weight/bias split as a pass: 1-D k-means clusters
/// each quantizable tensor into lower/middle/upper groups, each quantized
/// with its own affine parameters. Writes the dequantized (fake-quant) view
/// into the artifact's eval store (copy-on-write) and the packed
/// codes+cid form into its tensor map.
///
/// Per-layer [`SplitQuantConfig`] overrides make mixed precision
/// expressible: `SplitQuantPass::bits(2).layer_bits("classifier.weight", 8)`
/// keeps a sensitive head at INT8 while the rest of the model drops to INT2.
#[derive(Debug, Clone)]
pub struct SplitQuantPass {
    cfg: SplitQuantConfig,
    overrides: BTreeMap<String, SplitQuantConfig>,
    quantizable: Option<Vec<String>>,
}

impl SplitQuantPass {
    /// Uniform `bits` everywhere (paper defaults: k = 3, greedy k-means++).
    pub fn bits(bits: u8) -> SplitQuantPass {
        SplitQuantPass::with_config(SplitQuantConfig::new(bits))
    }

    /// Explicit base config.
    pub fn with_config(cfg: SplitQuantConfig) -> SplitQuantPass {
        SplitQuantPass { cfg, overrides: BTreeMap::new(), quantizable: None }
    }

    /// Mixed precision: override the bit-width for one layer.
    pub fn layer_bits(self, name: &str, bits: u8) -> SplitQuantPass {
        let cfg = SplitQuantConfig { bits, ..self.cfg };
        self.layer_config(name, cfg)
    }

    /// Mixed precision: override the full config for one layer.
    pub fn layer_config(mut self, name: &str, cfg: SplitQuantConfig) -> SplitQuantPass {
        self.overrides.insert(name.to_string(), cfg);
        self
    }

    /// Restrict the quantized set (default:
    /// [`crate::splitquant::default_quantizable`] of the eval store).
    pub fn quantizable(mut self, names: Vec<String>) -> SplitQuantPass {
        self.quantizable = Some(names);
        self
    }

    /// Effective config for one parameter (override or base).
    pub fn config_for(&self, name: &str) -> SplitQuantConfig {
        self.overrides.get(name).copied().unwrap_or(self.cfg)
    }
}

impl QuantPass for SplitQuantPass {
    fn name(&self) -> String {
        format!("splitquant(bits={}, k={})", self.cfg.bits, self.cfg.k)
    }

    fn apply(&self, model: &mut ModelArtifact) -> Result<()> {
        let quantizable = match &self.quantizable {
            Some(q) => q.clone(),
            None => default_quantizable(&model.eval),
        };
        let quantset: HashSet<&str> = quantizable.iter().map(|s| s.as_str()).collect();
        let mut rng = Rng::new(self.cfg.seed);

        // One pass in `quantizable` order. Each name is either a bias that
        // its weight's config claims for joint clustering (skipped here,
        // packed on the weight's turn), a weight with such a companion (one
        // k-means over the concatenated values, two packed tensors), or a
        // tensor quantized on its own. The shared seeded RNG advances in
        // `quantizable` order — deterministic for a given (store, config),
        // which is the contract; exact bit-layout is not stable across
        // refactors of this iteration order.
        for name in &quantizable {
            if let Some(stem) = name.strip_suffix(".bias") {
                let wname = format!("{stem}.weight");
                if quantset.contains(wname.as_str()) && self.config_for(&wname).joint_bias {
                    continue;
                }
            }
            let cfg = self.config_for(name);
            let joint_bias = name
                .strip_suffix(".weight")
                .map(|stem| format!("{stem}.bias"))
                .filter(|bn| cfg.joint_bias && quantset.contains(bn.as_str()));
            match joint_bias {
                Some(bn) => {
                    let (wt, bt) = {
                        let w = model.eval.get(name)?;
                        let b = model.eval.get(&bn)?;
                        split_quantize_pair(w, Some(b), &cfg, &mut rng)?
                    };
                    let bt = bt.expect("split_quantize_pair returns a bias split");
                    model.eval.set(name, wt.qtensor.dequantize())?;
                    model.eval.set(&bn, bt.qtensor.dequantize())?;
                    model.tensors.insert(name.clone(), wt.qtensor);
                    model.tensors.insert(bn, bt.qtensor);
                }
                None => {
                    let st = {
                        let t = model.eval.get(name)?;
                        split_quantize(t, &cfg, &mut rng)?
                    };
                    model.eval.set(name, st.qtensor.dequantize())?;
                    model.tensors.insert(name.clone(), st.qtensor);
                }
            }
        }
        model.bits = self.cfg.bits;
        Ok(())
    }
}

/// Plain affine PTQ under one shared [`QConfig`] (the paper's "Baseline"
/// column: min-max, percentile or MSE observer, per-tensor or per-channel).
#[derive(Debug, Clone)]
pub struct BaselinePass {
    cfg: QConfig,
    quantizable: Option<Vec<String>>,
}

impl BaselinePass {
    pub fn new(cfg: QConfig) -> BaselinePass {
        BaselinePass { cfg, quantizable: None }
    }

    /// Restrict the quantized set (default:
    /// [`crate::splitquant::default_quantizable`] of the eval store).
    pub fn quantizable(mut self, names: Vec<String>) -> BaselinePass {
        self.quantizable = Some(names);
        self
    }
}

impl QuantPass for BaselinePass {
    fn name(&self) -> String {
        format!("baseline({})", self.cfg.label())
    }

    fn apply(&self, model: &mut ModelArtifact) -> Result<()> {
        let quantizable = match &self.quantizable {
            Some(q) => q.clone(),
            None => default_quantizable(&model.eval),
        };
        for name in &quantizable {
            let q = {
                let t = model.eval.get(name)?;
                QTensor::quantize(t, &self.cfg)?
            };
            model.eval.set(name, q.dequantize())?;
            model.tensors.insert(name.clone(), q);
        }
        model.bits = self.cfg.bits;
        Ok(())
    }
}

/// Outlier Channel Splitting (Zhao et al., ICML 2019) as a pass: rank-2+
/// tensors get the expand → quantize → fold-back fake-quant treatment,
/// vectors fall back to plain quantization. Produces only the eval view —
/// the OCS evaluation protocol has no packed deployment form.
#[derive(Debug, Clone)]
pub struct OcsPass {
    cfg: QConfig,
    expand_ratio: f64,
    quantizable: Option<Vec<String>>,
}

impl OcsPass {
    pub fn new(cfg: QConfig, expand_ratio: f64) -> OcsPass {
        OcsPass { cfg, expand_ratio, quantizable: None }
    }

    /// Restrict the quantized set (default:
    /// [`crate::splitquant::default_quantizable`] of the eval store).
    pub fn quantizable(mut self, names: Vec<String>) -> OcsPass {
        self.quantizable = Some(names);
        self
    }
}

impl QuantPass for OcsPass {
    fn name(&self) -> String {
        format!("ocs({}, expand={})", self.cfg.label(), self.expand_ratio)
    }

    fn apply(&self, model: &mut ModelArtifact) -> Result<()> {
        let quantizable = match &self.quantizable {
            Some(q) => q.clone(),
            None => default_quantizable(&model.eval),
        };
        for name in &quantizable {
            let fq = {
                let t = model.eval.get(name)?;
                if t.shape().len() >= 2 {
                    ocs_fake_quant(t, &self.cfg, self.expand_ratio)?.fake_quant
                } else {
                    QTensor::quantize(t, &self.cfg)?.dequantize()
                }
            };
            model.eval.set(name, fq)?;
        }
        Ok(())
    }
}

/// Activation-split calibration (paper §4.2) as a pass: run forwards of the
/// artifact's **current** eval view (so calibration sees the weights the
/// earlier passes produced) over the calibration batches through the
/// pure-Rust executor, record per-site/per-chunk ranges, and store the
/// resulting [`ActQuantParams`] on the artifact. The eval store is shared
/// O(1) into the model, not copied.
pub struct ActCalibratePass {
    cfg: BertConfig,
    batches: Vec<(IntTensor, Tensor)>,
    bits: u8,
    mode: ActQuantMode,
}

impl ActCalibratePass {
    pub fn new(
        cfg: BertConfig,
        batches: Vec<(IntTensor, Tensor)>,
        bits: u8,
        mode: ActQuantMode,
    ) -> ActCalibratePass {
        ActCalibratePass { cfg, batches, bits, mode }
    }
}

impl QuantPass for ActCalibratePass {
    fn name(&self) -> String {
        format!("act_calibrate(bits={}, {:?})", self.bits, self.mode)
    }

    fn apply(&self, model: &mut ModelArtifact) -> Result<()> {
        let bert = crate::model::bert::BertModel::new(self.cfg.clone(), model.eval.share())?;
        let mut cal = ActCalibrator::new(&self.cfg);
        for (ids, mask) in &self.batches {
            let mut hook = cal.hook();
            bert.forward_hooked(ids, mask, Some(&mut hook));
        }
        model.act_params = Some(cal.to_params(self.bits, self.mode));
        Ok(())
    }
}

/// Observer-based activation quantization (the integer-inference front end):
/// pool every calibration value seen at each activation site, reduce each
/// pool with a [`Observer`] from `quant/observer.rs` (min-max, percentile,
/// MSE search, entropy), and store the resulting **per-tensor** scale /
/// zero-point parameters on [`ModelArtifact::act_params`]. Ranges are widened
/// to include 0 so a zero activation always quantizes exactly — the invariant
/// the `KernelKind::Int8` datapath's fallback-parity rules rely on.
///
/// Where [`ActCalibratePass`] records per-chunk min-max ranges for the
/// fake-quant evaluation path (paper §4.2), this pass feeds the real integer
/// kernels: the produced params are what
/// [`crate::model::qbert::QuantizedBert`] consumes to quantize activations at
/// layer boundaries. Empty calibration sets or non-finite activations
/// surface as a deterministic [`crate::error::Error::Quant`] from the
/// observer, never as a garbage range.
pub struct ActQuantizePass {
    cfg: BertConfig,
    batches: Vec<(IntTensor, Tensor)>,
    bits: u8,
    observer: Observer,
}

impl ActQuantizePass {
    pub fn new(
        cfg: BertConfig,
        batches: Vec<(IntTensor, Tensor)>,
        bits: u8,
        observer: Observer,
    ) -> ActQuantizePass {
        ActQuantizePass { cfg, batches, bits, observer }
    }
}

impl QuantPass for ActQuantizePass {
    fn name(&self) -> String {
        format!("act_quantize(bits={}, {})", self.bits, self.observer.label())
    }

    fn apply(&self, model: &mut ModelArtifact) -> Result<()> {
        let bert = crate::model::bert::BertModel::new(self.cfg.clone(), model.eval.share())?;
        let n_sites = self.cfg.act_sites().len();
        let mut samples: Vec<Vec<f32>> = vec![Vec::new(); n_sites];
        for (ids, mask) in &self.batches {
            let mut hook = |site: usize, t: &mut Tensor| {
                samples[site].extend_from_slice(t.data());
            };
            bert.forward_hooked(ids, mask, Some(&mut hook));
        }
        let params = params_from_samples(&samples, self.bits, self.observer)?;
        let per_site = params.into_iter().map(|p| [p, p, p]).collect();
        model.act_params = Some(ActQuantParams { per_site, bits: self.bits });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::CnnConfig;
    use crate::splitquant::quantize_store;

    fn tiny_store() -> (BertConfig, ParamStore) {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        (cfg, store)
    }

    #[test]
    fn pipeline_reproduces_quantize_store_byte_identically() {
        // acceptance check: BnFold (no-op on BERT) + SplitQuantPass::bits(2)
        // must equal the quantize_store path bit for bit
        let (_, store) = tiny_store();
        let quantizable = default_quantizable(&store);
        let (eval_ref, qm_ref) =
            quantize_store(&store, &quantizable, &SplitQuantConfig::new(2)).unwrap();

        let artifact = QuantPipeline::new()
            .pass(BnFold)
            .pass(SplitQuantPass::bits(2))
            .run(&store)
            .unwrap();
        assert_eq!(artifact.quantized_model(), qm_ref);
        for (name, t) in eval_ref.iter() {
            assert_eq!(t.data(), artifact.eval.get(name).unwrap().data(), "{name}");
        }
        assert_eq!(
            artifact.provenance,
            vec!["bn_fold".to_string(), "splitquant(bits=2, k=3)".to_string()]
        );
    }

    #[test]
    fn pipeline_source_store_is_untouched_and_shared() {
        let (_, store) = tiny_store();
        let before: Vec<f32> =
            store.get("encoder.0.attn.q.weight").unwrap().data().to_vec();
        let artifact =
            QuantPipeline::new().pass(SplitQuantPass::bits(4)).run(&store).unwrap();
        // source unchanged
        assert_eq!(store.get("encoder.0.attn.q.weight").unwrap().data(), &before[..]);
        // untouched (non-quantizable) tensors still pointer-shared
        assert!(artifact.eval.shares_tensor(&store, "embeddings.ln.gamma"));
        assert!(artifact.eval.shares_tensor(&store, "embeddings.position"));
        // quantized tensors were copy-on-written
        assert!(!artifact.eval.shares_tensor(&store, "encoder.0.attn.q.weight"));
    }

    #[test]
    fn per_layer_bit_overrides_mix_precision() {
        let (_, store) = tiny_store();
        let artifact = QuantPipeline::new()
            .pass(SplitQuantPass::bits(2).layer_bits("classifier.weight", 8))
            .run(&store)
            .unwrap();
        assert_eq!(artifact.tensors["classifier.weight"].bits(), 8);
        assert_eq!(artifact.tensors["encoder.0.attn.q.weight"].bits(), 2);
        // the INT8 layer reconstructs far tighter than its INT2 peers
        let tight = store
            .get("classifier.weight")
            .unwrap()
            .max_abs_diff(artifact.eval.get("classifier.weight").unwrap());
        let loose = store
            .get("encoder.0.attn.q.weight")
            .unwrap()
            .max_abs_diff(artifact.eval.get("encoder.0.attn.q.weight").unwrap());
        assert!(tight < loose, "int8 {tight} vs int2 {loose}");
    }

    #[test]
    fn bn_fold_auto_matches_fold_cnn() {
        let ccfg = CnnConfig::default();
        let mut rng = Rng::new(3);
        let mut store = ParamStore::init_cnn(&ccfg.param_order(), &mut rng);
        for bn in ["bn1", "bn2"] {
            let ch = store.get(&format!("{bn}.gamma")).unwrap().numel();
            store.set(&format!("{bn}.gamma"), Tensor::full(&[ch], 1.5)).unwrap();
            store.set(&format!("{bn}.mean"), Tensor::full(&[ch], 0.2)).unwrap();
            store.set(&format!("{bn}.var"), Tensor::full(&[ch], 2.0)).unwrap();
        }
        let mut manual = store.share();
        crate::splitquant::bn_fold::fold_cnn(&mut manual, DEFAULT_BN_EPS).unwrap();
        let artifact = QuantPipeline::new().pass(BnFold).run(&store).unwrap();
        for (name, t) in manual.iter() {
            assert_eq!(t.data(), artifact.eval.get(name).unwrap().data(), "{name}");
        }
    }

    #[test]
    fn bn_fold_is_noop_on_bert() {
        let (_, store) = tiny_store();
        assert!(discover_bn_pairs(&store).is_empty());
        let artifact = QuantPipeline::new().pass(BnFold).run(&store).unwrap();
        for name in store.names() {
            assert!(artifact.eval.shares_tensor(&store, name), "{name}");
        }
    }

    #[test]
    fn act_calibrate_pass_records_params() {
        let (cfg, store) = tiny_store();
        let mut rng = Rng::new(7);
        let l = cfg.max_len;
        let batches: Vec<(IntTensor, Tensor)> = (0..2)
            .map(|_| {
                let ids: Vec<i32> =
                    (0..4 * l).map(|_| rng.below(cfg.vocab_size) as i32).collect();
                (IntTensor::new(&[4, l], ids).unwrap(), Tensor::full(&[4, l], 1.0))
            })
            .collect();
        let artifact = QuantPipeline::new()
            .pass(SplitQuantPass::bits(8))
            .pass(ActCalibratePass::new(cfg.clone(), batches, 8, ActQuantMode::Split))
            .run(&store)
            .unwrap();
        let act = artifact.act_params.as_ref().unwrap();
        assert_eq!(act.per_site.len(), cfg.act_sites().len());
        assert_eq!(artifact.provenance.len(), 2);
        assert_eq!(act.bits, 8);
    }

    #[test]
    fn act_quantize_pass_produces_per_tensor_zero_pinned_params() {
        let (cfg, store) = tiny_store();
        let mut rng = Rng::new(9);
        let l = cfg.max_len;
        let batches: Vec<(IntTensor, Tensor)> = (0..2)
            .map(|_| {
                let ids: Vec<i32> =
                    (0..4 * l).map(|_| rng.below(cfg.vocab_size) as i32).collect();
                (IntTensor::new(&[4, l], ids).unwrap(), Tensor::full(&[4, l], 1.0))
            })
            .collect();
        let artifact = QuantPipeline::new()
            .pass(SplitQuantPass::bits(8))
            .pass(ActQuantizePass::new(cfg.clone(), batches, 8, Observer::MinMax))
            .run(&store)
            .unwrap();
        let act = artifact.act_params.as_ref().unwrap();
        assert_eq!(act.per_site.len(), cfg.act_sites().len());
        assert_eq!(act.bits, 8);
        for site in &act.per_site {
            // per-tensor: all three chunk slots share one param set
            assert_eq!(site[0], site[1]);
            assert_eq!(site[1], site[2]);
            // zero-pinned range: 0.0 must quantize exactly
            let p = &site[0];
            assert_eq!(p.dequantize(p.quantize(0.0)), 0.0, "zero not exact: {p:?}");
        }
        assert!(artifact.provenance[1].starts_with("act_quantize(bits=8"));
    }

    #[test]
    fn act_quantize_pass_surfaces_observer_errors() {
        // no calibration batches ⇒ empty per-site pools ⇒ deterministic error
        let (cfg, store) = tiny_store();
        let err = QuantPipeline::new()
            .pass(ActQuantizePass::new(cfg, Vec::new(), 8, Observer::MinMax))
            .run(&store)
            .unwrap_err();
        assert!(
            err.to_string().contains("empty calibration data"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn joint_bias_pass_packs_weight_and_bias_together() {
        let (_, store) = tiny_store();
        let cfg = SplitQuantConfig { joint_bias: true, ..SplitQuantConfig::new(4) };
        let artifact = QuantPipeline::new()
            .pass(SplitQuantPass::with_config(cfg))
            .run(&store)
            .unwrap();
        let w = &artifact.tensors["encoder.0.attn.q.weight"];
        let b = &artifact.tensors["encoder.0.attn.q.bias"];
        // joint clustering ⇒ identical per-cluster quantization params
        assert_eq!(w.params(), b.params());
        // and the legacy wrapper agrees with the pass route
        let quantizable = default_quantizable(&store);
        let (_, qm) = quantize_store(&store, &quantizable, &cfg).unwrap();
        assert_eq!(artifact.quantized_model(), qm);
    }

    #[test]
    fn joint_bias_orphan_bias_is_quantized_solo() {
        // pinned behavior: under joint_bias, a bias whose weight is NOT in
        // the quantizable set is still quantized (on its own) rather than
        // silently left FP32 — the caller listed it, so it gets packed
        let order = vec![
            ("x.weight".to_string(), vec![4usize, 4]),
            ("x.bias".to_string(), vec![4usize]),
        ];
        let mut store = ParamStore::zeros(&order);
        let mut rng = Rng::new(11);
        store.set("x.bias", Tensor::randn(&[4], 0.0, 1.0, &mut rng)).unwrap();
        let cfg = SplitQuantConfig { joint_bias: true, ..SplitQuantConfig::new(4) };
        let artifact = QuantPipeline::new()
            .pass(SplitQuantPass::with_config(cfg).quantizable(vec!["x.bias".to_string()]))
            .run(&store)
            .unwrap();
        assert!(artifact.tensors.contains_key("x.bias"));
        assert!(!artifact.tensors.contains_key("x.weight"));
        assert_eq!(artifact.fp32_names(), vec!["x.weight".to_string()]);
    }

    #[test]
    fn baseline_and_ocs_passes_match_legacy_wrappers() {
        let (_, store) = tiny_store();
        let quantizable = default_quantizable(&store);
        let qcfg = QConfig::baseline(4);

        let a = QuantPipeline::new().pass(BaselinePass::new(qcfg)).run(&store).unwrap();
        let (eval, tensors) =
            crate::baselines::quantize_store_baseline(&store, &quantizable, &qcfg).unwrap();
        assert_eq!(a.tensors, tensors);
        for (name, t) in eval.iter() {
            assert_eq!(t.data(), a.eval.get(name).unwrap().data(), "{name}");
        }

        let o = QuantPipeline::new().pass(OcsPass::new(qcfg, 0.05)).run(&store).unwrap();
        let eval_ocs =
            crate::baselines::ocs::quantize_store_ocs(&store, &quantizable, &qcfg, 0.05)
                .unwrap();
        for (name, t) in eval_ocs.iter() {
            assert_eq!(t.data(), o.eval.get(name).unwrap().data(), "{name}");
        }
        assert!(o.tensors.is_empty());
    }
}
