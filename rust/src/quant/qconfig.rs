//! Quantization configuration: bit-width × scheme × granularity × observer.

use super::observer::Observer;

/// Scale-group granularity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Granularity {
    /// One (scale, zp) for the whole tensor — what the paper's baseline uses.
    PerTensor,
    /// One (scale, zp) per slice along `axis` (0 = leading, otherwise the
    /// trailing axis is supported).
    PerChannel { axis: usize },
}

/// Full quantizer configuration shared by baselines and SplitQuant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QConfig {
    pub bits: u8,
    pub symmetric: bool,
    pub granularity: Granularity,
    pub observer: Observer,
}

impl QConfig {
    /// The paper's baseline: asymmetric per-tensor min-max at `bits`.
    pub fn baseline(bits: u8) -> QConfig {
        QConfig {
            bits,
            symmetric: false,
            granularity: Granularity::PerTensor,
            observer: Observer::MinMax,
        }
    }

    /// Percentile-clipping baseline (§1: the de-facto outlier treatment).
    pub fn percentile(bits: u8, pct: f64) -> QConfig {
        QConfig { observer: Observer::Percentile { pct }, ..QConfig::baseline(bits) }
    }

    /// Per-channel variant of the baseline (stronger classical PTQ).
    pub fn per_channel(bits: u8, axis: usize) -> QConfig {
        QConfig { granularity: Granularity::PerChannel { axis }, ..QConfig::baseline(bits) }
    }

    /// Report label, e.g. `INT2/minmax/per-tensor`.
    pub fn label(&self) -> String {
        let g = match self.granularity {
            Granularity::PerTensor => "per-tensor".to_string(),
            Granularity::PerChannel { axis } => format!("per-ch{axis}"),
        };
        let sym = if self.symmetric { "sym" } else { "asym" };
        format!("INT{}/{}/{}/{}", self.bits, self.observer.label(), g, sym)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        let b = QConfig::baseline(2);
        assert_eq!(b.bits, 2);
        assert_eq!(b.granularity, Granularity::PerTensor);
        let p = QConfig::percentile(4, 99.0);
        assert_eq!(p.observer, Observer::Percentile { pct: 99.0 });
        assert_eq!(QConfig::per_channel(8, 1).granularity, Granularity::PerChannel { axis: 1 });
    }

    #[test]
    fn labels() {
        assert_eq!(QConfig::baseline(2).label(), "INT2/minmax/per-tensor/asym");
    }
}
