//! Cache-blocked, row-partitioned parallel kernels.
//!
//! Each kernel partitions its *output* into disjoint row chunks and lends
//! one chunk per task to the global [`super::WorkerPool`]; inputs are shared
//! immutably. Every chunk runs the same inner loop as the serial kernel in
//! [`crate::tensor::ops`] (same k-quad unrolling, same zero-skip, same
//! accumulation order), so for `matmul`/`batch_matmul` the parallel result
//! is bit-identical to the serial one — property-tested below, with a 1e-5
//! tolerance to keep the contract honest if the inner loops ever diverge.
//!
//! Three micro-kernel families execute those chunks
//! ([`crate::parallel::KernelKind`], default [`KernelKind::Simd`] when the
//! `simd` feature is compiled in): the scalar quad kernels, the explicit
//! f32x8 tile kernels from [`crate::tensor::simd`] — packed-B panels +
//! register accumulation for the plain matmul, 8-lane in-register dequant
//! for the fused tiles — and the i8×i8→i32 integer kernels behind
//! [`KernelKind::Int8`]. The two f32 families are **bit-identical** (same
//! per-element IEEE op sequence), so scalar-vs-SIMD choice never changes
//! results; the remainder-torture tests below assert exact equality across
//! serial/pooled × scalar/SIMD. The integer family changes the datapath of
//! *fused* matmuls (activations quantize to i8 per call, accumulation is
//! exact i32, f32 appears only in the dequantize epilogue), so it differs
//! from the f32 engines by the activation quantization error — while its
//! own SIMD strips and scalar reference twin
//! ([`split_matmul_int8_reference`]) stay bit-identical to each other
//! across every dispatch/partition, because integer sums are exact in any
//! order and the float epilogue is one fixed shared expression.
//!
//! The fused split-dequant matmul is the Rust twin of the L1 `split_matmul`
//! Pallas kernel: weight tiles are reconstructed `w = (q − zp)·(1/s)` from
//! int codes + cluster ids into a per-worker scratch tile (cache-resident,
//! `tile_k × tile_n`), never materializing the full FP32 weight matrix.

use std::ops::Range;

use crate::quant::QParams;
use crate::tensor::ops;
use crate::tensor::Tensor;

use super::{config, global, kernel_kind, should_parallelize, KernelKind};

/// Rows per task: oversplit by 4× the thread count so the zero-skip
/// fast path (padded batch rows cost almost nothing) load-balances.
fn rows_per_task(rows: usize, threads: usize) -> usize {
    rows.div_ceil(threads.max(1) * 4).max(1)
}

/// Assemble a kernel's output tensor from its freshly built buffer.
fn out_tensor(shape: &[usize], data: Vec<f32>) -> Tensor {
    // sq-lint: allow(no-panic-in-serving) — every kernel allocates `data` as the exact product of `shape`, so the shape check cannot fail
    Tensor::new(shape, data).unwrap()
}

/// `C = A(m×k) @ B(k×n)` on the worker pool, unconditionally parallel,
/// under the process-wide kernel choice. Use [`ops::matmul`] for the
/// size-aware dispatching entry point.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_with(a, b, kernel_kind())
}

/// Pooled matmul with an explicit micro-kernel choice (benches / engine
/// agreement tests). On the SIMD engine B is packed into 8-wide panels
/// **once**, then shared immutably by every row-chunk task.
pub fn matmul_with(a: &Tensor, b: &Tensor, kind: KernelKind) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out_tensor(&[m, n], out);
    }
    let pool = global();
    let rows_per = rows_per_task(m, pool.threads());
    let (ad, bd) = (a.data(), b.data());
    #[cfg(feature = "simd")]
    if kind.effective() != KernelKind::Scalar {
        // Simd and Int8 share the f32x8 family here: a plain f32×f32
        // matmul has no integer inputs for the i8 engine to exploit
        let pb = crate::tensor::simd::PackedB::pack(bd, k, n);
        let pb = &pb;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            let r0 = ci * rows_per;
            let len = chunk.len() / n;
            let rows = r0..r0 + len;
            tasks.push(Box::new(move || {
                // sq-lint: allow(no-timing-in-kernels) — chunk-granularity span around the whole task, not inside the micro-kernel inner loops
                let _sp = crate::trace::kernel_span("matmul-chunk", r0 as u64, len as u64);
                crate::tensor::simd::matmul_rows_simd(ad, pb, chunk, rows)
            }));
        }
        pool.scope(tasks);
        return out_tensor(&[m, n], out);
    }
    let _ = kind; // scalar fallback when the simd feature is compiled out
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
        let r0 = ci * rows_per;
        let len = chunk.len() / n;
        let rows = r0..r0 + len;
        tasks.push(Box::new(move || {
            // sq-lint: allow(no-timing-in-kernels) — chunk-granularity span around the whole task, not inside the micro-kernel inner loops
            let _sp = crate::trace::kernel_span("matmul-chunk", r0 as u64, len as u64);
            ops::matmul_rows(ad, bd, chunk, rows, k, n)
        }));
    }
    pool.scope(tasks);
    out_tensor(&[m, n], out)
}

/// `(B, m, k) @ (B, k, n) -> (B, m, n)` on the worker pool, partitioned
/// over the batch dimension.
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (bs, m, k) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let (bs2, k2, n) = (b.shape()[0], b.shape()[1], b.shape()[2]);
    assert_eq!(bs, bs2);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; bs * m * n];
    if bs == 0 || m * n == 0 {
        return out_tensor(&[bs, m, n], out);
    }
    let pool = global();
    let per = bs.div_ceil(pool.threads().max(1) * 2).max(1);
    let (ad, bd) = (a.data(), b.data());
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (ci, chunk) in out.chunks_mut(per * m * n).enumerate() {
        let b0 = ci * per;
        let nb = chunk.len() / (m * n);
        tasks.push(Box::new(move || {
            // sq-lint: allow(no-timing-in-kernels) — chunk-granularity span around the whole task, not inside the micro-kernel inner loops
            let _sp = crate::trace::kernel_span("batch-matmul-chunk", b0 as u64, nb as u64);
            for (bi, o2) in chunk.chunks_mut(m * n).enumerate() {
                let idx = b0 + bi;
                let a2 = &ad[idx * m * k..(idx + 1) * m * k];
                let b2 = &bd[idx * k * n..(idx + 1) * k * n];
                ops::matmul_naive_into(a2, b2, o2, m, k, n);
            }
        }));
    }
    pool.scope(tasks);
    out_tensor(&[bs, m, n], out)
}

/// Fused split-dequant matmul: `y = x @ dq(W)` where `W` lives as int
/// codes (+ optional per-element cluster ids selecting a `QParams` group).
/// Dispatches serial/parallel by size under the process-wide kernel
/// choice; `wshape` is `[k, n]`. An empty `cid` means a single param group
/// (per-tensor layout).
///
/// The pooled path requires `m ≫ threads`: every task re-dequantizes the
/// W tiles it streams through, so with T threads the reconstruction
/// happens T times per call — amortized only when each task owns many
/// activation rows (at `m ≥ 8·T` the redundant dequant is ≤ ~12% of the
/// FMA work). Small-batch shapes stay on the serial tiled path.
pub fn split_matmul(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
) -> Tensor {
    split_matmul_with(x, wshape, codes, cid, params, kernel_kind())
}

/// [`split_matmul`] with an explicit micro-kernel choice.
pub fn split_matmul_with(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
    kind: KernelKind,
) -> Tensor {
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let (k2, n) = (wshape[0], wshape[1]);
    assert_eq!(k, k2, "fused matmul inner dims {k} vs {k2}");
    assert_eq!(codes.len(), k * n, "fused matmul codes len");
    assert!(cid.is_empty() || cid.len() == k * n, "fused matmul cid len");
    assert!(!params.is_empty(), "fused matmul needs at least one param group");
    if should_parallelize(2 * m * k * n) && m >= 8 * super::effective_threads() {
        split_matmul_pooled_with(x, wshape, codes, cid, params, kind)
    } else {
        split_matmul_serial_with(x, wshape, codes, cid, params, kind)
    }
}

/// Fused split-dequant matmul forced onto the calling thread (tiled).
pub fn split_matmul_serial(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
) -> Tensor {
    split_matmul_serial_with(x, wshape, codes, cid, params, kernel_kind())
}

/// [`split_matmul_serial`] with an explicit micro-kernel choice.
pub fn split_matmul_serial_with(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
    kind: KernelKind,
) -> Tensor {
    #[cfg(feature = "simd")]
    if kind.effective() == KernelKind::Int8 {
        if let Some(out) = int8_fused(x, wshape, codes, cid, params, None, false, false) {
            return out;
        }
        // empty/non-finite activations: integer scaling is undefined there
        return split_matmul_serial_with(x, wshape, codes, cid, params, KernelKind::Simd);
    }
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let n = wshape[1];
    let group = DequantGroups::new(params);
    let mut out = vec![0.0f32; m * n];
    if m * n > 0 {
        split_matmul_rows(x.data(), codes, cid, &group, &mut out, 0..m, k, n, kind);
    }
    out_tensor(&[m, n], out)
}

/// Fused split-dequant matmul forced onto the worker pool.
pub fn split_matmul_pooled(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
) -> Tensor {
    split_matmul_pooled_with(x, wshape, codes, cid, params, kernel_kind())
}

/// [`split_matmul_pooled`] with an explicit micro-kernel choice.
pub fn split_matmul_pooled_with(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
    kind: KernelKind,
) -> Tensor {
    #[cfg(feature = "simd")]
    if kind.effective() == KernelKind::Int8 {
        if let Some(out) = int8_fused(x, wshape, codes, cid, params, None, true, false) {
            return out;
        }
        return split_matmul_pooled_with(x, wshape, codes, cid, params, KernelKind::Simd);
    }
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let n = wshape[1];
    let group = DequantGroups::new(params);
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out_tensor(&[m, n], out);
    }
    let pool = global();
    // one chunk per thread (NOT the 4× oversplit of the plain matmul):
    // every task re-dequantizes the W tiles it touches, so finer chunks
    // would multiply the reconstruction work per call
    let rows_per = m.div_ceil(pool.threads()).max(1);
    let xd = x.data();
    let groups = &group;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
        let r0 = ci * rows_per;
        let len = chunk.len() / n;
        let rows = r0..r0 + len;
        tasks.push(Box::new(move || {
            // sq-lint: allow(no-timing-in-kernels) — chunk-granularity span around the whole task, not inside the micro-kernel inner loops
            let _sp = crate::trace::kernel_span("split-matmul-chunk", r0 as u64, len as u64);
            split_matmul_rows(xd, codes, cid, groups, chunk, rows, k, n, kind);
        }));
    }
    pool.scope(tasks);
    out_tensor(&[m, n], out)
}

/// Per-group dequant constants, precomputed once per call: the hot loop
/// reconstructs `w = (q − zp) · inv` with two loads and one FMA.
struct DequantGroups {
    inv: Vec<f32>,
    zp: Vec<f32>,
}

impl DequantGroups {
    fn new(params: &[QParams]) -> DequantGroups {
        DequantGroups {
            inv: params.iter().map(|p| 1.0 / p.scale).collect(),
            zp: params.iter().map(|p| p.zp).collect(),
        }
    }
}

/// Per-call activation quantization for the integer engine: min–max over
/// the activation tensor, widened to include 0 so the zero-point stays in
/// the i8 range and padded zero rows quantize losslessly. `None` when the
/// data is empty or contains a non-finite value — integer scaling is
/// undefined there and the caller falls back to the f32 path.
#[cfg(feature = "simd")]
fn act_qparams(xd: &[f32]) -> Option<QParams> {
    if xd.is_empty() {
        return None;
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in xd {
        if !v.is_finite() {
            return None;
        }
        lo = lo.min(v);
        hi = hi.max(v);
    }
    Some(QParams::from_range(lo.min(0.0), hi.max(0.0), 8))
}

/// Shared body of the integer fused matmul: quantize the activations once
/// per call (calibrated params when supplied, per-call min–max otherwise),
/// then run the i8 row kernels — SIMD strips or the scalar reference —
/// over a serial or pooled row partition. All four combinations are
/// bit-identical (exact i32 accumulation + one shared float epilogue).
#[cfg(feature = "simd")]
#[allow(clippy::too_many_arguments)]
fn int8_fused(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
    act: Option<&QParams>,
    pooled: bool,
    reference: bool,
) -> Option<Tensor> {
    use crate::tensor::simd::{matmul_rows_i8, matmul_rows_i8_ref, quantize_acts_i8, I8Plane};
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let n = wshape[1];
    let mut out = vec![0.0f32; m * n];
    if m * n == 0 {
        return Some(out_tensor(&[m, n], out));
    }
    let xp = match act {
        Some(p) => *p,
        None => act_qparams(x.data())?,
    };
    let xc = quantize_acts_i8(x.data(), &xp);
    let zps: Vec<f32> = params.iter().map(|p| p.zp).collect();
    let inv: Vec<f32> = params.iter().map(|p| 1.0 / p.scale).collect();
    let plane = I8Plane { codes, cid, zps: &zps, inv: &inv, k, n };
    let inv_x = 1.0 / xp.scale;
    let kernel: fn(&[i16], &I8Plane, f32, &mut [f32], Range<usize>) =
        if reference { matmul_rows_i8_ref } else { matmul_rows_i8 };
    if pooled {
        let pool = global();
        let rows_per = m.div_ceil(pool.threads()).max(1);
        let (xc, plane) = (&xc, &plane);
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            let r0 = ci * rows_per;
            let len = chunk.len() / n;
            let rows = r0..r0 + len;
            tasks.push(Box::new(move || {
                // sq-lint: allow(no-timing-in-kernels) — chunk-granularity span around the whole task, not inside the micro-kernel inner loops
                let _sp = crate::trace::kernel_span("int8-matmul-chunk", r0 as u64, len as u64);
                kernel(xc, plane, inv_x, chunk, rows)
            }));
        }
        pool.scope(tasks);
    } else {
        kernel(&xc, &plane, inv_x, &mut out, 0..m);
    }
    Some(out_tensor(&[m, n], out))
}

/// Explicit entry to the integer fused matmul — what
/// [`split_matmul_with`] runs under [`KernelKind::Int8`], with the option
/// of a pre-calibrated activation range: `act = Some(p)` skips the
/// per-call min–max scan and uses the calibrated scale/zero-point (the
/// `ActQuantizePass` artifact deployed at model layer boundaries), `None`
/// quantizes dynamically. Dispatches serial/pooled by size like
/// [`split_matmul`]. Falls back to the f32 path when integer scaling is
/// infeasible (empty or non-finite dynamic activations) or the `simd`
/// feature is compiled out — the documented `Int8 → Scalar` degradation.
pub fn split_matmul_int8(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
    act: Option<&QParams>,
) -> Tensor {
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let n = wshape[1];
    let pooled = should_parallelize(2 * m * k * n) && m >= 8 * super::effective_threads();
    #[cfg(feature = "simd")]
    if let Some(out) = int8_fused(x, wshape, codes, cid, params, act, pooled, false) {
        return out;
    }
    let _ = (act, pooled);
    split_matmul_with(x, wshape, codes, cid, params, KernelKind::Simd)
}

/// Scalar reference twin of [`split_matmul_int8`]: one output element at a
/// time through `tensor::simd::matmul_rows_i8_ref`, always serial, with
/// the identical activation quantization and fallback rules — so a
/// verification harness can push a whole model through both paths and
/// assert **bit equality** end to end (the qbert int8 oracle test does).
pub fn split_matmul_int8_reference(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    params: &[QParams],
    act: Option<&QParams>,
) -> Tensor {
    #[cfg(feature = "simd")]
    if let Some(out) = int8_fused(x, wshape, codes, cid, params, act, false, true) {
        return out;
    }
    let _ = act;
    split_matmul_serial_with(x, wshape, codes, cid, params, KernelKind::Simd)
}

/// Activation-path outlier channels for the OCS-style escape hatch:
/// columns of `x` whose max |value| exceeds `ratio ×` the mean column
/// max |value|. Empty when the activations are degenerate (all zero or
/// non-finite), so the caller skips the expansion.
pub fn act_outlier_columns(x: &Tensor, ratio: f32) -> Vec<usize> {
    let (m, k) = (x.shape()[0], x.shape()[1]);
    if m == 0 || k == 0 {
        return Vec::new();
    }
    let mut colmax = vec![0.0f32; k];
    for row in x.data().chunks(k) {
        for (cm, &v) in colmax.iter_mut().zip(row) {
            *cm = cm.max(v.abs());
        }
    }
    let mean = colmax.iter().sum::<f32>() / k as f32;
    if mean <= 0.0 || !mean.is_finite() {
        return Vec::new();
    }
    (0..k).filter(|&c| colmax[c] > ratio * mean).collect()
}

/// OCS-style duplicate-and-halve on the **activation** path (the
/// weight-side analogue is [`crate::baselines::ocs`]): each outlier column
/// `c` of `x` is halved in place and a halved copy appended, while the
/// matching k-row of the weight code/cid planes is duplicated. Halving is
/// exact in f32 and the consumer's sum restores the product, so
/// `x'·dq(W') = x·dq(W)` up to summation order — but the activation range
/// the integer engine quantizes over shrinks by up to 2× per split, which
/// is the whole point: an outlier channel stops stretching the per-tensor
/// activation scale. Returns the expanded `(x, wshape, codes, cid)`; feed
/// them to [`split_matmul_int8`] with `act = None` so the dynamic range
/// scan sees the tightened values (a range calibrated on the unexpanded
/// activations would give the win back).
pub fn ocs_expand_acts(
    x: &Tensor,
    wshape: &[usize],
    codes: &[i8],
    cid: &[u8],
    outliers: &[usize],
) -> (Tensor, [usize; 2], Vec<i8>, Vec<u8>) {
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let n = wshape[1];
    let ke = k + outliers.len();
    let xd = x.data();
    let mut xe = vec![0.0f32; m * ke];
    for r in 0..m {
        let src = &xd[r * k..(r + 1) * k];
        let dst = &mut xe[r * ke..(r + 1) * ke];
        dst[..k].copy_from_slice(src);
        for (j, &c) in outliers.iter().enumerate() {
            let half = src[c] * 0.5;
            dst[c] = half;
            dst[k + j] = half;
        }
    }
    let mut ce = Vec::with_capacity(ke * n);
    ce.extend_from_slice(codes);
    let mut ie = Vec::with_capacity(if cid.is_empty() { 0 } else { ke * n });
    ie.extend_from_slice(cid);
    for &c in outliers {
        ce.extend_from_slice(&codes[c * n..(c + 1) * n]);
        if !cid.is_empty() {
            ie.extend_from_slice(&cid[c * n..(c + 1) * n]);
        }
    }
    (out_tensor(&[m, ke], xe), [ke, n], ce, ie)
}

/// Per-cluster code counts of a weight's cluster-id plane: how many codes
/// land in the lower / middle / upper SplitQuant cluster. A **dispatch
/// prologue** helper for the numeric-health layer ([`crate::qhealth`]) —
/// one pass over the cid plane outside the micro-kernel loops, so the
/// bit-exact kernels themselves stay untouched. Ids other than 0/1/2
/// (impossible for well-formed planes) are ignored. An all-zero entry in
/// the result marks a *dead cluster*: one of the three split ranges
/// carries no codes, wasting the accuracy SplitQuant's split allocation
/// paid for.
pub fn cluster_occupancy(cid: &[u8]) -> [u64; 3] {
    let mut occ = [0u64; 3];
    for &c in cid {
        if let Some(slot) = occ.get_mut(c as usize) {
            *slot += 1;
        }
    }
    occ
}

/// Inner fused kernel dispatch for one output row chunk: scalar quad
/// kernel or the f32x8 tile kernel, chosen per call. Both share the exact
/// tiling (`tile_k × tile_n`, `tile_k` a multiple of 4) and per-element
/// op order, so the choice never changes bits.
#[allow(clippy::too_many_arguments)]
fn split_matmul_rows(
    xd: &[f32],
    codes: &[i8],
    cid: &[u8],
    groups: &DequantGroups,
    out_chunk: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
    kind: KernelKind,
) {
    #[cfg(feature = "simd")]
    if kind.effective() == KernelKind::Simd {
        return split_matmul_rows_simd(xd, codes, cid, groups, out_chunk, rows, k, n);
    }
    let _ = kind;
    split_matmul_rows_scalar(xd, codes, cid, groups, out_chunk, rows, k, n)
}

/// Scalar fused kernel for one output row chunk. Tiles W as
/// `tile_k × tile_n`, dequantizing each tile into a worker-local scratch
/// buffer before streaming all chunk rows through it. `tile_k` is a
/// multiple of 4, so the k-quad boundaries (and the zero-skip over padded
/// activation rows) line up exactly with the serial kernel's unroll.
#[allow(clippy::too_many_arguments)]
fn split_matmul_rows_scalar(
    xd: &[f32],
    codes: &[i8],
    cid: &[u8],
    groups: &DequantGroups,
    out_chunk: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    let cfg = config();
    let tk = (cfg.tile_k.max(4) / 4) * 4;
    let tn = cfg.tile_n.max(8).min(n.max(1));
    let mut scratch = vec![0.0f32; tk * tn];
    let per_tensor = cid.is_empty();
    let (i0, z0) = (groups.inv[0], groups.zp[0]);
    let mut n0 = 0;
    while n0 < n {
        let nt = tn.min(n - n0);
        let mut k0 = 0;
        while k0 < k {
            let kt = tk.min(k - k0);
            // ---- dequantize the W tile [k0..k0+kt) × [n0..n0+nt)
            for kk in 0..kt {
                let wrow = (k0 + kk) * n + n0;
                let srow = &mut scratch[kk * nt..(kk + 1) * nt];
                if per_tensor {
                    for (s, &q) in srow.iter_mut().zip(&codes[wrow..wrow + nt]) {
                        *s = (q as f32 - z0) * i0;
                    }
                } else {
                    for (j, s) in srow.iter_mut().enumerate() {
                        let c = cid[wrow + j] as usize;
                        *s = (codes[wrow + j] as f32 - groups.zp[c]) * groups.inv[c];
                    }
                }
            }
            // ---- FMA all chunk rows through the tile
            let k4 = kt - kt % 4;
            for (ri, i) in rows.clone().enumerate() {
                let arow = &xd[i * k + k0..i * k + k0 + kt];
                let orow = &mut out_chunk[ri * n + n0..ri * n + n0 + nt];
                let mut kk = 0;
                while kk < k4 {
                    let (a0, a1, a2, a3) =
                        (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        kk += 4;
                        continue; // padded/sparse rows (zero-mask batch slots)
                    }
                    let b0 = &scratch[kk * nt..kk * nt + nt];
                    let b1 = &scratch[(kk + 1) * nt..(kk + 1) * nt + nt];
                    let b2 = &scratch[(kk + 2) * nt..(kk + 2) * nt + nt];
                    let b3 = &scratch[(kk + 3) * nt..(kk + 3) * nt + nt];
                    for j in 0..nt {
                        orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    kk += 4;
                }
                for kk in k4..kt {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &scratch[kk * nt..kk * nt + nt];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            k0 += kt;
        }
        n0 += nt;
    }
}

/// f32x8 fused kernel for one output row chunk — same tiling as
/// [`split_matmul_rows_scalar`], with two differences that keep every bit
/// identical while cutting memory traffic:
///
/// * tile dequant runs 8 lanes per step in registers: codes widen
///   `i8 → f32x8`, then one `(q − zp) · inv` vector expression (per-tensor:
///   splatted constants; split layout: per-lane gather of the cluster's
///   scale/zero-point — fed by the word-at-a-time LUT unpack in
///   [`crate::tensor::packing`]);
/// * the FMA sweeps 8-wide C strips with register accumulation (strip
///   loaded once per k-tile, not re-read/re-written every quad), with the
///   scratch column strip hot across all chunk rows.
#[cfg(feature = "simd")]
#[allow(clippy::too_many_arguments)]
fn split_matmul_rows_simd(
    xd: &[f32],
    codes: &[i8],
    cid: &[u8],
    groups: &DequantGroups,
    out_chunk: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    use crate::tensor::simd::{F32x8, LANES};
    let cfg = config();
    let tk = (cfg.tile_k.max(4) / 4) * 4;
    let tn = cfg.tile_n.max(8).min(n.max(1));
    let mut scratch = vec![0.0f32; tk * tn];
    let per_tensor = cid.is_empty();
    let (i0, z0) = (groups.inv[0], groups.zp[0]);
    let mut n0 = 0;
    while n0 < n {
        let nt = tn.min(n - n0);
        let w8 = nt - nt % LANES;
        let mut k0 = 0;
        while k0 < k {
            let kt = tk.min(k - k0);
            // ---- dequantize the W tile, 8 lanes per step
            for kk in 0..kt {
                let wrow = (k0 + kk) * n + n0;
                let srow = &mut scratch[kk * nt..(kk + 1) * nt];
                if per_tensor {
                    let (zv, iv) = (F32x8::splat(z0), F32x8::splat(i0));
                    let mut j = 0;
                    while j < w8 {
                        let q = F32x8::from_i8(&codes[wrow + j..wrow + j + LANES]);
                        q.sub(zv).mul(iv).store(&mut srow[j..j + LANES]);
                        j += LANES;
                    }
                    for (j, s) in srow.iter_mut().enumerate().skip(w8) {
                        *s = (codes[wrow + j] as f32 - z0) * i0;
                    }
                } else {
                    let mut j = 0;
                    while j < w8 {
                        let mut zp = [0.0f32; LANES];
                        let mut inv = [0.0f32; LANES];
                        let ids = &cid[wrow + j..wrow + j + LANES];
                        for ((z, v), &c) in zp.iter_mut().zip(&mut inv).zip(ids) {
                            *z = groups.zp[c as usize];
                            *v = groups.inv[c as usize];
                        }
                        let q = F32x8::from_i8(&codes[wrow + j..wrow + j + LANES]);
                        q.sub(F32x8::from_array(zp))
                            .mul(F32x8::from_array(inv))
                            .store(&mut srow[j..j + LANES]);
                        j += LANES;
                    }
                    for (j, s) in srow.iter_mut().enumerate().skip(w8) {
                        let c = cid[wrow + j] as usize;
                        *s = (codes[wrow + j] as f32 - groups.zp[c]) * groups.inv[c];
                    }
                }
            }
            // ---- FMA: 8-wide C strip outer, rows inner (the kt×8 scratch
            //      column strip stays L1-hot across every chunk row)
            let k4 = kt - kt % 4;
            let mut j = 0;
            while j < nt {
                let w = LANES.min(nt - j);
                for (ri, i) in rows.clone().enumerate() {
                    let arow = &xd[i * k + k0..i * k + k0 + kt];
                    let ostrip = &mut out_chunk[ri * n + n0 + j..ri * n + n0 + j + w];
                    let mut acc = if w == LANES {
                        F32x8::load(ostrip)
                    } else {
                        F32x8::load_partial(ostrip)
                    };
                    let strip = |kk: usize| {
                        let s = &scratch[kk * nt + j..kk * nt + j + w];
                        if w == LANES {
                            F32x8::load(s)
                        } else {
                            F32x8::load_partial(s)
                        }
                    };
                    let mut kk = 0;
                    while kk < k4 {
                        let (a0, a1, a2, a3) =
                            (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                        if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                            kk += 4;
                            continue; // same zero-skip as the scalar quad
                        }
                        // scalar association order: ((a0·b0 + a1·b1) + a2·b2) + a3·b3
                        let t = F32x8::splat(a0)
                            .mul(strip(kk))
                            .add(F32x8::splat(a1).mul(strip(kk + 1)))
                            .add(F32x8::splat(a2).mul(strip(kk + 2)))
                            .add(F32x8::splat(a3).mul(strip(kk + 3)));
                        acc = acc.add(t);
                        kk += 4;
                    }
                    for kk in k4..kt {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        acc = acc.add(F32x8::splat(av).mul(strip(kk)));
                    }
                    if w == LANES {
                        acc.store(ostrip);
                    } else {
                        acc.store_partial(ostrip);
                    }
                }
                j += LANES;
            }
            k0 += kt;
        }
        n0 += nt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::qrange;
    use crate::util::proptest::{check, gen_values_with_outliers};
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, m: usize, n: usize) -> Tensor {
        Tensor::new(&[m, n], gen_values_with_outliers(rng, m * n, 0.05)).unwrap()
    }

    /// Zero out a few full rows (the padded-batch-slot pattern).
    fn zero_some_rows(t: &mut Tensor, rng: &mut Rng) {
        let (m, n) = (t.shape()[0], t.shape()[1]);
        for i in 0..m {
            if rng.chance(0.3) {
                for v in &mut t.data_mut()[i * n..(i + 1) * n] {
                    *v = 0.0;
                }
            }
        }
    }

    #[test]
    fn property_parallel_matmul_matches_serial() {
        check("pooled matmul == serial matmul", 40, |rng| {
            let m = rng.range(1, 33); // includes m = 1
            let k = rng.range(1, 41); // includes k % 4 != 0
            let n = rng.range(1, 33);
            let mut a = rand_tensor(rng, m, k);
            zero_some_rows(&mut a, rng);
            let b = rand_tensor(rng, k, n);
            let par = matmul(&a, &b);
            let ser = ops::matmul_serial(&a, &b);
            assert!(
                par.max_abs_diff(&ser) <= 1e-5,
                "gap {} at {m}x{k}x{n}",
                par.max_abs_diff(&ser)
            );
        });
    }

    #[test]
    fn property_parallel_batch_matmul_matches_serial() {
        check("pooled batch_matmul == serial", 30, |rng| {
            let bs = rng.range(1, 7);
            let m = rng.range(1, 12);
            let k = rng.range(1, 17);
            let n = rng.range(1, 12);
            let a = Tensor::new(
                &[bs, m, k],
                gen_values_with_outliers(rng, bs * m * k, 0.05),
            )
            .unwrap();
            let b = Tensor::new(
                &[bs, k, n],
                gen_values_with_outliers(rng, bs * k * n, 0.05),
            )
            .unwrap();
            let par = batch_matmul(&a, &b);
            let ser = ops::batch_matmul_serial(&a, &b);
            assert!(par.max_abs_diff(&ser) <= 1e-5, "gap {}", par.max_abs_diff(&ser));
        });
    }

    /// Random quantized weight: codes within INT`bits` range plus either a
    /// per-tensor param group or a split layout with 2–4 groups.
    fn rand_qweight(
        rng: &mut Rng,
        k: usize,
        n: usize,
        bits: u8,
    ) -> (Vec<i8>, Vec<u8>, Vec<QParams>) {
        let (qmin, qmax) = qrange(bits);
        let span = (qmax - qmin + 1) as usize;
        let codes: Vec<i8> =
            (0..k * n).map(|_| (qmin + rng.below(span) as i32) as i8).collect();
        if rng.chance(0.5) {
            let p = QParams::from_range(-1.0, 1.0, bits);
            (codes, Vec::new(), vec![p])
        } else {
            let groups = rng.range(2, 5);
            let params: Vec<QParams> = (0..groups)
                .map(|g| {
                    let lo = -0.1 * (g as f32 + 1.0) * (1.0 + rng.f32());
                    let hi = 0.2 * (g as f32 + 1.0) * (1.0 + rng.f32());
                    QParams::from_range(lo, hi, bits)
                })
                .collect();
            let cid: Vec<u8> = (0..k * n).map(|_| rng.below(groups) as u8).collect();
            (codes, cid, params)
        }
    }

    /// Reference: dequantize W fully with the same `(q − zp)·inv` formula,
    /// then run the serial matmul.
    fn reference_fused(
        x: &Tensor,
        k: usize,
        n: usize,
        codes: &[i8],
        cid: &[u8],
        params: &[QParams],
    ) -> Tensor {
        let inv: Vec<f32> = params.iter().map(|p| 1.0 / p.scale).collect();
        let zp: Vec<f32> = params.iter().map(|p| p.zp).collect();
        let mut w = vec![0.0f32; k * n];
        for (i, (o, &q)) in w.iter_mut().zip(codes).enumerate() {
            let c = if cid.is_empty() { 0 } else { cid[i] as usize };
            *o = (q as f32 - zp[c]) * inv[c];
        }
        ops::matmul_serial(x, &Tensor::new(&[k, n], w).unwrap())
    }

    #[test]
    fn property_fused_split_matmul_matches_dequant_reference() {
        check("fused split matmul == dequant + serial matmul", 40, |rng| {
            let m = rng.range(1, 20);
            let k = rng.range(1, 41);
            let n = rng.range(1, 28);
            let bits = [2u8, 4, 8][rng.below(3)];
            let mut x = rand_tensor(rng, m, k);
            zero_some_rows(&mut x, rng);
            let (codes, cid, params) = rand_qweight(rng, k, n, bits);
            let want = reference_fused(&x, k, n, &codes, &cid, &params);
            for kind in [KernelKind::Scalar, KernelKind::Simd] {
                for got in [
                    split_matmul_serial_with(&x, &[k, n], &codes, &cid, &params, kind),
                    split_matmul_pooled_with(&x, &[k, n], &codes, &cid, &params, kind),
                ] {
                    assert!(
                        got.max_abs_diff(&want) <= 1e-5,
                        "gap {} at {m}x{k}x{n} INT{bits} {kind:?}",
                        got.max_abs_diff(&want)
                    );
                }
            }
        });
    }

    #[test]
    fn property_fused_engines_are_bit_identical() {
        // the contract the SIMD tile kernel is built on: same IEEE op
        // sequence per element ⇒ exact equality, not tolerance
        check("fused SIMD == scalar == serial (exact)", 40, |rng| {
            let m = rng.range(1, 24);
            let k = rng.range(1, 70);
            let n = rng.range(1, 70);
            let bits = [2u8, 4, 8][rng.below(3)];
            let mut x = rand_tensor(rng, m, k);
            zero_some_rows(&mut x, rng);
            let (codes, cid, params) = rand_qweight(rng, k, n, bits);
            let run = |pooled: bool, kind: KernelKind| {
                if pooled {
                    split_matmul_pooled_with(&x, &[k, n], &codes, &cid, &params, kind)
                } else {
                    split_matmul_serial_with(&x, &[k, n], &codes, &cid, &params, kind)
                }
            };
            let base = run(false, KernelKind::Scalar);
            for (label, pooled, kind) in [
                ("serial-simd", false, KernelKind::Simd),
                ("pooled-scalar", true, KernelKind::Scalar),
                ("pooled-simd", true, KernelKind::Simd),
            ] {
                let got = run(pooled, kind);
                assert_eq!(base.data(), got.data(), "{label} at {m}x{k}x{n} INT{bits}");
            }
        });
    }

    #[test]
    fn remainder_torture_all_engines_exact() {
        // ragged N/K remainders around the lane (8) and quad (4) widths,
        // plus the tile boundaries — every engine must agree exactly
        let mut rng = Rng::new(23);
        let dims = [1usize, 7, 8, 9, 63, 64, 65];
        for &k in &dims {
            for &n in &dims {
                for m in [1usize, 5] {
                    let mut x = rand_tensor(&mut rng, m, k);
                    zero_some_rows(&mut x, &mut rng);
                    let b = rand_tensor(&mut rng, k, n);
                    let base = ops::matmul_serial_with(&x, &b, KernelKind::Scalar);
                    for got in [
                        ops::matmul_serial_with(&x, &b, KernelKind::Simd),
                        matmul_with(&x, &b, KernelKind::Scalar),
                        matmul_with(&x, &b, KernelKind::Simd),
                    ] {
                        assert_eq!(base.data(), got.data(), "matmul {m}x{k}x{n}");
                    }
                    let (codes, cid, params) = rand_qweight(&mut rng, k, n, 4);
                    let fbase = split_matmul_serial_with(
                        &x, &[k, n], &codes, &cid, &params, KernelKind::Scalar,
                    );
                    for kind in [KernelKind::Scalar, KernelKind::Simd] {
                        for got in [
                            split_matmul_serial_with(&x, &[k, n], &codes, &cid, &params, kind),
                            split_matmul_pooled_with(&x, &[k, n], &codes, &cid, &params, kind),
                        ] {
                            assert_eq!(fbase.data(), got.data(), "fused {m}x{k}x{n} {kind:?}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn torture_empty_rows_and_degenerate_clusters() {
        let mut rng = Rng::new(31);
        let (k, n) = (65usize, 9usize);

        // all-zero activations (every quad takes the zero-skip)
        let x0 = Tensor::zeros(&[3, k]);
        // zero rows (m = 0)
        let xe = Tensor::new(&[0, k], vec![]).unwrap();
        let (codes, _, _) = rand_qweight(&mut rng, k, n, 4);

        // single-cluster split: cid all zeros, one param group — must match
        // the per-tensor layout (empty cid) bit for bit
        let p = QParams::from_range(-0.7, 0.9, 4);
        let cid0 = vec![0u8; k * n];
        // empty cluster: three groups, ids only ever use {0, 2}
        let params3 =
            vec![p, QParams::from_range(-2.0, 2.0, 4), QParams::from_range(-0.1, 0.1, 4)];
        let cid_gap: Vec<u8> = (0..k * n).map(|i| if i % 3 == 0 { 2 } else { 0 }).collect();

        for x in [&x0, &xe] {
            let per_tensor =
                split_matmul_serial_with(x, &[k, n], &codes, &[], &[p], KernelKind::Scalar);
            // Int8 joins the loop: all-zero activations quantize to exact
            // zero codes (the range is widened to include 0), so its output
            // is the same all-zero plane as the f32 engines
            for kind in [KernelKind::Scalar, KernelKind::Simd, KernelKind::Int8] {
                let single = split_matmul_serial_with(x, &[k, n], &codes, &cid0, &[p], kind);
                assert_eq!(per_tensor.data(), single.data(), "single-cluster {kind:?}");
                let gap_ser =
                    split_matmul_serial_with(x, &[k, n], &codes, &cid_gap, &params3, kind);
                let gap_pool =
                    split_matmul_pooled_with(x, &[k, n], &codes, &cid_gap, &params3, kind);
                assert_eq!(gap_ser.data(), gap_pool.data(), "empty-cluster {kind:?}");
            }
        }

        // a real x through the empty-cluster layout, against the dequant
        // reference
        let x = rand_tensor(&mut rng, 4, k);
        let want = reference_fused(&x, k, n, &codes, &cid_gap, &params3);
        for kind in [KernelKind::Scalar, KernelKind::Simd] {
            let got = split_matmul_serial_with(&x, &[k, n], &codes, &cid_gap, &params3, kind);
            assert!(got.max_abs_diff(&want) <= 1e-5, "{kind:?}");
        }
    }

    #[test]
    fn all_qlayout_variants_agree_across_engines() {
        use crate::quant::{QConfig, QTensor};
        let mut rng = Rng::new(17);
        let x = rand_tensor(&mut rng, 6, 24);

        // PerTensor and Split run the fused kernels directly
        let w = Tensor::randn(&[24, 18], 0.0, 0.5, &mut rng);
        let qt = QTensor::quantize(&w, &QConfig::baseline(4)).unwrap();
        let (codes, cid) = qt.fused_planes().unwrap();
        let base =
            split_matmul_serial_with(&x, qt.shape(), &codes, &cid, qt.params(), KernelKind::Scalar);
        let simd =
            split_matmul_serial_with(&x, qt.shape(), &codes, &cid, qt.params(), KernelKind::Simd);
        assert_eq!(base.data(), simd.data(), "PerTensor");

        let (codes, cid, params) = rand_qweight(&mut rng, 24, 18, 2);
        if !cid.is_empty() {
            let b = split_matmul_serial_with(&x, &[24, 18], &codes, &cid, &params, KernelKind::Scalar);
            let s = split_matmul_serial_with(&x, &[24, 18], &codes, &cid, &params, KernelKind::Simd);
            assert_eq!(b.data(), s.data(), "Split");
        }

        // PerChannel is rejected by the fused path; its dequantized weights
        // still must agree across the plain matmul engines
        let qc = QTensor::quantize(&w, &QConfig::per_channel(4, 1)).unwrap();
        let dq = qc.dequantize();
        let b = ops::matmul_serial_with(&x, &dq, KernelKind::Scalar);
        let s = ops::matmul_serial_with(&x, &dq, KernelKind::Simd);
        assert_eq!(b.data(), s.data(), "PerChannel (dequantized)");
        // Int8 on a plain f32 matmul rides the f32x8 family — bit-equal to
        // the Simd engine (there are no integer inputs to exploit)
        let i = ops::matmul_serial_with(&x, &dq, KernelKind::Int8);
        assert_eq!(s.data(), i.data(), "PerChannel (int8 = f32x8 on plain matmul)");
    }

    #[test]
    fn int8_all_fused_layouts_match_reference_twin() {
        use crate::quant::{QConfig, QTensor};
        let mut rng = Rng::new(19);
        let x = rand_tensor(&mut rng, 6, 24);

        // PerTensor layout through a real QTensor
        let w = Tensor::randn(&[24, 18], 0.0, 0.5, &mut rng);
        let qt = QTensor::quantize(&w, &QConfig::baseline(4)).unwrap();
        let (codes, cid) = qt.fused_planes().unwrap();
        let main = split_matmul_int8(&x, qt.shape(), &codes, &cid, qt.params(), None);
        let oracle =
            split_matmul_int8_reference(&x, qt.shape(), &codes, &cid, qt.params(), None);
        assert_eq!(main.data(), oracle.data(), "PerTensor");

        // Split layout
        let params = vec![
            QParams::from_range(-0.4, 0.4, 2),
            QParams::from_range(-1.5, 1.5, 2),
            QParams::from_range(-0.05, 0.08, 2),
        ];
        let codes: Vec<i8> = (0..24 * 18).map(|v| ((v % 4) as i8) - 2).collect();
        let cid: Vec<u8> = (0..24 * 18).map(|v| (v % 3) as u8).collect();
        let main = split_matmul_int8(&x, &[24, 18], &codes, &cid, &params, None);
        let oracle = split_matmul_int8_reference(&x, &[24, 18], &codes, &cid, &params, None);
        assert_eq!(main.data(), oracle.data(), "Split");
    }

    #[test]
    fn ocs_act_escape_hatch_preserves_function_and_tightens_int8_error() {
        let mut rng = Rng::new(23);
        let (m, k, n) = (4usize, 24usize, 16usize);
        let mut x = Tensor::randn(&[m, k], 0.0, 0.5, &mut rng);
        // plant an outlier activation channel that stretches the range
        for r in 0..m {
            x.data_mut()[r * k + 5] = if r % 2 == 0 { 30.0 } else { -30.0 };
        }
        let (codes, cid, params) = rand_qweight(&mut rng, k, n, 4);
        let outliers = act_outlier_columns(&x, 4.0);
        assert!(outliers.contains(&5), "outlier channel not detected: {outliers:?}");
        let (xe, we, ce, ie) = ocs_expand_acts(&x, &[k, n], &codes, &cid, &outliers);
        assert_eq!(we, [k + outliers.len(), n]);

        // function preserved on the f32 path (up to summation order)
        let want = split_matmul(&x, &[k, n], &codes, &cid, &params);
        let got = split_matmul(&xe, &we, &ce, &ie, &params);
        assert!(got.max_abs_diff(&want) <= 1e-3, "{}", got.max_abs_diff(&want));

        // the integer engine gets a ~2× tighter activation scale out of it
        let int8_plain = split_matmul_int8(&x, &[k, n], &codes, &cid, &params, None);
        let int8_ocs = split_matmul_int8(&xe, &we, &ce, &ie, &params, None);
        let err = |t: &Tensor| t.max_abs_diff(&want) as f64;
        if cfg!(feature = "simd") {
            assert!(
                err(&int8_ocs) < err(&int8_plain),
                "ocs {} vs plain {}",
                err(&int8_ocs),
                err(&int8_plain)
            );
        } else {
            // feature off: both entries degrade to the same f32 engine
            assert!(err(&int8_ocs) <= 1e-3 && err(&int8_plain) <= 1e-5);
        }
    }

    #[test]
    fn fused_kernel_handles_tile_boundaries() {
        // shapes straddling the default 64×256 tiles, plus k % 4 != 0
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(3usize, 130usize, 300usize), (2, 67, 257), (1, 64, 256)] {
            let x = rand_tensor(&mut rng, m, k);
            let (codes, cid, params) = rand_qweight(&mut rng, k, n, 4);
            let want = reference_fused(&x, k, n, &codes, &cid, &params);
            let got = split_matmul(&x, &[k, n], &codes, &cid, &params);
            assert!(got.max_abs_diff(&want) <= 1e-5, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn property_int8_twins_and_partitions_are_bit_identical() {
        // the integer-engine contract: exact i32 accumulation + one shared
        // float epilogue ⇒ SIMD strips == scalar reference == pooled, as
        // exact equality (without the `simd` feature every path below
        // degrades to the same f32 engine and equality still holds)
        check("int8 SIMD/ref × serial/pooled exact", 40, |rng| {
            let m = rng.range(1, 24);
            let k = rng.range(1, 70);
            let n = rng.range(1, 70);
            let bits = [2u8, 4, 8][rng.below(3)];
            let mut x = rand_tensor(rng, m, k);
            zero_some_rows(&mut x, rng);
            let (codes, cid, params) = rand_qweight(rng, k, n, bits);
            let base = split_matmul_int8_reference(&x, &[k, n], &codes, &cid, &params, None);
            for got in [
                split_matmul_int8(&x, &[k, n], &codes, &cid, &params, None),
                split_matmul_serial_with(&x, &[k, n], &codes, &cid, &params, KernelKind::Int8),
                split_matmul_pooled_with(&x, &[k, n], &codes, &cid, &params, KernelKind::Int8),
            ] {
                assert_eq!(base.data(), got.data(), "{m}x{k}x{n} INT{bits}");
            }
            // calibrated activation params take the same route in both twins
            let p = QParams::from_range(-3.0, 3.0, 8);
            let a = split_matmul_int8(&x, &[k, n], &codes, &cid, &params, Some(&p));
            let b = split_matmul_int8_reference(&x, &[k, n], &codes, &cid, &params, Some(&p));
            assert_eq!(a.data(), b.data(), "calibrated {m}x{k}x{n}");
        });
    }

    #[test]
    fn property_int8_matches_float_within_act_quant_error() {
        // the int8 engine differs from the f32 fused path only by the
        // activation fake-quant: |x_fake − x| ≤ step/2 in range, so the
        // output gap is bounded by k · step/2 · max|dq(W)|
        check("int8 fused ≈ f32 fused (act-quant bounded)", 30, |rng| {
            let m = rng.range(1, 16);
            let k = rng.range(1, 41);
            let n = rng.range(1, 24);
            let bits = [2u8, 4, 8][rng.below(3)];
            let x = rand_tensor(rng, m, k);
            let (codes, cid, params) = rand_qweight(rng, k, n, bits);
            let want = reference_fused(&x, k, n, &codes, &cid, &params);
            let got = split_matmul_int8(&x, &[k, n], &codes, &cid, &params, None);
            if !cfg!(feature = "simd") {
                // degraded to the f32 scalar engine — plain tolerance
                assert!(got.max_abs_diff(&want) <= 1e-5);
                return;
            }
            let (lo, hi) = crate::util::stats::min_max(x.data());
            let step = (hi.max(0.0) - lo.min(0.0)).max(1e-8) / 255.0;
            let wmax = params
                .iter()
                .map(|p| {
                    let (dlo, dhi) = p.dequant_range();
                    dlo.abs().max(dhi.abs())
                })
                .fold(0.0f32, f32::max);
            let bound = k as f32 * step * wmax * 0.75 + 1e-3;
            assert!(
                got.max_abs_diff(&want) <= bound,
                "gap {} > bound {bound} at {m}x{k}x{n} INT{bits}",
                got.max_abs_diff(&want)
            );
        });
    }

    #[cfg(feature = "simd")]
    #[test]
    fn int8_epilogue_torture_ragged_shapes_and_layouts() {
        // the requantize-epilogue contract at the micro-kernel level:
        // i32→f32 dequant AND i32→i8 re-quant, SIMD strips vs scalar
        // reference, bit-identical across ragged shapes straddling the
        // lane width, per-tensor and split layouts, zero/empty rows
        use crate::tensor::simd::{
            matmul_rows_i8, matmul_rows_i8_ref, matmul_rows_i8_requant,
            matmul_rows_i8_requant_ref, quantize_acts_i8, I8Plane,
        };
        let mut rng = Rng::new(41);
        let dims = [1usize, 7, 8, 9, 63, 64, 65];
        let out_p = QParams::from_range(-6.0, 6.0, 8);
        for &k in &dims {
            for &n in &dims {
                for m in [1usize, 5] {
                    let mut x = rand_tensor(&mut rng, m, k);
                    zero_some_rows(&mut x, &mut rng);
                    let (lo, hi) = crate::util::stats::min_max(x.data());
                    let xp = QParams::from_range(lo.min(0.0), hi.max(0.0), 8);
                    let xc = quantize_acts_i8(x.data(), &xp);
                    let inv_x = 1.0 / xp.scale;
                    for &split in &[false, true] {
                        let (codes, cid, params) = loop {
                            let (c, id, p) = rand_qweight(&mut rng, k, n, 4);
                            if id.is_empty() != split {
                                break (c, id, p);
                            }
                        };
                        let zps: Vec<f32> = params.iter().map(|p| p.zp).collect();
                        let inv: Vec<f32> = params.iter().map(|p| 1.0 / p.scale).collect();
                        let plane =
                            I8Plane { codes: &codes, cid: &cid, zps: &zps, inv: &inv, k, n };
                        let mut a = vec![0.0f32; m * n];
                        let mut b = vec![0.0f32; m * n];
                        matmul_rows_i8(&xc, &plane, inv_x, &mut a, 0..m);
                        matmul_rows_i8_ref(&xc, &plane, inv_x, &mut b, 0..m);
                        for (u, v) in a.iter().zip(&b) {
                            assert_eq!(
                                u.to_bits(),
                                v.to_bits(),
                                "f32 epilogue {m}x{k}x{n} split={split}"
                            );
                        }
                        let mut qa = vec![0i8; m * n];
                        let mut qb = vec![0i8; m * n];
                        matmul_rows_i8_requant(&xc, &plane, inv_x, &out_p, &mut qa, 0..m);
                        matmul_rows_i8_requant_ref(&xc, &plane, inv_x, &out_p, &mut qb, 0..m);
                        assert_eq!(qa, qb, "i8 requant epilogue {m}x{k}x{n} split={split}");
                    }
                }
            }
        }
        // m = 0: empty row range writes nothing and must not panic
        let (codes, cid, params) = rand_qweight(&mut rng, 8, 8, 4);
        let zps: Vec<f32> = params.iter().map(|p| p.zp).collect();
        let inv: Vec<f32> = params.iter().map(|p| 1.0 / p.scale).collect();
        let plane = I8Plane { codes: &codes, cid: &cid, zps: &zps, inv: &inv, k: 8, n: 8 };
        let mut empty: Vec<f32> = vec![];
        matmul_rows_i8(&[], &plane, 1.0, &mut empty, 0..0);
        matmul_rows_i8_ref(&[], &plane, 1.0, &mut empty, 0..0);
        assert!(empty.is_empty());
    }

    #[test]
    fn big_matmul_is_bit_identical_across_engines() {
        // above the dispatch threshold: ops::matmul routes to the pool; the
        // row partition must not change the accumulation order at all
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[256, 96], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[96, 128], 0.0, 1.0, &mut rng);
        let par = matmul(&a, &b);
        let ser = ops::matmul_serial(&a, &b);
        assert_eq!(par.data(), ser.data(), "row partition must be bit-exact");
    }

    #[test]
    fn cluster_occupancy_counts_and_flags_dead_clusters() {
        assert_eq!(cluster_occupancy(&[]), [0, 0, 0]);
        assert_eq!(cluster_occupancy(&[1, 1, 1, 1]), [0, 4, 0]);
        assert_eq!(cluster_occupancy(&[0, 1, 2, 1, 2, 2]), [1, 2, 3]);
        // out-of-range ids (malformed plane) are ignored, not a panic
        assert_eq!(cluster_occupancy(&[0, 7, 2]), [1, 0, 1]);
        // matches a brute-force recount on a pseudo-random plane
        let mut rng = Rng::new(11);
        let plane: Vec<u8> = (0..999).map(|_| rng.below(3) as u8).collect();
        let occ = cluster_occupancy(&plane);
        for c in 0..3u8 {
            let n = plane.iter().filter(|&&v| v == c).count() as u64;
            assert_eq!(occ[c as usize], n, "cluster {c}");
        }
        assert_eq!(occ.iter().sum::<u64>(), 999);
    }
}
