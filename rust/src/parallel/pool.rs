//! Persistent scoped worker pool.
//!
//! One pool of long-lived threads serves every kernel in the process (see
//! [`super::global`]); callers submit a batch of borrowed closures with
//! [`WorkerPool::scope`], which blocks until all of them have run — the
//! rayon-style invariant that makes lending stack references to the pool
//! sound. Compared to spawning `std::thread::scope` threads per matmul this
//! removes ~50µs of thread start/stop from every dispatch, which at serving
//! batch sizes is the difference between a win and a regression.
//!
//! Nested use is detected via a thread-local flag: a task that itself calls
//! a parallel kernel runs it serially instead of deadlocking the pool (all
//! workers waiting on jobs only workers can run).

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::sync::{lock_recover, wait_recover};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    /// Signalled when jobs are pushed or the pool shuts down.
    available: Condvar,
}

/// Countdown latch: `scope` blocks on it until every submitted task ran.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { remaining: Mutex::new(n), done: Condvar::new(), panicked: AtomicBool::new(false) }
    }

    fn complete(&self, task_panicked: bool) {
        if task_panicked {
            self.panicked.store(true, Ordering::SeqCst);
        }
        let mut r = lock_recover(&self.remaining);
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = lock_recover(&self.remaining);
        while *r > 0 {
            r = wait_recover(&self.done, r);
        }
    }
}

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is a pool worker executing a task. Kernels
/// use this to fall back to their serial path instead of nesting scopes.
pub fn in_pool_worker() -> bool {
    IN_POOL.with(|f| f.get())
}

/// Fixed-size persistent thread pool with scoped (borrow-friendly) submits.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|wi| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("sq-pool-{wi}"))
                    .spawn(move || worker_loop(&shared))
                    // sq-lint: allow(no-panic-in-serving) — pool construction, not the request path: if the OS can't spawn a worker thread the process can't serve at all
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run all `tasks` on the pool and block until they finish. Tasks may
    /// borrow from the caller's stack: the blocking wait is what makes the
    /// internal lifetime erasure sound. Panics if any task panicked.
    pub fn scope<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if self.try_scope(tasks).is_err() {
            // sq-lint: allow(no-panic-in-serving) — deliberate re-raise: a task panic must surface on the submitting thread, not vanish in a worker (tests pin this contract)
            panic!("parallel: a pool task panicked");
        }
    }

    /// [`WorkerPool::scope`] for callers that must outlive task panics —
    /// the serving coordinator's degradation path. All tasks still run to
    /// completion (the latch waits for every one, panicked or not, so the
    /// borrow-soundness contract is identical), but a panic comes back as
    /// `Err` instead of unwinding the submitting thread; the pool itself is
    /// unharmed and the next scope runs normally.
    pub fn try_scope<'a>(
        &self,
        tasks: Vec<Box<dyn FnOnce() + Send + 'a>>,
    ) -> std::result::Result<(), PoolPanic> {
        if tasks.is_empty() {
            return Ok(());
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut q = lock_recover(&self.shared.queue);
            for task in tasks {
                // SAFETY: `try_scope` does not return until `latch.wait()`
                // has observed every task complete, so the borrows captured
                // in `task` are live for the whole time the pool can touch
                // it.
                let task: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(task)
                };
                let latch = latch.clone();
                q.jobs.push_back(Box::new(move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
                    latch.complete(r.is_err());
                }));
            }
        }
        self.shared.available.notify_all();
        latch.wait();
        if latch.panicked.load(Ordering::SeqCst) {
            Err(PoolPanic)
        } else {
            Ok(())
        }
    }
}

/// At least one task submitted to a [`WorkerPool::try_scope`] panicked. The
/// panic payload was consumed on the worker; the scope's remaining tasks all
/// ran to completion before this was returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolPanic;

impl std::fmt::Display for PoolPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a pool task panicked")
    }
}

impl std::error::Error for PoolPanic {}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        lock_recover(&self.shared.queue).shutdown = true;
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = lock_recover(&shared.queue);
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = wait_recover(&shared.available, q);
            }
        };
        IN_POOL.with(|f| f.set(true));
        // worker-utilization sampling: one relaxed load when tracing is off
        let t0 = crate::trace::enabled().then(std::time::Instant::now);
        job();
        if let Some(t0) = t0 {
            crate::trace::count("pool_tasks", 1);
            crate::trace::count("pool_busy_ns", t0.elapsed().as_nanos() as u64);
        }
        IN_POOL.with(|f| f.set(false));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scope_runs_every_task() {
        let pool = WorkerPool::new(4);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn scope_may_borrow_stack_data() {
        let pool = WorkerPool::new(2);
        let mut out = vec![0usize; 10];
        {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(3)
                .enumerate()
                .map(|(ci, chunk)| {
                    Box::new(move || {
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = ci * 100 + i;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(tasks);
        }
        assert_eq!(out, vec![0, 1, 2, 100, 101, 102, 200, 201, 202, 300]);
    }

    #[test]
    fn pool_survives_many_scopes() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..5)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(tasks);
        }
        assert_eq!(counter.load(Ordering::SeqCst), 250);
    }

    #[test]
    fn workers_report_in_pool() {
        let pool = WorkerPool::new(2);
        let saw = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    if in_pool_worker() {
                        saw.fetch_add(1, Ordering::SeqCst);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(saw.load(Ordering::SeqCst), 4);
        assert!(!in_pool_worker(), "caller thread is not a pool worker");
    }

    #[test]
    #[should_panic(expected = "pool task panicked")]
    fn task_panic_propagates_without_deadlock() {
        let pool = WorkerPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("boom")),
            Box::new(|| {}),
        ];
        pool.scope(tasks);
    }

    #[test]
    fn empty_scope_is_a_noop() {
        let pool = WorkerPool::new(1);
        pool.scope(Vec::new());
        assert!(pool.try_scope(Vec::new()).is_ok());
    }

    #[test]
    fn try_scope_reports_panic_without_unwinding() {
        let pool = WorkerPool::new(2);
        let survivors = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("boom")),
            Box::new(|| {
                survivors.fetch_add(1, Ordering::SeqCst);
            }),
            Box::new(|| {
                survivors.fetch_add(1, Ordering::SeqCst);
            }),
        ];
        assert_eq!(pool.try_scope(tasks), Err(PoolPanic));
        // sibling tasks of the panicking one still ran to completion
        assert_eq!(survivors.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn pool_serves_the_next_batch_after_a_poisoned_one() {
        let pool = WorkerPool::new(2);
        let poisoned: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("boom")), Box::new(|| {})];
        assert!(pool.try_scope(poisoned).is_err());
        // the pool is unharmed: the next scope runs every task
        let counter = AtomicUsize::new(0);
        let next: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        assert!(pool.try_scope(next).is_ok());
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }
}
