//! Parallel kernel engine (§Perf): a persistent worker pool plus
//! cache-blocked, row-partitioned kernels for the inference hot path.
//!
//! SplitQuant's mathematically-equivalent layer splitting (paper §4) — and
//! the OCS baseline's channel duplication — inflate every quantized matmul,
//! so the serial scalar kernels in [`crate::tensor::ops`] bound end-to-end
//! throughput. This subsystem provides:
//!
//! * [`pool::WorkerPool`] — one process-wide pool of persistent threads
//!   with rayon-style scoped submits (borrowed closures, blocking join).
//!   The serving coordinator's workers all share it instead of each
//!   oversubscribing the machine.
//! * [`kernels`] — parallel `matmul` / `batch_matmul` and the fused
//!   split-dequant matmul that reconstructs weight tiles from int codes +
//!   cluster ids on the fly (no full FP32 weight materialization).
//! * [`ParallelConfig`] — thread count, tile sizes, and the serial-fallback
//!   threshold, applied process-wide via [`configure`].
//!
//! Dispatch contract: `ops::matmul` and friends route through
//! [`should_parallelize`], which returns `false` for small problems, for
//! single-threaded configs, and from inside pool workers (nested parallel
//! sections run serially instead of deadlocking). Property tests assert the
//! parallel kernels match the serial ones within 1e-5 on every shape class
//! (`k % 4 != 0`, `m = 1`, zero-padded rows included).

pub mod kernels;
pub mod pool;

use std::sync::OnceLock;

pub use pool::{PoolPanic, WorkerPool};

/// Which micro-kernel family the engine executes.
///
/// The f32 families are **bit-identical** (the SIMD kernels replay the
/// scalar kernels' exact IEEE operation sequence per output element — see
/// [`crate::tensor::simd`]), so `Scalar` vs `Simd` is purely a performance
/// knob. `Int8` changes the *datapath* of fused quantized-weight matmuls —
/// activations are quantized per call and products accumulate exactly in
/// i32 until a float epilogue — so its outputs differ from the f32 engines
/// by the activation quantization error, while its own SIMD and scalar
/// reference twins stay bit-identical to each other (integer accumulation
/// is exact in any order). `Simd`/`Int8` silently degrade to `Scalar` when
/// the crate is built without the `simd` feature
/// ([`KernelKind::effective`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// The auto-vectorized scalar quad kernels (the only engine before the
    /// `simd` feature existed; always compiled, always the fallback).
    Scalar,
    /// Explicit f32x8 tile kernels: packed-B panels + register
    /// accumulation for `matmul_rows`, 8-lane in-register dequant for the
    /// fused split-dequant tiles.
    Simd,
    /// Integer datapath for the fused split-dequant matmul: activations
    /// are quantized to i8 per call, products accumulate in i32 with
    /// per-cluster zero-point correction folded into the integer plane,
    /// and f32 only appears in the requantize/dequantize epilogue (see
    /// [`crate::tensor::simd`]'s i8 kernel family). Plain f32×f32 matmuls
    /// have no integer inputs to exploit and run the f32x8 family.
    Int8,
}

impl Default for KernelKind {
    /// `Simd` when compiled in, `Scalar` otherwise.
    fn default() -> Self {
        if cfg!(feature = "simd") {
            KernelKind::Simd
        } else {
            KernelKind::Scalar
        }
    }
}

impl KernelKind {
    /// Parse a CLI flag value (`"scalar"` | `"simd"` | `"int8"`), shared
    /// by the example/CLI surfaces; `None` for anything else. The parsed
    /// `Simd`/`Int8` still degrade through [`KernelKind::effective`] when
    /// the feature is compiled out.
    pub fn from_flag(s: &str) -> Option<KernelKind> {
        match s {
            "scalar" => Some(KernelKind::Scalar),
            "simd" => Some(KernelKind::Simd),
            "int8" => Some(KernelKind::Int8),
            _ => None,
        }
    }

    /// The kind that will actually execute: `Simd` and `Int8` require the
    /// `simd` feature (the integer kernels live in [`crate::tensor::simd`]
    /// next to their f32x8 siblings); without it every request degrades to
    /// `Scalar`.
    pub fn effective(self) -> KernelKind {
        if cfg!(feature = "simd") {
            self
        } else {
            KernelKind::Scalar
        }
    }
}

/// Tuning knobs for the kernel engine. Process-wide: the first
/// [`configure`] (or the first kernel dispatch, whichever comes first)
/// freezes the values for the lifetime of the process, because the pool
/// threads are spawned once and shared by every subsystem.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Worker threads. `0` = auto: `SPLITQUANT_THREADS` env var if set,
    /// otherwise `std::thread::available_parallelism()`.
    pub threads: usize,
    /// Fused-kernel k-tile (rows of W dequantized per scratch refill);
    /// rounded down to a multiple of 4 to keep quad boundaries aligned
    /// with the serial kernel's unroll.
    pub tile_k: usize,
    /// Fused-kernel n-tile (scratch width); `tile_k * tile_n * 4` bytes of
    /// scratch per worker, sized to stay cache-resident.
    pub tile_n: usize,
    /// Problems below this many FLOPs (2·m·k·n for a matmul) stay on the
    /// calling thread: pool dispatch costs ~1–2µs and small serving shapes
    /// (batch-1 forward) are latency-sensitive.
    pub serial_flops: usize,
    /// Micro-kernel family for the matmul / fused split-dequant hot paths.
    /// Defaults to [`KernelKind::Simd`] when the `simd` feature is
    /// compiled in; `Scalar` and `Simd` are bit-identical, while
    /// [`KernelKind::Int8`] switches fused quantized-weight matmuls to the
    /// integer datapath (dynamic activation quantization — differs from the
    /// f32 engines only by that quantization error). Surfaced in
    /// `ServeConfig.parallel`.
    pub kernel: KernelKind,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            threads: 0,
            tile_k: 64,
            tile_n: 256,
            serial_flops: 4_000_000,
            kernel: KernelKind::default(),
        }
    }
}

impl ParallelConfig {
    /// Effective worker-thread count after env/auto resolution.
    pub fn resolve_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        if let Some(n) = std::env::var("SPLITQUANT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
        {
            return n;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

static CONFIG: OnceLock<ParallelConfig> = OnceLock::new();
static POOL: OnceLock<WorkerPool> = OnceLock::new();
static THREADS: OnceLock<usize> = OnceLock::new();

/// Install a process-wide config. Returns `false` (and changes nothing) if
/// the engine was already configured — first caller wins, so set it before
/// the first parallel kernel runs (e.g. from `Server::start`).
pub fn configure(cfg: ParallelConfig) -> bool {
    CONFIG.set(cfg).is_ok()
}

/// The effective process-wide config (defaults if [`configure`] never ran).
pub fn config() -> &'static ParallelConfig {
    CONFIG.get_or_init(ParallelConfig::default)
}

/// Effective worker-thread count, resolved once (env var / syscall are not
/// re-consulted on the per-matmul dispatch path).
pub fn effective_threads() -> usize {
    *THREADS.get_or_init(|| config().resolve_threads())
}

/// The shared process-wide pool, spawned lazily on first use.
pub fn global() -> &'static WorkerPool {
    POOL.get_or_init(|| WorkerPool::new(effective_threads()))
}

/// Should a kernel of `flops` total work fan out to the pool?
pub fn should_parallelize(flops: usize) -> bool {
    let cfg = config();
    flops >= cfg.serial_flops && !pool::in_pool_worker() && effective_threads() > 1
}

/// The process-wide micro-kernel choice after the feature-gate fallback —
/// what the no-suffix kernel entry points (`ops::matmul`,
/// `kernels::split_matmul`, …) execute. The `_with` variants take an
/// explicit [`KernelKind`] instead, so benches and property tests can pit
/// the engines against each other inside one process.
pub fn kernel_kind() -> KernelKind {
    config().kernel.effective()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_resolves_at_least_one_thread() {
        assert!(ParallelConfig::default().resolve_threads() >= 1);
    }

    #[test]
    fn explicit_thread_count_wins() {
        let cfg = ParallelConfig { threads: 3, ..ParallelConfig::default() };
        assert_eq!(cfg.resolve_threads(), 3);
    }

    #[test]
    fn small_problems_stay_serial() {
        // 2·8·8·8 = 1024 flops is far below any sane serial_flops
        assert!(!should_parallelize(1024));
    }

    #[test]
    fn kernel_kind_parses_cli_flags() {
        assert_eq!(KernelKind::from_flag("scalar"), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::from_flag("simd"), Some(KernelKind::Simd));
        assert_eq!(KernelKind::from_flag("int8"), Some(KernelKind::Int8));
        assert_eq!(KernelKind::from_flag("avx512"), None);
    }

    #[test]
    fn kernel_kind_degrades_without_the_feature() {
        assert_eq!(KernelKind::Scalar.effective(), KernelKind::Scalar);
        if cfg!(feature = "simd") {
            assert_eq!(KernelKind::Simd.effective(), KernelKind::Simd);
            assert_eq!(KernelKind::Int8.effective(), KernelKind::Int8);
            assert_eq!(KernelKind::default(), KernelKind::Simd);
        } else {
            assert_eq!(KernelKind::Simd.effective(), KernelKind::Scalar);
            assert_eq!(KernelKind::Int8.effective(), KernelKind::Scalar);
            assert_eq!(KernelKind::default(), KernelKind::Scalar);
        }
    }
}
