//! Training orchestration: the Rust coordinator drives the AOT-compiled
//! fused train-step executable (fwd + bwd + Adam inside one XLA graph).
//!
//! The coordinator owns all state (parameters, Adam moments, step counter,
//! RNG, data order); XLA owns only the math. One `step()` feeds
//! `3·P + 5` literals and ingests `3·P + 1` back.

pub mod schedule;

use crate::data::batch::TextBatcher;
use crate::error::{Error, Result};
use crate::model::params::ParamStore;
use crate::runtime::literal::Value;
use crate::runtime::Runtime;
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

pub use schedule::LrSchedule;

/// Progress record for one logged step.
#[derive(Debug, Clone)]
pub struct TrainLogEntry {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    pub elapsed: std::time::Duration,
}

/// Drives `bert_train_step_b{B}` (or `cnn_train_step_b{B}`).
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    exe: std::sync::Arc<crate::runtime::LoadedExe>,
    pub store: ParamStore,
    adam_m: Vec<Tensor>,
    adam_v: Vec<Tensor>,
    pub step: usize,
    pub log: Vec<TrainLogEntry>,
}

impl<'rt> Trainer<'rt> {
    /// Create from an initialized parameter store.
    pub fn new(rt: &'rt Runtime, exe_name: &str, store: ParamStore) -> Result<Self> {
        let exe = rt.load(exe_name)?;
        let nparams = store.len();
        // text steps take (step, ids, mask, labels, lr); image steps
        // (step, images, labels, lr)
        let got = exe.spec.inputs.len();
        if got != 3 * nparams + 5 && got != 3 * nparams + 4 {
            return Err(Error::Runtime(format!(
                "{exe_name}: {got} inputs do not match {nparams} params (want 3P+4 or 3P+5)"
            )));
        }
        let adam_m = store.flat_tensors().map(|t| Tensor::zeros(t.shape())).collect();
        let adam_v = store.flat_tensors().map(|t| Tensor::zeros(t.shape())).collect();
        Ok(Trainer { rt, exe, store, adam_m, adam_v, step: 0, log: Vec::new() })
    }

    /// One optimizer step on a (ids, mask, labels) batch. Returns the loss.
    pub fn step_batch(&mut self, ids: &IntTensor, mask: &Tensor, labels: &IntTensor, lr: f32) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let n = self.store.len();
        let mut inputs: Vec<Value> = Vec::with_capacity(3 * n + 5);
        inputs.extend(self.store.flat_tensors().map(|t| Value::F32(t.clone())));
        inputs.extend(self.adam_m.iter().map(|t| Value::F32(t.clone())));
        inputs.extend(self.adam_v.iter().map(|t| Value::F32(t.clone())));
        inputs.push(Value::I32(IntTensor::new(&[1], vec![self.step as i32])?));
        inputs.push(Value::I32(ids.clone()));
        inputs.push(Value::F32(mask.clone()));
        inputs.push(Value::I32(labels.clone()));
        inputs.push(Value::F32(Tensor::new(&[1], vec![lr])?));

        let mut out = self.exe.run(&inputs)?;
        if out.len() != 3 * n + 1 {
            return Err(Error::Runtime(format!(
                "train step returned {} outputs, expected {}",
                out.len(),
                3 * n + 1
            )));
        }
        let loss = out.pop().unwrap().into_f32()?.data()[0];
        let new_v: Vec<Tensor> =
            out.drain(2 * n..).map(|v| v.into_f32()).collect::<Result<_>>()?;
        let new_m: Vec<Tensor> =
            out.drain(n..).map(|v| v.into_f32()).collect::<Result<_>>()?;
        let new_p: Vec<Tensor> = out.into_iter().map(|v| v.into_f32()).collect::<Result<_>>()?;
        self.store.replace_flat(new_p)?;
        self.adam_m = new_m;
        self.adam_v = new_v;
        self.step += 1;
        if !loss.is_finite() {
            return Err(Error::Runtime(format!("loss diverged at step {}", self.step)));
        }
        self.log.push(TrainLogEntry {
            step: self.step,
            loss,
            lr,
            elapsed: t0.elapsed(),
        });
        Ok(loss)
    }

    /// One optimizer step on an image batch (`cnn_train_step_b{B}` signature:
    /// params, m, v, step, images, labels, lr).
    pub fn step_images(&mut self, images: &Tensor, labels: &IntTensor, lr: f32) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let n = self.store.len();
        let mut inputs: Vec<Value> = Vec::with_capacity(3 * n + 4);
        inputs.extend(self.store.flat_tensors().map(|t| Value::F32(t.clone())));
        inputs.extend(self.adam_m.iter().map(|t| Value::F32(t.clone())));
        inputs.extend(self.adam_v.iter().map(|t| Value::F32(t.clone())));
        inputs.push(Value::I32(IntTensor::new(&[1], vec![self.step as i32])?));
        inputs.push(Value::F32(images.clone()));
        inputs.push(Value::I32(labels.clone()));
        inputs.push(Value::F32(Tensor::new(&[1], vec![lr])?));

        let mut out = self.exe.run(&inputs)?;
        let loss = out.pop().unwrap().into_f32()?.data()[0];
        let new_v: Vec<Tensor> =
            out.drain(2 * n..).map(|v| v.into_f32()).collect::<Result<_>>()?;
        let new_m: Vec<Tensor> =
            out.drain(n..).map(|v| v.into_f32()).collect::<Result<_>>()?;
        let new_p: Vec<Tensor> = out.into_iter().map(|v| v.into_f32()).collect::<Result<_>>()?;
        self.store.replace_flat(new_p)?;
        self.adam_m = new_m;
        self.adam_v = new_v;
        self.step += 1;
        if !loss.is_finite() {
            return Err(Error::Runtime(format!("loss diverged at step {}", self.step)));
        }
        self.log.push(TrainLogEntry { step: self.step, loss, lr, elapsed: t0.elapsed() });
        Ok(loss)
    }

    /// Train for `steps` over a text batcher with a schedule; logs every
    /// `log_every` steps via the `progress` callback.
    pub fn train_text(
        &mut self,
        batcher: &mut TextBatcher,
        steps: usize,
        schedule: &LrSchedule,
        rng: &mut Rng,
        log_every: usize,
        mut progress: impl FnMut(&TrainLogEntry),
    ) -> Result<Vec<f32>> {
        let mut losses = Vec::with_capacity(steps);
        let steps_per_epoch = (batcher.len() / batcher.batch_size).max(1);
        for s in 0..steps {
            if s % steps_per_epoch == 0 {
                batcher.shuffle(rng);
            }
            let b = batcher.next_batch();
            let lr = schedule.lr_at(self.step, steps);
            let loss = self.step_batch(&b.ids, &b.mask, &b.labels, lr)?;
            losses.push(loss);
            if log_every > 0 && (s + 1) % log_every == 0 {
                progress(self.log.last().unwrap());
            }
        }
        Ok(losses)
    }

    /// Smoothed final loss (mean of the last k entries).
    pub fn final_loss(&self, k: usize) -> f32 {
        let n = self.log.len();
        if n == 0 {
            return f32::NAN;
        }
        let k = k.min(n);
        self.log[n - k..].iter().map(|e| e.loss).sum::<f32>() / k as f32
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}
