//! Learning-rate schedules.

/// Warmup + decay schedules for the Adam-in-graph trainer.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    /// Constant rate.
    Constant(f32),
    /// Linear warmup over `warmup` steps, then linear decay to `floor`.
    WarmupLinear { peak: f32, warmup: usize, floor: f32 },
}

impl LrSchedule {
    /// LR for `step` (0-based) of `total` steps.
    pub fn lr_at(&self, step: usize, total: usize) -> f32 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::WarmupLinear { peak, warmup, floor } => {
                if step < warmup {
                    peak * (step + 1) as f32 / warmup as f32
                } else if total <= warmup {
                    peak
                } else {
                    let p = (step - warmup) as f32 / (total - warmup).max(1) as f32;
                    floor + (peak - floor) * (1.0 - p.min(1.0))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant() {
        let s = LrSchedule::Constant(0.1);
        assert_eq!(s.lr_at(0, 100), 0.1);
        assert_eq!(s.lr_at(99, 100), 0.1);
    }

    #[test]
    fn warmup_then_decay() {
        let s = LrSchedule::WarmupLinear { peak: 1.0, warmup: 10, floor: 0.0 };
        assert!((s.lr_at(0, 110) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(9, 110) - 1.0).abs() < 1e-6);
        assert!(s.lr_at(50, 110) < 1.0);
        assert!(s.lr_at(109, 110) < 0.05);
        // monotone decay after warmup
        let mut prev = f32::INFINITY;
        for step in 10..110 {
            let lr = s.lr_at(step, 110);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }
}
