//! Greedy k-means++ seeding (paper ref [6]: Grunau, Özüdoğru, Rozhoň, Tětek,
//! SODA 2023).
//!
//! Standard k-means++ samples each new center from the D² distribution once;
//! the *greedy* variant draws `l ≈ 2 + ⌈log k⌉` candidates per round and
//! keeps the one that minimizes the resulting potential, which provably
//! tightens the approximation factor.

use crate::util::rng::Rng;

/// Number of candidates per greedy round.
pub fn greedy_candidates(k: usize) -> usize {
    2 + (k as f64).log2().ceil().max(0.0) as usize
}

/// Pick `k` initial centers from `values` with greedy k-means++.
///
/// Returns centers sorted ascending. Handles degenerate inputs (fewer
/// distinct values than `k`, constant data) by allowing duplicate centers —
/// Lloyd's empty-cluster repair deals with those downstream.
pub fn greedy_kmeanspp(values: &[f32], k: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(k >= 1, "k must be >= 1");
    assert!(!values.is_empty(), "cannot seed on empty data");
    let n = values.len();
    let mut centers = Vec::with_capacity(k);

    // first center: uniform
    centers.push(values[rng.below(n)]);

    // d2[i] = squared distance to the nearest chosen center
    let mut d2: Vec<f64> = values
        .iter()
        .map(|&v| {
            let d = (v - centers[0]) as f64;
            d * d
        })
        .collect();

    let l = greedy_candidates(k);
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        let mut best_candidate = None;
        let mut best_potential = f64::INFINITY;
        for _ in 0..l {
            let idx = if total <= 0.0 {
                // all points coincide with existing centers: uniform fallback
                rng.below(n)
            } else {
                sample_d2(&d2, total, rng)
            };
            let cand = values[idx];
            // potential if we were to add this candidate
            let pot: f64 = d2
                .iter()
                .zip(values)
                .map(|(&cur, &v)| {
                    let d = (v - cand) as f64;
                    cur.min(d * d)
                })
                .sum();
            if pot < best_potential {
                best_potential = pot;
                best_candidate = Some(cand);
            }
        }
        let c = best_candidate.expect("at least one candidate");
        for (cur, &v) in d2.iter_mut().zip(values) {
            let d = (v - c) as f64;
            *cur = cur.min(d * d);
        }
        centers.push(c);
    }

    centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
    centers
}

fn sample_d2(d2: &[f64], total: f64, rng: &mut Rng) -> usize {
    let mut t = rng.f64() * total;
    for (i, &w) in d2.iter().enumerate() {
        t -= w;
        if t <= 0.0 {
            return i;
        }
    }
    d2.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn candidate_count() {
        assert_eq!(greedy_candidates(1), 2);
        assert_eq!(greedy_candidates(2), 3);
        assert_eq!(greedy_candidates(3), 4);
        assert_eq!(greedy_candidates(8), 5);
    }

    #[test]
    fn centers_come_from_data_and_are_sorted() {
        let mut rng = Rng::new(0);
        let values: Vec<f32> = (0..100).map(|i| (i as f32) * 0.5 - 25.0).collect();
        let c = greedy_kmeanspp(&values, 3, &mut rng);
        assert_eq!(c.len(), 3);
        assert!(c.windows(2).all(|w| w[0] <= w[1]));
        for x in &c {
            assert!(values.contains(x));
        }
    }

    #[test]
    fn separated_blobs_get_one_center_each() {
        // three tight, far-apart blobs: greedy ++ must land one center in each
        let mut values = Vec::new();
        let mut rng = Rng::new(42);
        for &center in &[-100.0f32, 0.0, 100.0] {
            for _ in 0..50 {
                values.push(center + rng.normal_f32(0.0, 0.1));
            }
        }
        for seed in 0..20 {
            let mut r = Rng::new(seed);
            let c = greedy_kmeanspp(&values, 3, &mut r);
            assert!(c[0] < -90.0, "seed {seed}: {c:?}");
            assert!(c[1].abs() < 10.0, "seed {seed}: {c:?}");
            assert!(c[2] > 90.0, "seed {seed}: {c:?}");
        }
    }

    #[test]
    fn constant_data_does_not_panic() {
        let mut rng = Rng::new(1);
        let values = vec![2.5f32; 40];
        let c = greedy_kmeanspp(&values, 3, &mut rng);
        assert_eq!(c, vec![2.5, 2.5, 2.5]);
    }

    #[test]
    fn fewer_points_than_k() {
        let mut rng = Rng::new(2);
        let c = greedy_kmeanspp(&[1.0, 2.0], 3, &mut rng);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn property_centers_subset_of_values() {
        check("kmeans++ centers ⊆ data", 40, |rng| {
            let n = rng.range(1, 200);
            let k = rng.range(1, 6);
            let values: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 3.0)).collect();
            let c = greedy_kmeanspp(&values, k, rng);
            assert_eq!(c.len(), k);
            for x in &c {
                assert!(values.iter().any(|v| v == x));
            }
        });
    }
}
