//! Optimized 1-D k-means (the production path for big weight tensors).
//!
//! For 1-D data with sorted centroids, the nearest-centroid assignment is a
//! set of k−1 boundary midpoints, so each Lloyd iteration needs only
//! O(k log n) boundary bisection + O(k) centroid updates over prefix sums —
//! after a single O(n log n) sort. On the 1M-element BERT-Tiny token
//! embedding this is ~40× faster than the generic O(n·k)-per-iteration loop
//! (see EXPERIMENTS.md §Perf) and produces identical clusters from the same
//! initialization (property tested against [`super::kmeans`]).

use crate::util::rng::Rng;

use super::init::greedy_kmeanspp;
use super::kmeans::KMeansResult;
#[cfg(test)]
use super::kmeans::lloyd_generic;

/// Threshold below which the generic path is used (sorting overhead is not
/// worth it, and tiny inputs hit more degenerate-repair corner cases).
const SMALL_N: usize = 512;

/// Lloyd on pre-sorted values. Returns (sorted-order assignment, result).
fn lloyd_sorted(sorted: &[f32], init: &[f32], max_iter: usize) -> KMeansResult {
    let n = sorted.len();
    let k = init.len();
    debug_assert!(k >= 1 && n >= 1);

    // prefix sums for O(1) range means
    let mut prefix = vec![0f64; n + 1];
    for (i, &v) in sorted.iter().enumerate() {
        prefix[i + 1] = prefix[i] + v as f64;
    }

    let mut centroids = init.to_vec();
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // segment start index per cluster; segment c = [starts[c], starts[c+1])
    let mut starts = boundaries(sorted, &centroids);
    let mut iterations = 0;

    for _ in 0..max_iter {
        iterations += 1;
        let mut new_centroids = centroids.clone();
        let mut any_empty = false;
        for c in 0..k {
            let (lo, hi) = (starts[c], starts[c + 1]);
            if hi > lo {
                new_centroids[c] = ((prefix[hi] - prefix[lo]) / (hi - lo) as f64) as f32;
            } else {
                any_empty = true;
            }
        }
        if any_empty {
            // Degenerate-cluster repair, mirroring `lloyd_generic`: re-seed
            // every empty cluster on the point farthest from its (updated)
            // assigned centroid. Without this the sorted path kept stale
            // centroids while the generic path repaired them, so the two
            // diverged on duplicate/clustered data (empty segments are
            // common when k exceeds the number of distinct values).
            let mut far_val = sorted[0];
            let mut far_d = f32::NEG_INFINITY;
            for c in 0..k {
                for &v in &sorted[starts[c]..starts[c + 1]] {
                    let d = (v - new_centroids[c]).abs();
                    if d >= far_d {
                        far_d = d;
                        far_val = v;
                    }
                }
            }
            for c in 0..k {
                if starts[c + 1] == starts[c] {
                    new_centroids[c] = far_val;
                }
            }
        }
        new_centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let new_starts = boundaries(sorted, &new_centroids);
        let converged = new_starts == starts && new_centroids == centroids;
        centroids = new_centroids;
        starts = new_starts;
        if converged {
            break;
        }
    }

    let mut assignment = vec![0u8; n];
    for c in 0..k {
        for a in assignment[starts[c]..starts[c + 1]].iter_mut() {
            *a = c as u8;
        }
    }
    let inertia = sorted
        .iter()
        .zip(&assignment)
        .map(|(&v, &a)| {
            let d = (v - centroids[a as usize]) as f64;
            d * d
        })
        .sum();
    KMeansResult { centroids, assignment, inertia, iterations }
}

/// Segment start indices (length k+1) for sorted values & sorted centroids.
/// Boundary between clusters c and c+1 is the midpoint; ties go to the lower
/// cluster (matching the generic `assign` tie rule).
fn boundaries(sorted: &[f32], centroids: &[f32]) -> Vec<usize> {
    let k = centroids.len();
    let mut starts = vec![0usize; k + 1];
    starts[k] = sorted.len();
    for c in 1..k {
        let mid = 0.5 * (centroids[c - 1] + centroids[c]);
        // first index with value > mid  (value == mid stays in lower cluster)
        starts[c] = sorted.partition_point(|&v| v <= mid).max(starts[c - 1]);
    }
    // enforce monotone (duplicate centroids can produce equal midpoints)
    for c in 1..k {
        if starts[c] < starts[c - 1] {
            starts[c] = starts[c - 1];
        }
    }
    starts
}

/// Full production run: greedy k-means++ init + fast sorted Lloyd, assignment
/// returned in the *original* value order.
pub fn cluster(values: &[f32], k: usize, max_iter: usize, rng: &mut Rng) -> KMeansResult {
    assert!(!values.is_empty() && k >= 1);
    if values.len() < SMALL_N || k == 1 {
        return super::kmeans::kmeans(values, k, max_iter, rng);
    }
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    idx.sort_by(|&a, &b| values[a as usize].partial_cmp(&values[b as usize]).unwrap());
    let sorted: Vec<f32> = idx.iter().map(|&i| values[i as usize]).collect();

    let init = greedy_kmeanspp(&sorted, k, rng);
    let r = lloyd_sorted(&sorted, &init, max_iter);

    let mut assignment = vec![0u8; values.len()];
    for (pos, &orig) in idx.iter().enumerate() {
        assignment[orig as usize] = r.assignment[pos];
    }
    KMeansResult {
        centroids: r.centroids,
        assignment,
        inertia: r.inertia,
        iterations: r.iterations,
    }
}

/// Run Lloyd from explicit init on unsorted values via the fast path
/// (exposed for the equivalence property tests).
pub fn lloyd_fast(values: &[f32], init: &[f32], max_iter: usize) -> KMeansResult {
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    idx.sort_by(|&a, &b| values[a as usize].partial_cmp(&values[b as usize]).unwrap());
    let sorted: Vec<f32> = idx.iter().map(|&i| values[i as usize]).collect();
    let r = lloyd_sorted(&sorted, init, max_iter);
    let mut assignment = vec![0u8; values.len()];
    for (pos, &orig) in idx.iter().enumerate() {
        assignment[orig as usize] = r.assignment[pos];
    }
    KMeansResult {
        centroids: r.centroids,
        assignment,
        inertia: r.inertia,
        iterations: r.iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen_values_with_outliers};

    fn assert_matches_generic(values: &[f32], k: usize, rng: &mut crate::util::rng::Rng) {
        let init = crate::clustering::init::greedy_kmeanspp(values, k, rng);
        let fast = lloyd_fast(values, &init, 40);
        let gen = lloyd_generic(values, &init, 40);
        // identical partition quality (assignments may differ only on
        // exact midpoint ties, which have equal cost)
        assert!(
            (fast.inertia - gen.inertia).abs() <= 1e-5 * (1.0 + gen.inertia.abs()),
            "fast {} vs generic {} (n={}, k={k})",
            fast.inertia,
            gen.inertia,
            values.len()
        );
    }

    #[test]
    fn matches_generic_from_same_init() {
        check("fast lloyd == generic lloyd", 30, |rng| {
            let n = rng.range(8, 1500);
            let values = gen_values_with_outliers(rng, n, 0.05);
            let k = rng.range(2, 5);
            assert_matches_generic(&values, k, rng);
        });
    }

    #[test]
    fn matches_generic_on_duplicate_heavy_data() {
        // duplicate/clustered values force empty segments during Lloyd;
        // before the sorted path gained the degenerate-cluster repair it
        // kept stale centroids here and diverged from the generic path
        check("fast lloyd == generic lloyd (duplicates)", 30, |rng| {
            let n = rng.range(8, 800);
            let distinct = rng.range(1, 6);
            // jittered levels: heavy duplication without exact-midpoint
            // distance ties (which both paths may break differently)
            let levels: Vec<f32> =
                (0..distinct).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut values: Vec<f32> =
                (0..n).map(|_| levels[rng.below(distinct)]).collect();
            if rng.chance(0.5) {
                values.push(40.0); // lone outlier on top of the duplicates
            }
            let k = rng.range(2, 5);
            assert_matches_generic(&values, k, rng);
        });
    }

    #[test]
    fn repair_resolves_empty_clusters_like_generic() {
        // deterministic regression: k=3 with an init that leaves the middle
        // centroid's segment empty on duplicate data
        let values: Vec<f32> = [0.0f32; 600]
            .iter()
            .chain([10.0f32; 600].iter())
            .copied()
            .collect();
        let init = vec![0.0f32, 4.0, 10.0];
        let fast = lloyd_fast(&values, &init, 40);
        let gen = lloyd_generic(&values, &init, 40);
        assert!(
            (fast.inertia - gen.inertia).abs() <= 1e-5 * (1.0 + gen.inertia.abs()),
            "fast {} vs generic {}",
            fast.inertia,
            gen.inertia
        );
        // both must land on zero inertia: every point sits on a centroid
        assert!(fast.inertia <= 1e-9, "repair failed: inertia {}", fast.inertia);
    }

    #[test]
    fn production_cluster_on_large_input() {
        let mut rng = Rng::new(0);
        let mut values = Vec::new();
        for &c in &[-8.0f32, 0.0, 8.0] {
            for _ in 0..2000 {
                values.push(c + rng.normal_f32(0.0, 0.3));
            }
        }
        let r = cluster(&values, 3, 50, &mut rng);
        assert!((r.centroids[0] + 8.0).abs() < 0.3);
        assert!(r.centroids[1].abs() < 0.3);
        assert!((r.centroids[2] - 8.0).abs() < 0.3);
        assert_eq!(r.cluster_sizes(), vec![2000, 2000, 2000]);
    }

    #[test]
    fn assignment_order_is_preserved() {
        let mut rng = Rng::new(5);
        let values: Vec<f32> = (0..4000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let r = cluster(&values, 3, 50, &mut rng);
        // nearest-centroid invariant holds in the ORIGINAL order
        for (&v, &a) in values.iter().zip(&r.assignment) {
            let d_assigned = (v - r.centroids[a as usize]).abs();
            for &c in &r.centroids {
                assert!(d_assigned <= (v - c).abs() + 1e-6);
            }
        }
    }

    #[test]
    fn boundaries_tie_goes_lower() {
        let sorted = vec![-1.0f32, 0.0, 1.0];
        let cents = vec![-1.0f32, 1.0];
        let b = boundaries(&sorted, &cents);
        // midpoint is 0.0; the 0.0 value belongs to the lower cluster
        assert_eq!(b, vec![0, 2, 3]);
    }

    #[test]
    fn small_inputs_fall_back() {
        let mut rng = Rng::new(7);
        let values = vec![1.0f32, 2.0, 100.0];
        let r = cluster(&values, 2, 20, &mut rng);
        assert_eq!(r.assignment[2], 1);
        assert_eq!(r.assignment[0], 0);
    }
}
