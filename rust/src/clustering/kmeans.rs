//! Generic Lloyd's algorithm for 1-D data (reference implementation).

use crate::util::rng::Rng;

use super::init::greedy_kmeanspp;

/// Result of a k-means run.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    /// Cluster centers, sorted ascending (lower / middle / upper for k=3).
    pub centroids: Vec<f32>,
    /// Per-value cluster index into `centroids`.
    pub assignment: Vec<u8>,
    /// Sum of squared distances to assigned centers.
    pub inertia: f64,
    /// Lloyd iterations executed.
    pub iterations: usize,
}

impl KMeansResult {
    /// Per-cluster (min, max) value ranges; `None` for empty clusters.
    pub fn cluster_ranges(&self, values: &[f32]) -> Vec<Option<(f32, f32)>> {
        let k = self.centroids.len();
        let mut ranges: Vec<Option<(f32, f32)>> = vec![None; k];
        for (&v, &a) in values.iter().zip(&self.assignment) {
            let e = &mut ranges[a as usize];
            *e = Some(match *e {
                None => (v, v),
                Some((lo, hi)) => (lo.min(v), hi.max(v)),
            });
        }
        ranges
    }

    /// Per-cluster population counts.
    pub fn cluster_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.centroids.len()];
        for &a in &self.assignment {
            sizes[a as usize] += 1;
        }
        sizes
    }
}

/// Assign each value to its nearest centroid (ties → lowest index).
pub fn assign(values: &[f32], centroids: &[f32]) -> Vec<u8> {
    values
        .iter()
        .map(|&v| {
            let mut best = 0u8;
            let mut best_d = f32::INFINITY;
            for (c, &cv) in centroids.iter().enumerate() {
                let d = (v - cv) * (v - cv);
                if d < best_d {
                    best_d = d;
                    best = c as u8;
                }
            }
            best
        })
        .collect()
}

fn inertia_of(values: &[f32], centroids: &[f32], assignment: &[u8]) -> f64 {
    values
        .iter()
        .zip(assignment)
        .map(|(&v, &a)| {
            let d = (v - centroids[a as usize]) as f64;
            d * d
        })
        .sum()
}

/// Lloyd iterations from explicit initial centers.
///
/// Empty clusters are repaired by re-seeding them on the point farthest from
/// its center (a standard k-means trick that keeps exactly `k` non-degenerate
/// clusters whenever the data has ≥ k distinct values).
pub fn lloyd_generic(values: &[f32], init: &[f32], max_iter: usize) -> KMeansResult {
    let k = init.len();
    assert!(k >= 1 && !values.is_empty());
    let mut centroids = init.to_vec();
    let mut assignment = assign(values, &centroids);
    let mut iterations = 0;

    for _ in 0..max_iter {
        iterations += 1;
        // update
        let mut sums = vec![0f64; k];
        let mut counts = vec![0usize; k];
        for (&v, &a) in values.iter().zip(&assignment) {
            sums[a as usize] += v as f64;
            counts[a as usize] += 1;
        }
        let mut new_centroids = centroids.clone();
        for c in 0..k {
            if counts[c] > 0 {
                new_centroids[c] = (sums[c] / counts[c] as f64) as f32;
            }
        }
        // empty-cluster repair: move to the farthest point. Ties on
        // distance break toward the larger value so the sorted fast path
        // (which scans in value order) picks the identical reseed point.
        for c in 0..k {
            if counts[c] == 0 {
                if let Some((idx, _)) = values
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| {
                        let d = (v - new_centroids[assignment[i] as usize]).abs();
                        (i, d)
                    })
                    .max_by(|a, b| {
                        a.1.partial_cmp(&b.1)
                            .unwrap()
                            .then(values[a.0].partial_cmp(&values[b.0]).unwrap())
                    })
                {
                    new_centroids[c] = values[idx];
                }
            }
        }
        let new_assignment = assign(values, &new_centroids);
        let converged = new_assignment == assignment && new_centroids == centroids;
        centroids = new_centroids;
        assignment = new_assignment;
        if converged {
            break;
        }
    }

    // canonical order: centroids ascending, assignment remapped
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| centroids[a].partial_cmp(&centroids[b]).unwrap());
    let mut remap = vec![0u8; k];
    for (new_idx, &old_idx) in order.iter().enumerate() {
        remap[old_idx] = new_idx as u8;
    }
    let centroids_sorted: Vec<f32> = order.iter().map(|&i| centroids[i]).collect();
    let assignment: Vec<u8> = assignment.iter().map(|&a| remap[a as usize]).collect();
    let inertia = inertia_of(values, &centroids_sorted, &assignment);
    KMeansResult { centroids: centroids_sorted, assignment, inertia, iterations }
}

/// Full run: greedy k-means++ init, then Lloyd.
pub fn kmeans(values: &[f32], k: usize, max_iter: usize, rng: &mut Rng) -> KMeansResult {
    let init = greedy_kmeanspp(values, k, rng);
    lloyd_generic(values, &init, max_iter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn three_blobs_recovered() {
        let mut rng = Rng::new(0);
        let mut values = Vec::new();
        for &c in &[-10.0f32, 0.0, 10.0] {
            for _ in 0..100 {
                values.push(c + rng.normal_f32(0.0, 0.2));
            }
        }
        let r = kmeans(&values, 3, 50, &mut rng);
        assert!((r.centroids[0] + 10.0).abs() < 0.5, "{:?}", r.centroids);
        assert!(r.centroids[1].abs() < 0.5);
        assert!((r.centroids[2] - 10.0).abs() < 0.5);
        let sizes = r.cluster_sizes();
        assert_eq!(sizes, vec![100, 100, 100]);
    }

    #[test]
    fn assignment_is_monotone_in_value() {
        let mut rng = Rng::new(1);
        let values: Vec<f32> = (0..500).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let r = kmeans(&values, 3, 50, &mut rng);
        let mut pairs: Vec<(f32, u8)> = values.iter().copied().zip(r.assignment.clone()).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert!(pairs.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn k1_centroid_is_mean() {
        let values = vec![1.0f32, 2.0, 3.0, 6.0];
        let mut rng = Rng::new(2);
        let r = kmeans(&values, 1, 20, &mut rng);
        assert!((r.centroids[0] - 3.0).abs() < 1e-6);
        assert!(r.assignment.iter().all(|&a| a == 0));
    }

    #[test]
    fn outlier_lands_in_its_own_cluster() {
        // the paper's motivating scenario: a lone outlier should isolate
        let mut values = vec![0.0f32; 0];
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            values.push(rng.normal_f32(0.0, 1.0));
        }
        values.push(1000.0);
        let r = kmeans(&values, 3, 50, &mut rng);
        let out_cluster = r.assignment[200];
        assert_eq!(out_cluster, 2, "outlier must be in the upper cluster");
        assert_eq!(r.cluster_sizes()[2], 1, "outlier alone in its cluster");
    }

    #[test]
    fn constant_values() {
        let values = vec![5.0f32; 17];
        let mut rng = Rng::new(4);
        let r = kmeans(&values, 3, 20, &mut rng);
        assert_eq!(r.inertia, 0.0);
        assert_eq!(r.assignment.len(), 17);
    }

    #[test]
    fn property_inertia_never_worse_than_single_cluster() {
        check("kmeans(k=3) <= kmeans(k=1) inertia", 30, |rng| {
            let n = rng.range(3, 400);
            let values: Vec<f32> =
                crate::util::proptest::gen_values_with_outliers(rng, n, 0.05);
            let r3 = kmeans(&values, 3, 50, rng);
            let r1 = kmeans(&values, 1, 50, rng);
            assert!(
                r3.inertia <= r1.inertia + 1e-6,
                "k=3 {} vs k=1 {}",
                r3.inertia,
                r1.inertia
            );
        });
    }

    #[test]
    fn property_lloyd_never_increases_inertia() {
        check("more lloyd iters never hurt", 25, |rng| {
            let n = rng.range(5, 300);
            let values: Vec<f32> =
                crate::util::proptest::gen_values_with_outliers(rng, n, 0.1);
            let init = super::greedy_kmeanspp(&values, 3, rng);
            let short = lloyd_generic(&values, &init, 1);
            let long = lloyd_generic(&values, &init, 60);
            assert!(long.inertia <= short.inertia + 1e-6);
        });
    }

    #[test]
    fn cluster_ranges_cover_values() {
        let values = vec![-5.0f32, -4.0, 0.0, 0.5, 4.0, 5.0];
        let mut rng = Rng::new(6);
        let r = kmeans(&values, 3, 50, &mut rng);
        let ranges = r.cluster_ranges(&values);
        for (i, &v) in values.iter().enumerate() {
            let (lo, hi) = ranges[r.assignment[i] as usize].unwrap();
            assert!(v >= lo && v <= hi);
        }
    }
}
