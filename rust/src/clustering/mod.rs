//! 1-D k-means clustering — the optimizer behind SplitQuant's layer split
//! (paper §4.1: k = 3, greedy k-means++ initialization [Grunau et al. 2023]).
//!
//! Two Lloyd implementations are provided:
//! * [`kmeans::lloyd_generic`] — direct O(n·k) per iteration, any data order.
//! * [`kmeans1d::cluster`] — the production path: sort once, then each Lloyd
//!   iteration is O(k log n) using boundary bisection + prefix sums.
//!
//! Both produce identical results from the same initialization (property
//! tested), and centroids are always returned **sorted ascending** so cluster
//! 0/1/2 are the paper's lower/middle/upper clusters.

pub mod init;
pub mod kmeans;
pub mod kmeans1d;

pub use kmeans::{lloyd_generic, KMeansResult};
pub use kmeans1d::cluster;

/// Default cluster count from the paper (lower / middle / upper).
pub const DEFAULT_K: usize = 3;
