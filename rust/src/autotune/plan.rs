//! The serializable bit-allocation plan and its realized-payload validation.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::shardstore::{ShardData, ShardKind, ShardReader};
use crate::util::json::{obj, Json};

/// A per-layer bit assignment chosen under a byte budget — the autotuner's
/// output and the [`crate::autotune::AutoTunePass`] input. Serializable to
/// JSON ([`BitPlan::save`] / [`BitPlan::load`]) so a plan computed once on
/// a calibration host can be replayed at deployment time.
#[derive(Debug, Clone, PartialEq)]
pub struct BitPlan {
    /// Bit-width per layer group (stem name, e.g. `encoder.0.attn.q`).
    pub layers: BTreeMap<String, u8>,
    /// The byte budget the plan was allocated under (packed quantized
    /// payload, [`crate::quant::QTensor::byte_size`] accounting).
    pub budget_bytes: usize,
    /// Predicted packed bytes of the assignment (exact: byte cost depends
    /// only on element count, bit-width and cluster count, so the realized
    /// artifact matches this figure — asserted in the integration tests).
    pub planned_bytes: usize,
    /// Predicted logit distortion (sum of per-layer calibration KL under
    /// the additive single-layer approximation).
    pub planned_kl: f64,
}

impl BitPlan {
    /// Layer count per assigned width, ascending (e.g. `{2: 5, 4: 3, 8: 2}`).
    pub fn bits_histogram(&self) -> BTreeMap<u8, usize> {
        let mut h = BTreeMap::new();
        for &bits in self.layers.values() {
            *h.entry(bits).or_insert(0usize) += 1;
        }
        h
    }

    /// Compact human label, e.g. `b2×5 b4×3 b8×2`.
    pub fn summary(&self) -> String {
        self.bits_histogram()
            .iter()
            .map(|(bits, n)| format!("b{bits}×{n}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// JSON form (layer map plus budget/planned figures).
    pub fn to_json(&self) -> Json {
        let layers: BTreeMap<String, Json> = self
            .layers
            .iter()
            .map(|(name, &bits)| (name.clone(), Json::from(bits as usize)))
            .collect();
        obj(vec![
            ("budget_bytes", Json::from(self.budget_bytes)),
            ("planned_bytes", Json::from(self.planned_bytes)),
            ("planned_kl", Json::from(self.planned_kl)),
            ("layers", Json::Obj(layers)),
        ])
    }

    /// Inverse of [`BitPlan::to_json`].
    pub fn from_json(j: &Json) -> Result<BitPlan> {
        let mut layers = BTreeMap::new();
        for (name, bits) in j.get("layers")?.as_obj()? {
            let b = bits.as_usize()?;
            if !(1..=8).contains(&b) {
                return Err(Error::Quant(format!("bit plan: {name:?} has invalid width {b}")));
            }
            layers.insert(name.clone(), b as u8);
        }
        Ok(BitPlan {
            layers,
            budget_bytes: j.get("budget_bytes")?.as_usize()?,
            planned_bytes: j.get("planned_bytes")?.as_usize()?,
            planned_kl: j.get("planned_kl")?.as_f64()?,
        })
    }

    /// Write the plan as JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load a plan saved with [`BitPlan::save`].
    pub fn load(path: &Path) -> Result<BitPlan> {
        let text = std::fs::read_to_string(path)?;
        BitPlan::from_json(&Json::parse(&text)?)
    }

    /// Validate a realized sharded artifact (`SQSH0001`) against the plan's
    /// budget: fault in every quantized shard and sum its packed byte cost
    /// under the same [`crate::quant::QTensor::byte_size`] accounting the
    /// allocator used. Returns the realized bytes; errors if they exceed
    /// the budget (the deployment-time guard that a mis-paired plan/model
    /// cannot silently blow the size contract).
    pub fn validate_sharded(&self, path: &Path) -> Result<usize> {
        let _sp = crate::trace::span(crate::trace::Category::Autotune, "validate");
        let reader = ShardReader::open(path)?;
        let mut realized = 0usize;
        for name in reader.names() {
            // the index knows each entry's kind without I/O — only the
            // quantized records are faulted in and decoded
            if reader.entry(name).map(|e| e.kind) != Some(ShardKind::Quant) {
                continue;
            }
            if let ShardData::Quant(q) = reader.read(name)? {
                realized += q.byte_size();
            }
        }
        if realized > self.budget_bytes {
            return Err(Error::Quant(format!(
                "realized quantized payload {realized} B exceeds the {} B budget \
                 (plan {}, {} on-disk record bytes)",
                self.budget_bytes,
                self.summary(),
                reader.quantized_payload_bytes()
            )));
        }
        Ok(realized)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_plan() -> BitPlan {
        let mut layers = BTreeMap::new();
        layers.insert("classifier".to_string(), 8u8);
        layers.insert("encoder.0.attn.q".to_string(), 2u8);
        layers.insert("encoder.0.ffn.in".to_string(), 4u8);
        BitPlan { layers, budget_bytes: 1234, planned_bytes: 1200, planned_kl: 0.125 }
    }

    #[test]
    fn json_roundtrip_exact() {
        let p = demo_plan();
        let j = p.to_json();
        let q = BitPlan::from_json(&j).unwrap();
        assert_eq!(p, q);
        // and through the text form (f64 Display round-trips)
        let q2 = BitPlan::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(p, q2);
    }

    #[test]
    fn save_load_roundtrip() {
        let p = demo_plan();
        let path = std::env::temp_dir().join("sq_bitplan_rt.json");
        p.save(&path).unwrap();
        let q = BitPlan::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(p, q);
    }

    #[test]
    fn invalid_widths_rejected() {
        let j = Json::parse(
            r#"{"budget_bytes":10,"planned_bytes":5,"planned_kl":0.1,"layers":{"x":16}}"#,
        )
        .unwrap();
        assert!(BitPlan::from_json(&j).is_err());
    }

    #[test]
    fn summary_histogram() {
        let p = demo_plan();
        assert_eq!(p.summary(), "b2×1 b4×1 b8×1");
        assert_eq!(p.bits_histogram().get(&8), Some(&1));
    }
}
