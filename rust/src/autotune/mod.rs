//! Sensitivity-guided mixed-precision autotuner: per-layer bit allocation
//! under a packed-byte budget.
//!
//! SplitQuant keeps outliers representable at low bit-widths; this subsystem
//! decides *which* layers get which widths. The repo previously quantized
//! every layer at one global width (with hand-written per-layer overrides
//! from PR 2) — the autotuner closes the ROADMAP's "adaptive mixed-precision
//! search" item by making the assignment automatic:
//!
//! ```text
//!  FP32 store ──┐                                 (one O(1) share per candidate,
//!               ▼                                  copy-on-write — never cloned)
//!  [1] sensitivity sweep      layer × {2,4,8}: quantize ONE layer, forward the
//!      (sensitivity.rs)       calibration batches, record KL vs FP32 logits +
//!               │             exact packed bytes (QTensor::byte_size)
//!               ▼
//!  [2] greedy Lagrangian      convexified per-layer upgrade chains, merged into
//!      allocation             one gain-sorted schedule; a budget buys the
//!      (allocate.rs)          longest affordable prefix → BitPlan (plan.rs,
//!               │             JSON-serializable, deterministic)
//!               ▼
//!  [3] AutoTunePass           expands the plan into per-layer SplitQuantConfig
//!      (this module)          overrides on one QuantPipeline pass; provenance
//!               │             records budget + assignment histogram
//!               ▼
//!  [4] validation             PackedModel::save_sharded → BitPlan::validate_sharded
//!                             re-reads every quantized shard and checks the
//!                             realized payload against the budget
//! ```
//!
//! See `examples/autotune_budget.rs` for the end-to-end walkthrough (budget =
//! uniform-INT4 bytes, plan beats uniform-INT2 accuracy) and the `autotune`
//! CLI subcommand for checkpoint workflows.

pub mod allocate;
pub mod plan;
pub mod sensitivity;

pub use allocate::allocate;
pub use plan::BitPlan;
pub use sensitivity::{
    candidate_artifact, logit_distortion, sweep, BitOption, LayerSensitivity, SensitivityTable,
    SweepConfig,
};

use crate::error::{Error, Result};
use crate::model::params::ParamStore;
use crate::quant::pipeline::{ModelArtifact, QuantPass, SplitQuantPass};
use crate::splitquant::{default_quantizable, SplitQuantConfig};

/// Quantizable parameters grouped into layer units that share one bit-width
/// decision: `P.weight` + `P.bias` group under stem `P`; standalone tensors
/// (e.g. `embeddings.token`) form their own group. Order follows the
/// store's parameter order (deterministic).
pub fn layer_groups(store: &ParamStore) -> Vec<(String, Vec<String>)> {
    let mut order: Vec<String> = Vec::new();
    let mut groups: std::collections::BTreeMap<String, Vec<String>> = Default::default();
    for name in default_quantizable(store) {
        let stem = name
            .strip_suffix(".weight")
            .or_else(|| name.strip_suffix(".bias"))
            .unwrap_or(name.as_str())
            .to_string();
        if !groups.contains_key(&stem) {
            order.push(stem.clone());
        }
        groups.entry(stem).or_default().push(name);
    }
    order
        .into_iter()
        .map(|l| {
            let params = groups.remove(&l).expect("group recorded above");
            (l, params)
        })
        .collect()
}

/// A [`QuantPass`] that expands a [`BitPlan`] into per-layer
/// [`SplitQuantConfig`] overrides on one [`SplitQuantPass`]: every layer
/// group is quantized at its planned width in a single pipeline pass, and
/// the artifact's provenance records the budget and the assignment
/// histogram. The plan must cover exactly the store's quantizable layer
/// groups (a stale plan against a different model errors instead of
/// silently misquantizing).
#[derive(Debug, Clone)]
pub struct AutoTunePass {
    plan: BitPlan,
    base: SplitQuantConfig,
}

impl AutoTunePass {
    /// Apply `plan` on top of `base` (which supplies cluster count, seed,
    /// and every non-`bits` knob).
    pub fn new(plan: BitPlan, base: SplitQuantConfig) -> AutoTunePass {
        AutoTunePass { plan, base }
    }

    /// The plan this pass expands.
    pub fn plan(&self) -> &BitPlan {
        &self.plan
    }
}

impl QuantPass for AutoTunePass {
    fn name(&self) -> String {
        format!(
            "autotune(budget={}B, planned={}B, {})",
            self.plan.budget_bytes,
            self.plan.planned_bytes,
            self.plan.summary()
        )
    }

    fn apply(&self, model: &mut ModelArtifact) -> Result<()> {
        let _sp = crate::trace::span(crate::trace::Category::Autotune, "apply");
        let groups = layer_groups(&model.eval);
        for name in self.plan.layers.keys() {
            if !groups.iter().any(|(l, _)| l == name) {
                return Err(Error::Quant(format!(
                    "bit plan layer {name:?} does not exist in this model"
                )));
            }
        }
        let mut pass = SplitQuantPass::with_config(self.base);
        let mut quantizable = Vec::new();
        for (layer, params) in &groups {
            let Some(&bits) = self.plan.layers.get(layer) else {
                return Err(Error::Quant(format!(
                    "bit plan has no assignment for layer {layer:?}"
                )));
            };
            for p in params {
                pass = pass.layer_bits(p, bits);
                quantizable.push(p.clone());
            }
        }
        pass.quantizable(quantizable).apply(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;
    use crate::quant::pipeline::QuantPipeline;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn tiny_store() -> ParamStore {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(0))
    }

    #[test]
    fn layer_groups_pair_weights_with_biases() {
        let store = tiny_store();
        let groups = layer_groups(&store);
        let by_name: BTreeMap<&str, &Vec<String>> =
            groups.iter().map(|(l, p)| (l.as_str(), p)).collect();
        assert_eq!(
            by_name["encoder.0.attn.q"],
            &vec![
                "encoder.0.attn.q.weight".to_string(),
                "encoder.0.attn.q.bias".to_string()
            ]
        );
        assert_eq!(by_name["embeddings.token"], &vec!["embeddings.token".to_string()]);
        // groups partition the quantizable set exactly
        let total: usize = groups.iter().map(|(_, p)| p.len()).sum();
        assert_eq!(total, default_quantizable(&store).len());
        // deterministic across calls
        assert_eq!(groups, layer_groups(&store));
    }

    #[test]
    fn autotune_pass_applies_planned_widths() {
        let store = tiny_store();
        let mut layers = BTreeMap::new();
        for (l, _) in layer_groups(&store) {
            layers.insert(l, 2u8);
        }
        layers.insert("classifier".to_string(), 8);
        layers.insert("pooler".to_string(), 4);
        let plan =
            BitPlan { layers, budget_bytes: 1 << 20, planned_bytes: 0, planned_kl: 0.0 };
        let artifact = QuantPipeline::new()
            .pass(AutoTunePass::new(plan, SplitQuantConfig::new(2)))
            .run(&store)
            .unwrap();
        assert_eq!(artifact.tensors["classifier.weight"].bits(), 8);
        assert_eq!(artifact.tensors["classifier.bias"].bits(), 8);
        assert_eq!(artifact.tensors["pooler.weight"].bits(), 4);
        assert_eq!(artifact.tensors["encoder.0.attn.q.weight"].bits(), 2);
        assert_eq!(artifact.tensors["embeddings.token"].bits(), 2);
        assert!(artifact.provenance[0].starts_with("autotune(budget="));
        // every quantizable param was packed
        assert_eq!(artifact.tensors.len(), default_quantizable(&store).len());
    }

    #[test]
    fn autotune_pass_rejects_mismatched_plans() {
        let store = tiny_store();
        // missing layer
        let mut layers = BTreeMap::new();
        layers.insert("classifier".to_string(), 8u8);
        let partial =
            BitPlan { layers, budget_bytes: 0, planned_bytes: 0, planned_kl: 0.0 };
        assert!(QuantPipeline::new()
            .pass(AutoTunePass::new(partial, SplitQuantConfig::new(2)))
            .run(&store)
            .is_err());
        // phantom layer
        let mut layers = BTreeMap::new();
        for (l, _) in layer_groups(&store) {
            layers.insert(l, 2u8);
        }
        layers.insert("nonexistent.layer".to_string(), 4);
        let phantom =
            BitPlan { layers, budget_bytes: 0, planned_bytes: 0, planned_kl: 0.0 };
        assert!(QuantPipeline::new()
            .pass(AutoTunePass::new(phantom, SplitQuantConfig::new(2)))
            .run(&store)
            .is_err());
    }
}
