//! Bit allocation under a byte budget: a greedy Lagrangian sweep over the
//! sensitivity table.
//!
//! Every layer starts at the cheapest candidate width (the floor). Each
//! possible upgrade (e.g. INT2 → INT4 for one layer) has a marginal gain:
//! KL reduction per extra packed byte. Per layer, the upgrade chain is
//! **convexified** (consecutive steps merge while a later step's gain
//! matches or beats an earlier one — the classic lower-convex-hull trick
//! that keeps greedy selection chain-valid) and non-improving tail steps
//! are dropped (an upgrade that doesn't reduce KL never earns its bytes).
//!
//! The surviving steps form one global **upgrade schedule**, sorted by gain
//! (descending, deterministic tie-breaking by layer name then target
//! width). A plan for budget *B* is the longest prefix of that schedule
//! that fits: the schedule is budget-independent, so a larger budget's plan
//! strictly extends a smaller one — monotonicity (more bytes ⇒ predicted
//! distortion no worse) holds **by construction**, and is property-tested
//! below and in `tests/integration_autotune.rs`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

use super::plan::BitPlan;
use super::sensitivity::SensitivityTable;

/// One upgrade step of the global schedule: move `layer` from option
/// `from_idx` to `to_idx` (consecutive, or merged across several widths by
/// convexification) for `dbytes` extra bytes and `dkl` less distortion.
#[derive(Debug, Clone)]
struct Step {
    layer: usize,
    to_idx: usize,
    dbytes: usize,
    dkl: f64,
    gain: f64,
}

/// Choose per-layer bit-widths under `budget_bytes` (packed quantized
/// payload, [`crate::quant::QTensor::byte_size`] accounting). Errors when
/// the table is empty, malformed (bytes/bits not strictly increasing), or
/// the budget cannot even fit the all-floor assignment.
pub fn allocate(table: &SensitivityTable, budget_bytes: usize) -> Result<BitPlan> {
    let _sp = crate::trace::span(crate::trace::Category::Autotune, "allocate");
    if table.layers.is_empty() {
        return Err(Error::Quant("allocate: empty sensitivity table".into()));
    }
    for l in &table.layers {
        if l.options.is_empty() {
            return Err(Error::Quant(format!("allocate: layer {:?} has no options", l.layer)));
        }
    }

    // The floor: every layer at its cheapest candidate.
    let mut level: Vec<usize> = vec![0; table.layers.len()];
    let mut bytes: usize = table.layers.iter().map(|l| l.options[0].bytes).sum();
    let mut kl: f64 = table.layers.iter().map(|l| l.options[0].kl).sum();
    if bytes > budget_bytes {
        let floor_bits = table.layers.iter().map(|l| l.options[0].bits).min().unwrap_or(0);
        return Err(Error::Quant(format!(
            "budget {budget_bytes} B is below the all-INT{floor_bits} floor ({bytes} B) — \
             nothing to allocate"
        )));
    }

    // Longest affordable prefix of the budget-independent schedule.
    for step in upgrade_schedule(table)? {
        if bytes + step.dbytes > budget_bytes {
            break;
        }
        level[step.layer] = step.to_idx;
        bytes += step.dbytes;
        kl -= step.dkl;
    }

    let layers: BTreeMap<String, u8> = table
        .layers
        .iter()
        .zip(&level)
        .map(|(l, &li)| (l.layer.clone(), l.options[li].bits))
        .collect();
    Ok(BitPlan { layers, budget_bytes, planned_bytes: bytes, planned_kl: kl })
}

/// Build the global upgrade schedule: per-layer convexified chains, merged
/// and sorted by marginal gain. Within a layer gains strictly decrease
/// after convexification, so any deterministic tie-break preserves chain
/// order across layers.
fn upgrade_schedule(table: &SensitivityTable) -> Result<Vec<Step>> {
    let mut all: Vec<Step> = Vec::new();
    for (li, layer) in table.layers.iter().enumerate() {
        for w in layer.options.windows(2) {
            if w[1].bits <= w[0].bits || w[1].bytes <= w[0].bytes {
                return Err(Error::Quant(format!(
                    "sensitivity options for {:?} must have strictly increasing bits and bytes \
                     (got INT{}@{}B then INT{}@{}B)",
                    layer.layer, w[0].bits, w[0].bytes, w[1].bits, w[1].bytes
                )));
            }
        }
        // Raw consecutive steps, then convexify: merge while a later step's
        // gain is not strictly worse than its predecessor's.
        let mut hull: Vec<Step> = Vec::new();
        for j in 1..layer.options.len() {
            let dbytes = layer.options[j].bytes - layer.options[j - 1].bytes;
            let dkl = layer.options[j - 1].kl - layer.options[j].kl;
            let mut s = Step { layer: li, to_idx: j, dbytes, dkl, gain: dkl / dbytes as f64 };
            while let Some(prev) = hull.last() {
                if s.gain >= prev.gain {
                    let prev = hull.pop().expect("non-empty");
                    let dbytes = prev.dbytes + s.dbytes;
                    let dkl = prev.dkl + s.dkl;
                    let gain = dkl / dbytes as f64;
                    s = Step { layer: li, to_idx: s.to_idx, dbytes, dkl, gain };
                } else {
                    break;
                }
            }
            hull.push(s);
        }
        // Gains now strictly decrease along the chain, so non-improving
        // steps form a suffix; drop them (never spend bytes for ≤ 0 gain).
        while hull.last().is_some_and(|s| s.gain <= 0.0) {
            hull.pop();
        }
        all.extend(hull);
    }
    all.sort_by(|a, b| {
        b.gain
            .total_cmp(&a.gain)
            .then_with(|| table.layers[a.layer].layer.cmp(&table.layers[b.layer].layer))
            .then_with(|| a.to_idx.cmp(&b.to_idx))
    });
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::sensitivity::{BitOption, LayerSensitivity};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn opt(bits: u8, bytes: usize, kl: f64) -> BitOption {
        BitOption { bits, bytes, kl, kl_int8: None, max_abs_delta: 0.0 }
    }

    fn layer(name: &str, options: Vec<BitOption>) -> LayerSensitivity {
        LayerSensitivity {
            layer: name.to_string(),
            params: vec![format!("{name}.weight")],
            options,
        }
    }

    /// Random but well-formed table: strictly increasing bytes, arbitrary
    /// (possibly non-monotone) KL so convexification gets exercised.
    fn random_table(rng: &mut Rng) -> SensitivityTable {
        let nl = rng.range(1, 6);
        let layers = (0..nl)
            .map(|i| {
                let base_bytes = rng.range(10, 200);
                let mut bytes = base_bytes;
                let mut options = Vec::new();
                for &bits in &[2u8, 4, 8] {
                    options.push(opt(bits, bytes, rng.range_f64(0.0, 2.0)));
                    bytes += rng.range(1, 300);
                }
                layer(&format!("layer.{i}"), options)
            })
            .collect();
        SensitivityTable { layers, examples: 1 }
    }

    fn recompute(table: &SensitivityTable, plan: &BitPlan) -> (usize, f64) {
        let mut bytes = 0usize;
        let mut kl = 0.0f64;
        for l in &table.layers {
            let bits = plan.layers[&l.layer];
            let o = l.options.iter().find(|o| o.bits == bits).unwrap();
            bytes += o.bytes;
            kl += o.kl;
        }
        (bytes, kl)
    }

    #[test]
    fn spends_budget_on_the_sensitive_layer_first() {
        // "hot" collapses from 10.0 to ~0 KL; "cold" barely moves — the
        // first upgrade bytes must go to hot
        let table = SensitivityTable {
            layers: vec![
                layer("cold", vec![opt(2, 100, 0.02), opt(4, 200, 0.01), opt(8, 400, 0.005)]),
                layer("hot", vec![opt(2, 100, 10.0), opt(4, 200, 0.5), opt(8, 400, 0.1)]),
            ],
            examples: 1,
        };
        let plan = allocate(&table, 300).unwrap();
        assert_eq!(plan.layers["hot"], 4);
        assert_eq!(plan.layers["cold"], 2);
        assert_eq!(plan.planned_bytes, 300);
    }

    #[test]
    fn convexification_jumps_straight_to_int8() {
        // 2→4 barely helps but 4→8 collapses the loss: the merged 2→8 step
        // must be offered (and taken) as one unit
        let table = SensitivityTable {
            layers: vec![layer("l", vec![opt(2, 100, 5.0), opt(4, 150, 4.9), opt(8, 200, 0.1)])],
            examples: 1,
        };
        let plan = allocate(&table, 200).unwrap();
        assert_eq!(plan.layers["l"], 8);
        // and with a budget that only fits the partial step, nothing is taken
        let plan = allocate(&table, 160).unwrap();
        assert_eq!(plan.layers["l"], 2);
    }

    #[test]
    fn non_improving_upgrades_are_never_bought() {
        // INT8 measures *worse* than INT4 (calibration noise): even with an
        // unlimited budget the plan stops at INT4
        let table = SensitivityTable {
            layers: vec![layer("l", vec![opt(2, 100, 5.0), opt(4, 200, 1.0), opt(8, 400, 1.2)])],
            examples: 1,
        };
        let plan = allocate(&table, usize::MAX).unwrap();
        assert_eq!(plan.layers["l"], 4);
    }

    #[test]
    fn budget_below_floor_errors() {
        let table =
            SensitivityTable { layers: vec![layer("l", vec![opt(2, 100, 1.0)])], examples: 1 };
        assert!(allocate(&table, 99).is_err());
        assert!(allocate(&table, 100).is_ok());
    }

    #[test]
    fn malformed_options_rejected() {
        let table = SensitivityTable {
            layers: vec![layer("l", vec![opt(2, 100, 1.0), opt(4, 100, 0.5)])],
            examples: 1,
        };
        assert!(allocate(&table, 1000).is_err());
    }

    #[test]
    fn property_plan_never_exceeds_budget() {
        check("plan fits budget", 60, |rng| {
            let table = random_table(rng);
            let floor: usize = table.layers.iter().map(|l| l.options[0].bytes).sum();
            let ceil: usize = table.layers.iter().map(|l| l.options[2].bytes).sum();
            let budget = rng.range(floor, ceil + 50);
            let plan = allocate(&table, budget).unwrap();
            assert!(plan.planned_bytes <= budget, "{} > {budget}", plan.planned_bytes);
            // the reported totals match the assignment exactly
            let (bytes, kl) = recompute(&table, &plan);
            assert_eq!(bytes, plan.planned_bytes);
            assert!((kl - plan.planned_kl).abs() < 1e-9, "{kl} vs {}", plan.planned_kl);
        });
    }

    #[test]
    fn property_larger_budget_never_hurts() {
        check("monotone in budget", 60, |rng| {
            let table = random_table(rng);
            let floor: usize = table.layers.iter().map(|l| l.options[0].bytes).sum();
            let ceil: usize = table.layers.iter().map(|l| l.options[2].bytes).sum();
            let mut b1 = rng.range(floor, ceil + 1);
            let mut b2 = rng.range(floor, ceil + 1);
            if b1 > b2 {
                std::mem::swap(&mut b1, &mut b2);
            }
            let p1 = allocate(&table, b1).unwrap();
            let p2 = allocate(&table, b2).unwrap();
            assert!(
                p2.planned_kl <= p1.planned_kl + 1e-12,
                "budget {b2} ({}) worse than {b1} ({})",
                p2.planned_kl,
                p1.planned_kl
            );
            // larger budget strictly extends the smaller plan's upgrades
            for (l, &bits) in &p1.layers {
                assert!(p2.layers[l] >= bits, "{l} downgraded {bits} -> {}", p2.layers[l]);
            }
        });
    }

    #[test]
    fn property_allocation_is_deterministic() {
        check("deterministic allocation", 40, |rng| {
            let table = random_table(rng);
            let floor: usize = table.layers.iter().map(|l| l.options[0].bytes).sum();
            let budget = floor + rng.range(0, 500);
            let a = allocate(&table, budget).unwrap();
            let b = allocate(&table, budget).unwrap();
            assert_eq!(a.layers, b.layers);
            assert_eq!(a.planned_bytes, b.planned_bytes);
            assert_eq!(a.planned_kl.to_bits(), b.planned_kl.to_bits());
        });
    }
}
