//! Per-layer sensitivity sweep: how much does quantizing **one** layer at a
//! candidate bit-width distort the model's logits, and what does it cost in
//! packed bytes?
//!
//! For every quantizable layer group (weight + bias, or a standalone tensor
//! like the token embedding) and every candidate bit-width, the sweep
//! quantizes *only that layer* through the existing
//! [`crate::quant::pipeline::QuantPipeline`] + [`SplitQuantPass`] route, runs
//! the calibration batches through the pure-Rust executor, and records
//!
//! * the mean per-example KL divergence between the FP32 reference logits
//!   and the candidate's logits (the allocator's objective),
//! * the max absolute logit delta (a worst-case diagnostic), and
//! * the **exact** packed byte cost from [`crate::quant::QTensor::byte_size`]
//!   (codes + cluster-id plane + per-group parameters — the paper-§6
//!   accounting the byte budget is denominated in).
//!
//! Every candidate artifact starts as an O(1) [`ParamStore::share`] view of
//! the one FP32 store (copy-on-write rewrites only the swept layer's
//! tensors), so a full sweep over L layers × B bit-widths never deep-clones
//! the model — `tests/integration_autotune.rs` pins this with
//! `Arc::ptr_eq`-level accounting.

use crate::data::batch::TextBatch;
use crate::error::{Error, Result};
use crate::model::bert::BertModel;
use crate::model::config::BertConfig;
use crate::model::params::ParamStore;
use crate::quant::pipeline::{ModelArtifact, QuantPipeline, SplitQuantPass};
use crate::splitquant::SplitQuantConfig;
use crate::tensor::Tensor;

/// Sweep configuration: which bit-widths to try and the base SplitQuant
/// config (cluster count, seed, …) each candidate inherits.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Candidate bit-widths, deduplicated and sorted ascending before use.
    pub candidates: Vec<u8>,
    /// Base [`SplitQuantConfig`] every candidate derives from (only `bits`
    /// is overridden per candidate).
    pub base: SplitQuantConfig,
    /// Also measure each candidate through the deployment executor
    /// ([`crate::model::qbert::QuantizedBert`]) on the
    /// [`crate::parallel::KernelKind::Int8`] engine with dynamic activation
    /// quantization, filling [`BitOption::kl_int8`]. Off by default — it
    /// roughly doubles the sweep's forward count.
    pub int8_fidelity: bool,
}

impl Default for SweepConfig {
    /// The standard low-bit ladder {2, 4, 8} over the paper-default
    /// SplitQuant config (k = 3, greedy k-means++).
    fn default() -> Self {
        SweepConfig {
            candidates: vec![2, 4, 8],
            base: SplitQuantConfig::new(2),
            int8_fidelity: false,
        }
    }
}

/// One measured (layer, bit-width) cell of the sensitivity table.
#[derive(Debug, Clone, PartialEq)]
pub struct BitOption {
    /// Candidate bit-width.
    pub bits: u8,
    /// Exact packed byte cost of the layer's parameters at this width
    /// (sum of [`crate::quant::QTensor::byte_size`] over the group).
    pub bytes: usize,
    /// Mean per-example KL(fp32 ‖ candidate) over the calibration logits.
    pub kl: f64,
    /// Mean per-example KL(fp32 ‖ candidate) with the candidate executed on
    /// the integer engine ([`SweepConfig::int8_fidelity`]): same packed
    /// weights, activations quantized to 8 bits dynamically. `None` when
    /// the int8 fidelity column was not requested. The gap to [`kl`]
    /// isolates how much the integer datapath itself costs per layer.
    ///
    /// [`kl`]: BitOption::kl
    pub kl_int8: Option<f64>,
    /// Max `|fp32 − candidate|` over all calibration logits.
    pub max_abs_delta: f64,
}

/// Sensitivity measurements for one layer group across all candidates.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSensitivity {
    /// Layer group name (parameter stem, e.g. `encoder.0.attn.q`).
    pub layer: String,
    /// The group's parameter names (e.g. `…weight` + `…bias`).
    pub params: Vec<String>,
    /// One entry per candidate bit-width, ascending.
    pub options: Vec<BitOption>,
}

/// The full per-layer × per-bit-width sensitivity table — the allocator's
/// input ([`crate::autotune::allocate`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SensitivityTable {
    /// One row per quantizable layer group, in model (store) order.
    pub layers: Vec<LayerSensitivity>,
    /// Calibration examples each measurement averaged over.
    pub examples: usize,
}

impl SensitivityTable {
    /// Total packed bytes of a **uniform** assignment at `bits` (every layer
    /// at the same width) — the natural budget reference points. `None`
    /// when `bits` was not among the sweep candidates.
    pub fn uniform_bytes(&self, bits: u8) -> Option<usize> {
        let mut total = 0usize;
        for l in &self.layers {
            total += l.options.iter().find(|o| o.bits == bits)?.bytes;
        }
        Some(total)
    }
}

/// Quantize **only** `params` at `bits` (base config otherwise), returning
/// the candidate artifact. The artifact's eval view is an O(1) share of
/// `store`: every tensor outside `params` stays pointer-shared (this is the
/// sweep's inner loop — it must never deep-clone the FP32 store).
pub fn candidate_artifact(
    store: &ParamStore,
    params: &[String],
    bits: u8,
    base: &SplitQuantConfig,
) -> Result<ModelArtifact> {
    let cfg = SplitQuantConfig { bits, ..*base };
    QuantPipeline::new()
        .pass(SplitQuantPass::with_config(cfg).quantizable(params.to_vec()))
        .run(store)
}

/// Run the sensitivity sweep: for each quantizable layer group × candidate
/// bit-width, quantize only that layer and measure logit distortion against
/// the FP32 reference over `batches`. Deterministic for a given
/// `(store, batches, sweep config)` — candidates re-seed k-means from the
/// base config, and the executor is bit-stable across engines.
pub fn sweep(
    cfg: &BertConfig,
    store: &ParamStore,
    batches: &[TextBatch],
    sweep_cfg: &SweepConfig,
) -> Result<SensitivityTable> {
    let _sp = crate::trace::span(crate::trace::Category::Autotune, "sweep");
    if batches.is_empty() {
        return Err(Error::Quant("sensitivity sweep needs at least one calibration batch".into()));
    }
    let mut candidates = sweep_cfg.candidates.clone();
    candidates.sort_unstable();
    candidates.dedup();
    if candidates.is_empty() {
        return Err(Error::Quant("sensitivity sweep needs at least one candidate bit-width".into()));
    }

    // FP32 reference logits, one forward per calibration batch.
    let fp32 = BertModel::new(cfg.clone(), store.share())?;
    let refs: Vec<Tensor> = batches.iter().map(|b| fp32.forward(&b.ids, &b.mask)).collect();
    let examples: usize = refs.iter().map(|l| l.shape()[0]).sum();

    let groups = super::layer_groups(store);
    let mut layers = Vec::with_capacity(groups.len());
    for (layer, params) in groups {
        let mut options = Vec::with_capacity(candidates.len());
        for &bits in &candidates {
            let artifact = candidate_artifact(store, &params, bits, &sweep_cfg.base)?;
            let bytes: usize = artifact.tensors.values().map(|q| q.byte_size()).sum();
            let model = BertModel::new(cfg.clone(), artifact.eval.share())?;
            let mut kl_sum = 0.0f64;
            let mut max_abs = 0.0f64;
            for (b, r) in batches.iter().zip(&refs) {
                let logits = model.forward(&b.ids, &b.mask);
                let (dk, da) = logit_distortion(r, &logits);
                kl_sum += dk;
                max_abs = max_abs.max(da);
            }
            let kl_int8 = if sweep_cfg.int8_fidelity {
                let qm = artifact.quantized_model();
                let mut qbert =
                    crate::model::qbert::QuantizedBert::new(cfg.clone(), store, &qm)?;
                qbert.set_kernel(crate::parallel::KernelKind::Int8);
                let mut sum = 0.0f64;
                for (b, r) in batches.iter().zip(&refs) {
                    let logits = qbert.forward(&b.ids, &b.mask)?;
                    sum += logit_distortion(r, &logits).0;
                }
                Some(sum / examples.max(1) as f64)
            } else {
                None
            };
            options.push(BitOption {
                bits,
                bytes,
                kl: kl_sum / examples.max(1) as f64,
                kl_int8,
                max_abs_delta: max_abs,
            });
        }
        layers.push(LayerSensitivity { layer, params, options });
    }
    Ok(SensitivityTable { layers, examples })
}

/// Logit distortion between two `(rows × classes)` logit matrices: the sum
/// over rows of KL(softmax(reference) ‖ softmax(candidate)) plus the max
/// absolute element delta. Panics on shape mismatch (caller bug).
pub fn logit_distortion(reference: &Tensor, candidate: &Tensor) -> (f64, f64) {
    assert_eq!(reference.shape(), candidate.shape(), "logit shapes must match");
    let (rows, cols) = reference.as_2d();
    let mut kl = 0.0f64;
    let mut max_abs = 0.0f64;
    for i in 0..rows {
        let r = &reference.data()[i * cols..(i + 1) * cols];
        let c = &candidate.data()[i * cols..(i + 1) * cols];
        kl += kl_softmax(r, c);
        for (a, b) in r.iter().zip(c) {
            max_abs = max_abs.max(((a - b) as f64).abs());
        }
    }
    (kl, max_abs)
}

/// KL(softmax(p_logits) ‖ softmax(q_logits)) in f64, with the candidate
/// probabilities floored at 1e-12 so a collapsed candidate row stays finite.
fn kl_softmax(p_logits: &[f32], q_logits: &[f32]) -> f64 {
    let p = softmax64(p_logits);
    let q = softmax64(q_logits);
    p.iter()
        .zip(&q)
        .map(|(&pi, &qi)| if pi > 0.0 { pi * (pi / qi.max(1e-12)).ln() } else { 0.0 })
        .sum()
}

fn softmax64(logits: &[f32]) -> Vec<f64> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = logits.iter().map(|&v| ((v as f64) - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kl_zero_on_identical_logits() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.9, -0.3, 2.0, 1.0, 0.0]).unwrap();
        let (kl, max_abs) = logit_distortion(&t, &t);
        assert_eq!(kl, 0.0);
        assert_eq!(max_abs, 0.0);
    }

    #[test]
    fn kl_positive_and_grows_with_perturbation() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[4, 6], 0.0, 1.0, &mut rng);
        let mut small = a.clone();
        let mut big = a.clone();
        for (i, v) in small.data_mut().iter_mut().enumerate() {
            *v += if i % 2 == 0 { 0.01 } else { -0.01 };
        }
        for (i, v) in big.data_mut().iter_mut().enumerate() {
            *v += if i % 2 == 0 { 0.5 } else { -0.5 };
        }
        let (kl_s, da_s) = logit_distortion(&a, &small);
        let (kl_b, da_b) = logit_distortion(&a, &big);
        assert!(kl_s > 0.0 && kl_b > kl_s, "{kl_s} vs {kl_b}");
        assert!(da_b > da_s);
    }

    #[test]
    fn kl_finite_on_collapsed_candidate() {
        // an extreme candidate row must not produce inf/NaN
        let r = Tensor::new(&[1, 3], vec![0.0, 0.0, 0.0]).unwrap();
        let c = Tensor::new(&[1, 3], vec![100.0, -100.0, -100.0]).unwrap();
        let (kl, _) = logit_distortion(&r, &c);
        assert!(kl.is_finite() && kl > 0.0);
    }
}
