//! The sharded on-disk formats: an `SQQM0001` payload re-framed behind a
//! per-tensor offset index so any single layer's record (packed codes + cid
//! plane + params, or an FP32 remainder tensor) can be read with one seek +
//! one read, independently of the rest of the file.
//!
//! Version 1 (`SQSH0001`, read-compatible):
//!
//! ```text
//! magic "SQSH0001"
//! u8    bits                      (provenance; each Packed carries its own)
//! u32   n_entries
//! index, per entry:
//!   u16+bytes  name
//!   u8         kind               (0 = quantized, 1 = fp32)
//!   u8 rank, u32×rank dims        (shape, for classification without IO)
//!   u64        offset             (absolute file offset of the record)
//!   u64        len                (record length in bytes)
//! records, concatenated:
//!   quantized: shape, layout tag (+axis / +cid plane), params, codes
//!   fp32:      shape, raw f32 LE payload
//! ```
//!
//! Version 2 (`SQSH0002`, what [`write_sharded`] emits): identical layout
//! with end-to-end integrity added — a flipped bit on disk must fail a
//! read, never silently dequantize garbage into logits.
//!
//! ```text
//! magic "SQSH0002"
//! u8    bits
//! u32   n_entries
//! index, per entry:                (as v1, plus:)
//!   …name kind rank dims offset len
//!   u32        crc                 (CRC-32/ISO-HDLC of the record bytes)
//! u32   header_crc                 (CRC-32 of every header byte above,
//!                                   magic through the last index entry)
//! records, concatenated:           (byte-identical to v1)
//! ```
//!
//! The header checksum is verified at [`ShardReader::open`]; each record
//! CRC is verified on **every** read — demand fault and prefetch alike —
//! before the bytes are parsed ([`ShardReader::decode`]). v1 files still
//! open and read byte-compatibly, with no CRCs to check
//! ([`ShardIndexEntry::crc`] is `None`).
//!
//! Record encodings are byte-identical to the per-tensor sections of
//! `SQQM0001` (shared helpers in [`crate::quant::serialize`]); the index is
//! the only addition. `offset`/`len` are validated against the file size at
//! open, so truncated files fail fast instead of at first fault.

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::quant::serialize::{
    read_fp32_record, read_qtensor_record, read_str, write_fp32_record, write_qtensor_record,
    write_str,
};
use crate::quant::{PackedModel, QTensor};
use crate::tensor::Tensor;
use crate::util::crc32::{crc32, Hasher};
use crate::util::io::{read_u32, read_u64, read_u8};
use crate::util::sync::lock_recover;

const MAGIC_V1: &[u8; 8] = b"SQSH0001";
const MAGIC_V2: &[u8; 8] = b"SQSH0002";

const KIND_QUANT: u8 = 0;
const KIND_FP32: u8 = 1;

/// What kind of record an index entry points at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardKind {
    /// Packed quantized tensor (codes + optional cid plane + params).
    Quant,
    /// FP32 remainder tensor (LayerNorm, position embedding, biases, …).
    Fp32,
}

/// One shard's payload, as materialized from disk. FP32 tensors sit behind
/// an [`Arc`] so a [`crate::model::params::ParamStore`] can share the same
/// allocation via `push_shared` instead of copying the data out.
#[derive(Debug, Clone)]
pub enum ShardData {
    Quant(QTensor),
    Fp32(Arc<Tensor>),
}

impl ShardData {
    pub fn as_quant(&self) -> Option<&QTensor> {
        match self {
            ShardData::Quant(q) => Some(q),
            ShardData::Fp32(_) => None,
        }
    }

    pub fn as_fp32(&self) -> Option<&Arc<Tensor>> {
        match self {
            ShardData::Quant(_) => None,
            ShardData::Fp32(t) => Some(t),
        }
    }
}

/// One entry of the per-tensor offset index.
#[derive(Debug, Clone)]
pub struct ShardIndexEntry {
    pub kind: ShardKind,
    pub shape: Vec<usize>,
    pub offset: u64,
    pub len: u64,
    /// CRC-32 of the record bytes, verified on every read. `None` for
    /// version-1 (`SQSH0001`) files, which predate integrity checking.
    pub crc: Option<u32>,
}

/// Byte-counting + checksumming sink: measures a record's encoded length
/// and CRC without holding the bytes, so [`write_sharded`] never buffers a
/// second copy of the payload (this subsystem exists for models that barely
/// fit in RAM once).
struct CountingWriter {
    len: u64,
    hasher: Hasher,
}

impl CountingWriter {
    fn new() -> Self {
        CountingWriter { len: 0, hasher: Hasher::new() }
    }
}

impl Write for CountingWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.len += buf.len() as u64;
        self.hasher.update(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Checksumming source: folds every byte it hands out into a running
/// CRC-32, so [`ShardReader::open`] can verify the v2 header checksum over
/// exactly the bytes it parsed.
struct HashingReader<R> {
    inner: R,
    hasher: Hasher,
}

impl<R: Read> Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.hasher.update(&buf[..n]);
        Ok(n)
    }
}

/// Write `pm` in the sharded format (version 2, `SQSH0002`). Quantized
/// tensors come first (in `BTreeMap` name order), then the FP32 remainder
/// in its stored order — the same deterministic layout every save. Two
/// passes: records are length-counted and checksummed (not buffered) to lay
/// out the index, then streamed straight to the file.
pub fn write_sharded(pm: &PackedModel, path: &Path) -> Result<()> {
    // pass 1: record lengths + CRCs only
    let mut entries: Vec<(&str, u8, &[usize], u64, u32)> = Vec::new();
    for (name, q) in &pm.qmodel.tensors {
        let mut n = CountingWriter::new();
        write_qtensor_record(&mut n, q)?;
        entries.push((name.as_str(), KIND_QUANT, q.shape(), n.len, n.hasher.finish()));
    }
    for (name, t) in &pm.fp32 {
        let mut n = CountingWriter::new();
        write_fp32_record(&mut n, t)?;
        entries.push((name.as_str(), KIND_FP32, t.shape(), n.len, n.hasher.finish()));
    }

    // magic + bits + n_entries + index + trailing header CRC
    let mut header_len: u64 = 8 + 1 + 4 + 4;
    for (name, _, shape, _, _) in &entries {
        header_len += (2 + name.len() + 1 + 1 + 4 * shape.len() + 8 + 8 + 4) as u64;
    }

    // the header is index-sized (small), so buffering it to checksum it is
    // cheap; the records below still stream without a second copy
    let mut header: Vec<u8> = Vec::with_capacity(header_len as usize);
    header.extend_from_slice(MAGIC_V2);
    header.push(pm.qmodel.bits);
    header.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    let mut offset = header_len;
    for (name, kind, shape, len, crc) in &entries {
        write_str(&mut header, name)?;
        header.push(*kind);
        header.push(shape.len() as u8);
        for &d in *shape {
            header.extend_from_slice(&(d as u32).to_le_bytes());
        }
        header.extend_from_slice(&offset.to_le_bytes());
        header.extend_from_slice(&len.to_le_bytes());
        header.extend_from_slice(&crc.to_le_bytes());
        offset += len;
    }

    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&header)?;
    f.write_all(&crc32(&header).to_le_bytes())?;
    // pass 2: stream the records
    for q in pm.qmodel.tensors.values() {
        write_qtensor_record(&mut f, q)?;
    }
    for (_, t) in &pm.fp32 {
        write_fp32_record(&mut f, t)?;
    }
    Ok(())
}

/// Random-access reader over a sharded file: the index lives in memory, the
/// records stay on disk until [`ShardReader::read`] faults them in. The file
/// handle sits behind a `Mutex` so replicas sharing one reader can fault
/// concurrently (one seek+read at a time; the payloads themselves are
/// immutable once materialized).
///
/// Both format versions open transparently: `SQSH0002` headers are verified
/// against their checksum here, and every record read is CRC-checked before
/// parsing; `SQSH0001` files read byte-compatibly without integrity checks.
#[derive(Debug)]
pub struct ShardReader {
    file: Mutex<std::fs::File>,
    index: HashMap<String, ShardIndexEntry>,
    order: Vec<String>,
    bits: u8,
    path: PathBuf,
}

impl ShardReader {
    pub fn open(path: &Path) -> Result<ShardReader> {
        let f = std::fs::File::open(path)?;
        let file_size = f.metadata()?.len();
        let mut r = HashingReader { inner: std::io::BufReader::new(f), hasher: Hasher::new() };
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        let v2 = if &magic == MAGIC_V2 {
            true
        } else if &magic == MAGIC_V1 {
            false
        } else {
            return Err(Error::Checkpoint(format!("{path:?}: bad magic {magic:?}")));
        };
        let bits = read_u8(&mut r)?;
        let n = read_u32(&mut r)? as usize;
        let mut index = HashMap::with_capacity(n);
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            let name = read_str(&mut r)?;
            let kind = match read_u8(&mut r)? {
                KIND_QUANT => ShardKind::Quant,
                KIND_FP32 => ShardKind::Fp32,
                k => {
                    return Err(Error::Checkpoint(format!(
                        "{path:?}: bad shard kind {k} for {name:?}"
                    )))
                }
            };
            let rank = read_u8(&mut r)? as usize;
            let shape: Vec<usize> =
                (0..rank).map(|_| Ok(read_u32(&mut r)? as usize)).collect::<Result<_>>()?;
            let offset = read_u64(&mut r)?;
            let len = read_u64(&mut r)?;
            let crc = if v2 { Some(read_u32(&mut r)?) } else { None };
            match offset.checked_add(len) {
                Some(end) if end <= file_size => {}
                _ => {
                    return Err(Error::Checkpoint(format!(
                        "{path:?}: {name:?} record [{offset}, +{len}) exceeds \
                         file size {file_size} (truncated?)"
                    )))
                }
            }
            if index
                .insert(name.clone(), ShardIndexEntry { kind, shape, offset, len, crc })
                .is_some()
            {
                return Err(Error::Checkpoint(format!("{path:?}: duplicate entry {name:?}")));
            }
            order.push(name);
        }
        if v2 {
            // computed over exactly the header bytes parsed above; must be
            // taken before the stored value passes through the hasher
            let computed = r.hasher.finish();
            let stored = read_u32(&mut r)?;
            if stored != computed {
                return Err(Error::Checkpoint(format!(
                    "{path:?}: header checksum mismatch (stored {stored:#010x}, \
                     computed {computed:#010x}) — corrupt index"
                )));
            }
        }
        let file = Mutex::new(r.inner.into_inner());
        Ok(ShardReader { file, index, order, bits, path: path.to_path_buf() })
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Entry names in file (index) order.
    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn entry(&self, name: &str) -> Option<&ShardIndexEntry> {
        self.index.get(name)
    }

    /// Total record payload bytes (the on-disk cost without index framing) —
    /// comparable to [`PackedModel::payload_bytes`] modulo per-record shape
    /// framing.
    pub fn payload_bytes(&self) -> usize {
        self.index.values().map(|e| e.len as usize).sum()
    }

    /// On-disk record bytes of the **quantized** entries only — the payload
    /// a mixed-precision bit plan controls (FP32 remainder excluded). The
    /// autotuner's budget check re-reads the shards and validates the
    /// in-memory accounting twin of this figure
    /// ([`crate::autotune::BitPlan::validate_sharded`]).
    pub fn quantized_payload_bytes(&self) -> usize {
        self.index
            .values()
            .filter(|e| e.kind == ShardKind::Quant)
            .map(|e| e.len as usize)
            .sum()
    }

    /// Read one record's raw (undecoded) bytes: one seek + one read under
    /// the file lock, nothing else touched. Errors out of here are IO-layer
    /// failures — the retry policy in [`crate::shardstore::paged`] treats
    /// them as transient, unlike [`ShardReader::decode`] integrity errors.
    pub fn read_raw(&self, name: &str) -> Result<Vec<u8>> {
        let e = self
            .index
            .get(name)
            .ok_or_else(|| Error::Checkpoint(format!("{:?}: no shard {name:?}", self.path)))?;
        let mut buf = vec![0u8; e.len as usize];
        {
            // sq-lint: allow(lock-across-io) — this mutex exists to serialize seek+read on the one shared file handle; the IO *is* the critical section
            let mut f = lock_recover(&self.file);
            f.seek(SeekFrom::Start(e.offset))?;
            f.read_exact(&mut buf)?;
        }
        Ok(buf)
    }

    /// Verify and parse one record's bytes (as returned by
    /// [`ShardReader::read_raw`]). For v2 entries the CRC-32 is checked
    /// before any parsing; a mismatch is an integrity error, reported
    /// without touching the payload further.
    pub fn decode(&self, name: &str, bytes: &[u8]) -> Result<ShardData> {
        let e = self
            .index
            .get(name)
            .ok_or_else(|| Error::Checkpoint(format!("{:?}: no shard {name:?}", self.path)))?;
        if let Some(want) = e.crc {
            let got = crc32(bytes);
            if got != want {
                return Err(Error::Checkpoint(format!(
                    "{:?}: {name:?} record checksum mismatch (stored {want:#010x}, \
                     computed {got:#010x}) — corrupt shard",
                    self.path
                )));
            }
        }
        let mut cursor: &[u8] = bytes;
        let data = match e.kind {
            ShardKind::Quant => ShardData::Quant(read_qtensor_record(&mut cursor)?),
            ShardKind::Fp32 => ShardData::Fp32(Arc::new(read_fp32_record(&mut cursor)?)),
        };
        if !cursor.is_empty() {
            return Err(Error::Checkpoint(format!(
                "{:?}: {name:?} record has {} trailing bytes (corrupt index?)",
                self.path,
                cursor.len()
            )));
        }
        Ok(data)
    }

    /// Read, verify and parse one record — [`ShardReader::read_raw`]
    /// followed by [`ShardReader::decode`].
    pub fn read(&self, name: &str) -> Result<ShardData> {
        self.decode(name, &self.read_raw(name)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;
    use crate::model::params::ParamStore;
    use crate::quant::{QConfig, QParams};
    use crate::splitquant::{
        default_quantizable, quantize_store, QuantizedModel, SplitQuantConfig,
    };
    use crate::tensor::packing::Packed;
    use crate::util::rng::Rng;

    fn tiny_packed() -> PackedModel {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, qm) = quantize_store(&store, &q, &SplitQuantConfig::new(2)).unwrap();
        PackedModel::assemble(&store, &qm)
    }

    /// A hand-built model exercising all three `QLayout` variants plus an
    /// FP32 remainder tensor (mirrors `quant::serialize`'s corpus).
    fn all_layouts_packed() -> PackedModel {
        let mut rng = Rng::new(11);
        let mut tensors = std::collections::BTreeMap::new();
        let t = Tensor::randn(&[6, 4], 0.0, 1.0, &mut rng);
        tensors.insert(
            "per_tensor.weight".to_string(),
            QTensor::quantize(&t, &QConfig::baseline(8)).unwrap(),
        );
        let t = Tensor::randn(&[3, 5], 0.0, 1.0, &mut rng);
        tensors.insert(
            "per_channel.weight".to_string(),
            QTensor::quantize(&t, &QConfig::per_channel(4, 0)).unwrap(),
        );
        let values = [0.001f32, 0.002, -0.003, 500.0, 600.0, 700.0];
        let ids: Vec<u8> = vec![0, 0, 0, 1, 1, 1];
        let p0 = QParams::from_range(-0.003, 0.002, 4);
        let p1 = QParams::from_range(0.0, 700.0, 4);
        let codes: Vec<i8> = values
            .iter()
            .zip(&ids)
            .map(|(&v, &c)| if c == 0 { p0.quantize(v) } else { p1.quantize(v) })
            .collect();
        tensors.insert(
            "split.weight".to_string(),
            QTensor::from_split(
                &[6],
                Packed::pack(&codes, 4).unwrap(),
                Packed::pack_unsigned(&ids, 2).unwrap(),
                vec![p0, p1],
            )
            .unwrap(),
        );
        let fp32 =
            vec![("remainder.gamma".to_string(), Tensor::randn(&[7], 0.0, 1.0, &mut rng))];
        let fp32_names = vec!["remainder.gamma".to_string()];
        PackedModel { qmodel: QuantizedModel { tensors, fp32_names, bits: 4 }, fp32 }
    }

    /// Version-1 writer, kept test-only so cross-version compatibility can
    /// be pinned against real `SQSH0001` bytes.
    fn write_sharded_v1(pm: &PackedModel, path: &Path) -> Result<()> {
        let mut entries: Vec<(&str, u8, &[usize], u64)> = Vec::new();
        for (name, q) in &pm.qmodel.tensors {
            let mut n = CountingWriter::new();
            write_qtensor_record(&mut n, q)?;
            entries.push((name.as_str(), KIND_QUANT, q.shape(), n.len));
        }
        for (name, t) in &pm.fp32 {
            let mut n = CountingWriter::new();
            write_fp32_record(&mut n, t)?;
            entries.push((name.as_str(), KIND_FP32, t.shape(), n.len));
        }
        let mut header_len: u64 = 8 + 1 + 4;
        for (name, _, shape, _) in &entries {
            header_len += (2 + name.len() + 1 + 1 + 4 * shape.len() + 8 + 8) as u64;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC_V1)?;
        f.write_all(&[pm.qmodel.bits])?;
        f.write_all(&(entries.len() as u32).to_le_bytes())?;
        let mut offset = header_len;
        for (name, kind, shape, len) in &entries {
            write_str(&mut f, name)?;
            f.write_all(&[*kind])?;
            f.write_all(&[shape.len() as u8])?;
            for &d in *shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            f.write_all(&offset.to_le_bytes())?;
            f.write_all(&len.to_le_bytes())?;
            offset += len;
        }
        for q in pm.qmodel.tensors.values() {
            write_qtensor_record(&mut f, q)?;
        }
        for (_, t) in &pm.fp32 {
            write_fp32_record(&mut f, t)?;
        }
        Ok(())
    }

    #[test]
    fn every_entry_roundtrips() {
        let pm = tiny_packed();
        let path = std::env::temp_dir().join("sq_shard_rt.sqsh");
        write_sharded(&pm, &path).unwrap();
        let r = ShardReader::open(&path).unwrap();
        assert_eq!(r.bits(), pm.qmodel.bits);
        assert_eq!(r.names().len(), pm.qmodel.tensors.len() + pm.fp32.len());
        for (name, q) in &pm.qmodel.tensors {
            let e = r.entry(name).unwrap();
            assert_eq!(e.kind, ShardKind::Quant);
            assert_eq!(e.shape, q.shape());
            assert!(e.crc.is_some(), "{name}: v2 entry lost its CRC");
            match r.read(name).unwrap() {
                ShardData::Quant(got) => assert_eq!(got, *q, "{name}"),
                ShardData::Fp32(_) => panic!("{name}: wrong kind"),
            }
        }
        for (name, t) in &pm.fp32 {
            let e = r.entry(name).unwrap();
            assert_eq!(e.kind, ShardKind::Fp32);
            match r.read(name).unwrap() {
                ShardData::Fp32(got) => assert_eq!(got.data(), t.data(), "{name}"),
                ShardData::Quant(_) => panic!("{name}: wrong kind"),
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn writer_emits_v2_magic() {
        let pm = tiny_packed();
        let path = std::env::temp_dir().join("sq_shard_v2magic.sqsh");
        write_sharded(&pm, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(&bytes[..8], MAGIC_V2);
    }

    #[test]
    fn save_load_save_byte_identity_v2() {
        // the v2 writer must be as deterministic as the v1 one: write the
        // shards, read every record back, reassemble, write again — the two
        // files must be byte-identical (CRCs and header checksum included)
        let pm = all_layouts_packed();
        let p1 = std::env::temp_dir().join("sq_shard_bi_1.sqsh");
        let p2 = std::env::temp_dir().join("sq_shard_bi_2.sqsh");
        write_sharded(&pm, &p1).unwrap();
        let r = ShardReader::open(&p1).unwrap();
        let mut tensors = std::collections::BTreeMap::new();
        let mut fp32 = Vec::new();
        for name in r.names() {
            match r.read(name).unwrap() {
                ShardData::Quant(q) => {
                    tensors.insert(name.clone(), q);
                }
                ShardData::Fp32(t) => fp32.push((name.clone(), (*t).clone())),
            }
        }
        let fp32_names = fp32.iter().map(|(n, _)| n.clone()).collect();
        let reloaded = PackedModel {
            qmodel: QuantizedModel { tensors, fp32_names, bits: r.bits() },
            fp32,
        };
        drop(r);
        write_sharded(&reloaded, &p2).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        let b2 = std::fs::read(&p2).unwrap();
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
        assert_eq!(b1, b2, "v2 save→load→save is not byte-stable");
    }

    #[test]
    fn v1_files_still_read_byte_compatibly() {
        let pm = tiny_packed();
        let path = std::env::temp_dir().join("sq_shard_v1compat.sqsh");
        write_sharded_v1(&pm, &path).unwrap();
        let r = ShardReader::open(&path).unwrap();
        assert_eq!(r.bits(), pm.qmodel.bits);
        for (name, q) in &pm.qmodel.tensors {
            let e = r.entry(name).unwrap();
            assert!(e.crc.is_none(), "{name}: v1 entry grew a CRC from nowhere");
            match r.read(name).unwrap() {
                ShardData::Quant(got) => assert_eq!(got, *q, "{name}"),
                ShardData::Fp32(_) => panic!("{name}: wrong kind"),
            }
        }
        for (name, t) in &pm.fp32 {
            match r.read(name).unwrap() {
                ShardData::Fp32(got) => assert_eq!(got.data(), t.data(), "{name}"),
                ShardData::Quant(_) => panic!("{name}: wrong kind"),
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cross_version_reads_agree() {
        // same model through both writers: identical record bytes, only the
        // index framing differs — every decoded payload must compare equal
        let pm = all_layouts_packed();
        let pv1 = std::env::temp_dir().join("sq_shard_x_v1.sqsh");
        let pv2 = std::env::temp_dir().join("sq_shard_x_v2.sqsh");
        write_sharded_v1(&pm, &pv1).unwrap();
        write_sharded(&pm, &pv2).unwrap();
        let r1 = ShardReader::open(&pv1).unwrap();
        let r2 = ShardReader::open(&pv2).unwrap();
        assert_eq!(r1.names(), r2.names());
        for name in r1.names() {
            match (r1.read(name).unwrap(), r2.read(name).unwrap()) {
                (ShardData::Quant(a), ShardData::Quant(b)) => assert_eq!(a, b, "{name}"),
                (ShardData::Fp32(a), ShardData::Fp32(b)) => {
                    assert_eq!(a.data(), b.data(), "{name}")
                }
                _ => panic!("{name}: kind diverged across versions"),
            }
        }
        std::fs::remove_file(&pv1).ok();
        std::fs::remove_file(&pv2).ok();
    }

    #[test]
    fn payload_corruption_detected_for_every_byte_and_layout() {
        // flip any single record byte — PerTensor, PerChannel, Split or the
        // FP32 remainder — and the CRC must fail that record's read while
        // every untouched record keeps reading cleanly
        let pm = all_layouts_packed();
        let path = std::env::temp_dir().join("sq_shard_flip.sqsh");
        write_sharded(&pm, &path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let spans: Vec<(String, u64, u64)> = {
            let r = ShardReader::open(&path).unwrap();
            r.names()
                .iter()
                .map(|n| {
                    let e = r.entry(n).unwrap();
                    (n.clone(), e.offset, e.len)
                })
                .collect()
        };
        for (name, off, len) in &spans {
            for i in *off..off + len {
                let mut bytes = clean.clone();
                bytes[i as usize] ^= 0x01; // single bit: the hardest case
                std::fs::write(&path, &bytes).unwrap();
                let r = ShardReader::open(&path).unwrap();
                let err = r.read(name).unwrap_err();
                assert!(
                    err.to_string().contains("checksum mismatch"),
                    "{name} byte {i}: flip escaped the CRC: {err}"
                );
                // the sibling records are untouched and still verify
                for (other, _, _) in spans.iter().filter(|(o, _, _)| o != name) {
                    r.read(other).unwrap();
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_corruption_detected_at_open() {
        // any header byte flip — magic, bits, index fields or the stored
        // checksum itself — must fail open, not serve a scrambled index
        let pm = all_layouts_packed();
        let path = std::env::temp_dir().join("sq_shard_hdrflip.sqsh");
        write_sharded(&pm, &path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let header_end = {
            let r = ShardReader::open(&path).unwrap();
            r.index.values().map(|e| e.offset).min().unwrap() as usize
        };
        for i in 0..header_end {
            let mut bytes = clean.clone();
            bytes[i] ^= 0xFF;
            std::fs::write(&path, &bytes).unwrap();
            assert!(
                ShardReader::open(&path).is_err(),
                "open survived a header flip at byte {i}"
            );
        }
        std::fs::write(&path, &clean).unwrap();
        ShardReader::open(&path).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_entry_reads_independently() {
        // reading one shard must not require parsing any other record:
        // corrupt every byte outside the target record + index and read it
        let pm = tiny_packed();
        let path = std::env::temp_dir().join("sq_shard_indep.sqsh");
        write_sharded(&pm, &path).unwrap();
        let (target, expect) = {
            let r = ShardReader::open(&path).unwrap();
            let name = "encoder.0.ffn.out.weight".to_string();
            let e = r.entry(&name).unwrap();
            ((name, e.offset, e.len), r.read("encoder.0.ffn.out.weight").unwrap())
        };
        let mut bytes = std::fs::read(&path).unwrap();
        let r = ShardReader::open(&path).unwrap();
        let header_end = r.index.values().map(|e| e.offset).min().unwrap() as usize;
        drop(r);
        let (name, off, len) = target;
        for (i, b) in bytes.iter_mut().enumerate() {
            let in_header = i < header_end;
            let in_target = (i as u64) >= off && (i as u64) < off + len;
            if !in_header && !in_target {
                *b = 0xAB;
            }
        }
        std::fs::write(&path, &bytes).unwrap();
        let r = ShardReader::open(&path).unwrap();
        match (r.read(&name).unwrap(), expect) {
            (ShardData::Quant(a), ShardData::Quant(b)) => assert_eq!(a, b),
            _ => panic!("kind changed"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncation_detected_at_open() {
        let pm = tiny_packed();
        let path = std::env::temp_dir().join("sq_shard_trunc.sqsh");
        write_sharded(&pm, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for frac in [1, 2, 4, 9] {
            std::fs::write(&path, &bytes[..bytes.len() * frac / 10]).unwrap();
            assert!(ShardReader::open(&path).is_err(), "open survived {frac}0% prefix");
        }
        // even one missing byte invalidates the last record's bounds
        std::fs::write(&path, &bytes[..bytes.len() - 1]).unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = std::env::temp_dir().join("sq_shard_magic.sqsh");
        std::fs::write(&path, b"SQQM0001 not a shard file").unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
