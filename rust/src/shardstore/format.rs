//! The sharded `SQSH0001` on-disk format: an `SQQM0001` payload re-framed
//! behind a per-tensor offset index so any single layer's record (packed
//! codes + cid plane + params, or an FP32 remainder tensor) can be read
//! with one seek + one read, independently of the rest of the file.
//!
//! ```text
//! magic "SQSH0001"
//! u8    bits                      (provenance; each Packed carries its own)
//! u32   n_entries
//! index, per entry:
//!   u16+bytes  name
//!   u8         kind               (0 = quantized, 1 = fp32)
//!   u8 rank, u32×rank dims        (shape, for classification without IO)
//!   u64        offset             (absolute file offset of the record)
//!   u64        len                (record length in bytes)
//! records, concatenated:
//!   quantized: shape, layout tag (+axis / +cid plane), params, codes
//!   fp32:      shape, raw f32 LE payload
//! ```
//!
//! Record encodings are byte-identical to the per-tensor sections of
//! `SQQM0001` (shared helpers in [`crate::quant::serialize`]); the index is
//! the only addition. `offset`/`len` are validated against the file size at
//! open, so truncated files fail fast instead of at first fault.

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::quant::serialize::{
    read_fp32_record, read_qtensor_record, read_str, write_fp32_record, write_qtensor_record,
    write_str,
};
use crate::quant::{PackedModel, QTensor};
use crate::tensor::Tensor;
use crate::util::io::{read_u32, read_u64, read_u8};
use crate::util::sync::lock_recover;

const MAGIC: &[u8; 8] = b"SQSH0001";

const KIND_QUANT: u8 = 0;
const KIND_FP32: u8 = 1;

/// What kind of record an index entry points at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardKind {
    /// Packed quantized tensor (codes + optional cid plane + params).
    Quant,
    /// FP32 remainder tensor (LayerNorm, position embedding, biases, …).
    Fp32,
}

/// One shard's payload, as materialized from disk. FP32 tensors sit behind
/// an [`Arc`] so a [`crate::model::params::ParamStore`] can share the same
/// allocation via `push_shared` instead of copying the data out.
#[derive(Debug, Clone)]
pub enum ShardData {
    Quant(QTensor),
    Fp32(Arc<Tensor>),
}

impl ShardData {
    pub fn as_quant(&self) -> Option<&QTensor> {
        match self {
            ShardData::Quant(q) => Some(q),
            ShardData::Fp32(_) => None,
        }
    }

    pub fn as_fp32(&self) -> Option<&Arc<Tensor>> {
        match self {
            ShardData::Quant(_) => None,
            ShardData::Fp32(t) => Some(t),
        }
    }
}

/// One entry of the per-tensor offset index.
#[derive(Debug, Clone)]
pub struct ShardIndexEntry {
    pub kind: ShardKind,
    pub shape: Vec<usize>,
    pub offset: u64,
    pub len: u64,
}

/// Byte-counting sink: measures a record's encoded length without holding
/// the bytes, so [`write_sharded`] never buffers a second copy of the
/// payload (this subsystem exists for models that barely fit in RAM once).
struct CountingWriter(u64);

impl Write for CountingWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0 += buf.len() as u64;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Write `pm` in the sharded format. Quantized tensors come first (in
/// `BTreeMap` name order), then the FP32 remainder in its stored order —
/// the same deterministic layout every save. Two passes: records are
/// length-counted (not buffered) to lay out the index, then streamed
/// straight to the file.
pub fn write_sharded(pm: &PackedModel, path: &Path) -> Result<()> {
    // pass 1: record lengths only
    let mut entries: Vec<(&str, u8, &[usize], u64)> = Vec::new();
    for (name, q) in &pm.qmodel.tensors {
        let mut n = CountingWriter(0);
        write_qtensor_record(&mut n, q)?;
        entries.push((name.as_str(), KIND_QUANT, q.shape(), n.0));
    }
    for (name, t) in &pm.fp32 {
        let mut n = CountingWriter(0);
        write_fp32_record(&mut n, t)?;
        entries.push((name.as_str(), KIND_FP32, t.shape(), n.0));
    }

    let mut header_len: u64 = 8 + 1 + 4; // magic + bits + n_entries
    for (name, _, shape, _) in &entries {
        header_len += (2 + name.len() + 1 + 1 + 4 * shape.len() + 8 + 8) as u64;
    }

    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&[pm.qmodel.bits])?;
    f.write_all(&(entries.len() as u32).to_le_bytes())?;
    let mut offset = header_len;
    for (name, kind, shape, len) in &entries {
        write_str(&mut f, name)?;
        f.write_all(&[*kind])?;
        f.write_all(&[shape.len() as u8])?;
        for &d in *shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&offset.to_le_bytes())?;
        f.write_all(&len.to_le_bytes())?;
        offset += len;
    }
    // pass 2: stream the records
    for q in pm.qmodel.tensors.values() {
        write_qtensor_record(&mut f, q)?;
    }
    for (_, t) in &pm.fp32 {
        write_fp32_record(&mut f, t)?;
    }
    Ok(())
}

/// Random-access reader over a sharded file: the index lives in memory, the
/// records stay on disk until [`ShardReader::read`] faults them in. The file
/// handle sits behind a `Mutex` so replicas sharing one reader can fault
/// concurrently (one seek+read at a time; the payloads themselves are
/// immutable once materialized).
#[derive(Debug)]
pub struct ShardReader {
    file: Mutex<std::fs::File>,
    index: HashMap<String, ShardIndexEntry>,
    order: Vec<String>,
    bits: u8,
    path: PathBuf,
}

impl ShardReader {
    pub fn open(path: &Path) -> Result<ShardReader> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let file_size = f.get_ref().metadata()?.len();
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Checkpoint(format!("{path:?}: bad magic {magic:?}")));
        }
        let bits = read_u8(&mut f)?;
        let n = read_u32(&mut f)? as usize;
        let mut index = HashMap::with_capacity(n);
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            let name = read_str(&mut f)?;
            let kind = match read_u8(&mut f)? {
                KIND_QUANT => ShardKind::Quant,
                KIND_FP32 => ShardKind::Fp32,
                k => {
                    return Err(Error::Checkpoint(format!(
                        "{path:?}: bad shard kind {k} for {name:?}"
                    )))
                }
            };
            let rank = read_u8(&mut f)? as usize;
            let shape: Vec<usize> =
                (0..rank).map(|_| Ok(read_u32(&mut f)? as usize)).collect::<Result<_>>()?;
            let offset = read_u64(&mut f)?;
            let len = read_u64(&mut f)?;
            match offset.checked_add(len) {
                Some(end) if end <= file_size => {}
                _ => {
                    return Err(Error::Checkpoint(format!(
                        "{path:?}: {name:?} record [{offset}, +{len}) exceeds \
                         file size {file_size} (truncated?)"
                    )))
                }
            }
            if index
                .insert(name.clone(), ShardIndexEntry { kind, shape, offset, len })
                .is_some()
            {
                return Err(Error::Checkpoint(format!("{path:?}: duplicate entry {name:?}")));
            }
            order.push(name);
        }
        let file = Mutex::new(f.into_inner());
        Ok(ShardReader { file, index, order, bits, path: path.to_path_buf() })
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Entry names in file (index) order.
    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn entry(&self, name: &str) -> Option<&ShardIndexEntry> {
        self.index.get(name)
    }

    /// Total record payload bytes (the on-disk cost without index framing) —
    /// comparable to [`PackedModel::payload_bytes`] modulo per-record shape
    /// framing.
    pub fn payload_bytes(&self) -> usize {
        self.index.values().map(|e| e.len as usize).sum()
    }

    /// On-disk record bytes of the **quantized** entries only — the payload
    /// a mixed-precision bit plan controls (FP32 remainder excluded). The
    /// autotuner's budget check re-reads the shards and validates the
    /// in-memory accounting twin of this figure
    /// ([`crate::autotune::BitPlan::validate_sharded`]).
    pub fn quantized_payload_bytes(&self) -> usize {
        self.index
            .values()
            .filter(|e| e.kind == ShardKind::Quant)
            .map(|e| e.len as usize)
            .sum()
    }

    /// Read and parse one record: one seek + one read, nothing else touched.
    pub fn read(&self, name: &str) -> Result<ShardData> {
        let e = self
            .index
            .get(name)
            .ok_or_else(|| Error::Checkpoint(format!("{:?}: no shard {name:?}", self.path)))?;
        let mut buf = vec![0u8; e.len as usize];
        {
            // sq-lint: allow(lock-across-io) — this mutex exists to serialize seek+read on the one shared file handle; the IO *is* the critical section
            let mut f = lock_recover(&self.file);
            f.seek(SeekFrom::Start(e.offset))?;
            f.read_exact(&mut buf)?;
        }
        let mut cursor: &[u8] = &buf;
        let data = match e.kind {
            ShardKind::Quant => ShardData::Quant(read_qtensor_record(&mut cursor)?),
            ShardKind::Fp32 => ShardData::Fp32(Arc::new(read_fp32_record(&mut cursor)?)),
        };
        if !cursor.is_empty() {
            return Err(Error::Checkpoint(format!(
                "{:?}: {name:?} record has {} trailing bytes (corrupt index?)",
                self.path,
                cursor.len()
            )));
        }
        Ok(data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;
    use crate::model::params::ParamStore;
    use crate::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
    use crate::util::rng::Rng;

    fn tiny_packed() -> PackedModel {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, qm) = quantize_store(&store, &q, &SplitQuantConfig::new(2)).unwrap();
        PackedModel::assemble(&store, &qm)
    }

    #[test]
    fn every_entry_roundtrips() {
        let pm = tiny_packed();
        let path = std::env::temp_dir().join("sq_shard_rt.sqsh");
        write_sharded(&pm, &path).unwrap();
        let r = ShardReader::open(&path).unwrap();
        assert_eq!(r.bits(), pm.qmodel.bits);
        assert_eq!(r.names().len(), pm.qmodel.tensors.len() + pm.fp32.len());
        for (name, q) in &pm.qmodel.tensors {
            let e = r.entry(name).unwrap();
            assert_eq!(e.kind, ShardKind::Quant);
            assert_eq!(e.shape, q.shape());
            match r.read(name).unwrap() {
                ShardData::Quant(got) => assert_eq!(got, *q, "{name}"),
                ShardData::Fp32(_) => panic!("{name}: wrong kind"),
            }
        }
        for (name, t) in &pm.fp32 {
            let e = r.entry(name).unwrap();
            assert_eq!(e.kind, ShardKind::Fp32);
            match r.read(name).unwrap() {
                ShardData::Fp32(got) => assert_eq!(got.data(), t.data(), "{name}"),
                ShardData::Quant(_) => panic!("{name}: wrong kind"),
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_entry_reads_independently() {
        // reading one shard must not require parsing any other record:
        // corrupt every byte outside the target record + index and read it
        let pm = tiny_packed();
        let path = std::env::temp_dir().join("sq_shard_indep.sqsh");
        write_sharded(&pm, &path).unwrap();
        let (target, expect) = {
            let r = ShardReader::open(&path).unwrap();
            let name = "encoder.0.ffn.out.weight".to_string();
            let e = r.entry(&name).unwrap();
            ((name, e.offset, e.len), r.read("encoder.0.ffn.out.weight").unwrap())
        };
        let mut bytes = std::fs::read(&path).unwrap();
        let r = ShardReader::open(&path).unwrap();
        let header_end = r.index.values().map(|e| e.offset).min().unwrap() as usize;
        drop(r);
        let (name, off, len) = target;
        for (i, b) in bytes.iter_mut().enumerate() {
            let in_header = i < header_end;
            let in_target = (i as u64) >= off && (i as u64) < off + len;
            if !in_header && !in_target {
                *b = 0xAB;
            }
        }
        std::fs::write(&path, &bytes).unwrap();
        let r = ShardReader::open(&path).unwrap();
        match (r.read(&name).unwrap(), expect) {
            (ShardData::Quant(a), ShardData::Quant(b)) => assert_eq!(a, b),
            _ => panic!("kind changed"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncation_detected_at_open() {
        let pm = tiny_packed();
        let path = std::env::temp_dir().join("sq_shard_trunc.sqsh");
        write_sharded(&pm, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for frac in [1, 2, 4, 9] {
            std::fs::write(&path, &bytes[..bytes.len() * frac / 10]).unwrap();
            assert!(ShardReader::open(&path).is_err(), "open survived {frac}0% prefix");
        }
        // even one missing byte invalidates the last record's bounds
        std::fs::write(&path, &bytes[..bytes.len() - 1]).unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = std::env::temp_dir().join("sq_shard_magic.sqsh");
        std::fs::write(&path, b"SQQM0001 not a shard file").unwrap();
        assert!(ShardReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
