//! Deterministic fault injection and bounded retry for the shard IO path.
//!
//! Serving survives disks, not the other way round: a flipped bit or a
//! transient `EIO` on the paged path must degrade one request, never the
//! process, and every failure mode must be reproducible in a test. This
//! module provides the three pieces:
//!
//! * [`ShardIo`] — the seam all raw shard reads go through.
//!   [`crate::shardstore::ShardReader`] is the real implementation;
//!   [`crate::shardstore::PagedModel`] holds a `dyn ShardIo` so a decorator
//!   can slot in between the reader and the residency layer.
//! * [`FaultyIo`] — a seeded decorator that injects IO errors, short reads,
//!   byte corruption and latency stalls on a schedule derived from
//!   [`crate::util::rng`]. The schedule is a pure function of
//!   `(seed, shard name, per-shard read number)`, so concurrent worker
//!   interleavings cannot change which reads fail — the chaos tests replay
//!   the exact same faults every run. Not constructed at all in production
//!   (the decorator is only installed when a [`FaultConfig`] is given), so
//!   the fault-free path pays nothing.
//! * [`RetryPolicy`] — bounded retry with exponential backoff, the contract
//!   the paged model applies around every shard read: re-read on checksum
//!   mismatch or transient error, give up (and quarantine the shard) after
//!   `max_attempts`. The sleep is injectable, so tests assert the exact
//!   backoff sequence with a recording clock and zero real sleeping.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::shardstore::format::ShardReader;
use crate::util::rng::Rng;
use crate::util::sync::lock_recover;

/// The raw shard-read seam: everything the paged path reads from disk comes
/// through here as undecoded record bytes (CRC verification and parsing
/// happen above, in [`ShardReader::decode`], so injected corruption is
/// caught exactly like real corruption).
pub trait ShardIo: Send + Sync + std::fmt::Debug {
    /// Read the raw (undecoded) bytes of shard `name`.
    fn read_raw(&self, name: &str) -> Result<Vec<u8>>;
}

impl ShardIo for ShardReader {
    fn read_raw(&self, name: &str) -> Result<Vec<u8>> {
        ShardReader::read_raw(self, name)
    }
}

/// Shared handles are first-class IO sources: the paged model keeps one
/// `Arc<ShardReader>` and hands a clone to the decorator.
impl<T: ShardIo + ?Sized> ShardIo for Arc<T> {
    fn read_raw(&self, name: &str) -> Result<Vec<u8>> {
        (**self).read_raw(name)
    }
}

/// What [`FaultyIo`] injects and how often. All rates are per-read
/// probabilities in `[0, 1]`, drawn independently in the fixed order
/// error → short read → corruption → stall (the first hit wins). The
/// default injects nothing.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Seed for the deterministic fault schedule.
    pub seed: u64,
    /// Probability a read fails outright with an injected IO error.
    pub error_rate: f64,
    /// Probability a read returns fewer bytes than the record holds (the
    /// CRC layer must catch the truncation).
    pub short_read_rate: f64,
    /// Probability one byte of the returned record is flipped (the CRC
    /// layer must catch the corruption).
    pub corrupt_rate: f64,
    /// Probability a read stalls for [`FaultConfig::stall`] before
    /// succeeding (models a slow disk, exercises tail latency — never an
    /// error).
    pub stall_rate: f64,
    /// Injected latency when a stall fires.
    pub stall: Duration,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            error_rate: 0.0,
            short_read_rate: 0.0,
            corrupt_rate: 0.0,
            stall_rate: 0.0,
            stall: Duration::from_millis(1),
        }
    }
}

impl FaultConfig {
    /// Convenience for the serve-example knobs: the same `rate` for each
    /// failing fault kind (errors, short reads, corruption), no stalls.
    pub fn uniform(seed: u64, rate: f64) -> FaultConfig {
        FaultConfig {
            seed,
            error_rate: rate,
            short_read_rate: rate,
            corrupt_rate: rate,
            ..FaultConfig::default()
        }
    }

    /// Whether this config can ever inject anything.
    pub fn is_noop(&self) -> bool {
        self.error_rate <= 0.0
            && self.short_read_rate <= 0.0
            && self.corrupt_rate <= 0.0
            && self.stall_rate <= 0.0
    }
}

/// Counts of what a [`FaultyIo`] actually injected — the ground truth the
/// chaos tests reconcile the serving metrics against
/// (`integrity_failures == short_reads + corruptions`, and every injected
/// failure is either retried or ends in a quarantine).
#[derive(Debug, Default)]
pub struct FaultStats {
    io_errors: AtomicU64,
    short_reads: AtomicU64,
    corruptions: AtomicU64,
    stalls: AtomicU64,
}

impl FaultStats {
    pub fn io_errors(&self) -> u64 {
        self.io_errors.load(Ordering::Relaxed)
    }

    pub fn short_reads(&self) -> u64 {
        self.short_reads.load(Ordering::Relaxed)
    }

    pub fn corruptions(&self) -> u64 {
        self.corruptions.load(Ordering::Relaxed)
    }

    pub fn stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Injected *failures* (stalls succeed, so they are excluded).
    pub fn injected_failures(&self) -> u64 {
        self.io_errors() + self.short_reads() + self.corruptions()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fault {
    Error,
    ShortRead,
    Corrupt,
    Stall,
}

/// Seeded fault-injecting [`ShardIo`] decorator. See the module docs for
/// the determinism contract; see [`FaultConfig`] for the knobs.
#[derive(Debug)]
pub struct FaultyIo<I> {
    inner: I,
    cfg: FaultConfig,
    stats: Arc<FaultStats>,
    /// Per-shard read sequence numbers. The schedule keys on
    /// `(seed, name, k)` — not on a global call counter — so cross-thread
    /// interleaving of different shards cannot perturb it.
    seq: Mutex<HashMap<String, u64>>,
}

impl<I> FaultyIo<I> {
    pub fn new(inner: I, cfg: FaultConfig) -> FaultyIo<I> {
        FaultyIo {
            inner,
            cfg,
            stats: Arc::new(FaultStats::default()),
            seq: Mutex::new(HashMap::new()),
        }
    }

    /// Handle to the injection counters (shared, updated live).
    pub fn stats(&self) -> Arc<FaultStats> {
        Arc::clone(&self.stats)
    }

    /// The deterministic per-read RNG: forked from the seed by shard name
    /// and per-shard read number only.
    fn rng(&self, name: &str, k: u64) -> Rng {
        Rng::new(self.cfg.seed ^ name_tag(name)).fork(k)
    }

    fn decide(&self, rng: &mut Rng) -> Option<Fault> {
        // fixed draw order keeps the schedule stable when one rate changes
        if rng.chance(self.cfg.error_rate) {
            return Some(Fault::Error);
        }
        if rng.chance(self.cfg.short_read_rate) {
            return Some(Fault::ShortRead);
        }
        if rng.chance(self.cfg.corrupt_rate) {
            return Some(Fault::Corrupt);
        }
        if rng.chance(self.cfg.stall_rate) {
            return Some(Fault::Stall);
        }
        None
    }
}

/// FNV-1a of the shard name — folds the name into the schedule seed.
fn name_tag(name: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl<I: ShardIo> ShardIo for FaultyIo<I> {
    fn read_raw(&self, name: &str) -> Result<Vec<u8>> {
        let k = {
            let mut seq = lock_recover(&self.seq);
            let e = seq.entry(name.to_string()).or_insert(0);
            let k = *e;
            *e += 1;
            k
        };
        let mut rng = self.rng(name, k);
        match self.decide(&mut rng) {
            None => self.inner.read_raw(name),
            Some(Fault::Error) => {
                self.stats.io_errors.fetch_add(1, Ordering::Relaxed);
                Err(Error::Io(std::io::Error::other(format!(
                    "injected IO error on shard {name:?} (read #{k})"
                ))))
            }
            Some(Fault::ShortRead) => {
                let mut buf = self.inner.read_raw(name)?;
                if buf.is_empty() {
                    return Ok(buf);
                }
                self.stats.short_reads.fetch_add(1, Ordering::Relaxed);
                let keep = rng.below(buf.len());
                buf.truncate(keep);
                Ok(buf)
            }
            Some(Fault::Corrupt) => {
                let mut buf = self.inner.read_raw(name)?;
                if buf.is_empty() {
                    return Ok(buf);
                }
                self.stats.corruptions.fetch_add(1, Ordering::Relaxed);
                let at = rng.below(buf.len());
                let bit = rng.below(8) as u8;
                if let Some(b) = buf.get_mut(at) {
                    *b ^= 1 << bit;
                }
                Ok(buf)
            }
            Some(Fault::Stall) => {
                self.stats.stalls.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.cfg.stall);
                self.inner.read_raw(name)
            }
        }
    }
}

/// Bounded retry with exponential backoff — the contract the paged model
/// applies around shard reads.
///
/// Attempt `1` runs immediately; before re-attempt `r` (`2..=max_attempts`)
/// the caller sleeps [`RetryPolicy::backoff`]`(r - 1)` =
/// `min(cap, base · 2^(r-2))`. No jitter: the serving stack's determinism
/// contract extends to its failure handling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts, the first one included. `1` means no retries; `0`
    /// is treated as `1` (at least one attempt always runs).
    pub max_attempts: u32,
    /// Backoff before the first re-attempt.
    pub base: Duration,
    /// Ceiling on any single backoff sleep.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    /// 3 attempts, 500µs base, 20ms cap — a transient hiccup costs
    /// microseconds, a dead shard is declared within ~1 batch window.
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            base: Duration::from_micros(500),
            cap: Duration::from_millis(20),
        }
    }
}

impl RetryPolicy {
    /// Backoff before re-attempt number `retry` (1-based):
    /// `min(cap, base · 2^(retry-1))`.
    pub fn backoff(&self, retry: u32) -> Duration {
        let exp = retry.saturating_sub(1).min(31);
        self.base.saturating_mul(1u32 << exp).min(self.cap)
    }

    /// Drive `attempt` (called with the 1-based attempt number) until it
    /// succeeds or `max_attempts` is exhausted, sleeping the deterministic
    /// backoff between tries via `sleep`. The sleep is injectable so tests
    /// run on a recording clock; production passes `std::thread::sleep`.
    /// A first-try success calls `sleep` zero times.
    pub fn run<T>(
        &self,
        mut sleep: impl FnMut(Duration),
        mut attempt: impl FnMut(u32) -> Result<T>,
    ) -> Result<T> {
        let max = self.max_attempts.max(1);
        let mut tried = 0u32;
        // sq-lint: allow(bounded-retry) — this IS the bounded-retry primitive: `tried` counts up to `max` (= max_attempts) and the Err arm below returns when it is reached
        loop {
            tried += 1;
            match attempt(tried) {
                Ok(v) => return Ok(v),
                Err(e) if tried >= max => return Err(e),
                Err(_) => sleep(self.backoff(tried)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// An in-memory ShardIo for decorator tests: every name reads the same
    /// payload.
    #[derive(Debug)]
    struct MemIo(Vec<u8>);

    impl ShardIo for MemIo {
        fn read_raw(&self, _name: &str) -> Result<Vec<u8>> {
            Ok(self.0.clone())
        }
    }

    #[test]
    fn backoff_sequence_is_exact_and_capped() {
        let p = RetryPolicy {
            max_attempts: 8,
            base: Duration::from_millis(2),
            cap: Duration::from_millis(9),
        };
        let got: Vec<Duration> = (1..=5).map(|r| p.backoff(r)).collect();
        let want = [
            Duration::from_millis(2),
            Duration::from_millis(4),
            Duration::from_millis(8),
            Duration::from_millis(9), // 16ms hits the 9ms cap
            Duration::from_millis(9),
        ];
        assert_eq!(got, want);
        // enormous retry numbers must not overflow past the cap
        assert_eq!(p.backoff(1000), Duration::from_millis(9));
    }

    #[test]
    fn run_sleeps_exact_backoffs_then_succeeds() {
        let p = RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(100),
        };
        let mut slept: Vec<Duration> = Vec::new();
        let mut calls = 0u32;
        let out = p.run(
            |d| slept.push(d),
            |k| {
                calls += 1;
                assert_eq!(k, calls, "attempt numbering");
                if k < 3 {
                    Err(Error::Coordinator("transient".into()))
                } else {
                    Ok(k)
                }
            },
        );
        assert_eq!(out.unwrap(), 3);
        assert_eq!(calls, 3);
        assert_eq!(slept, vec![Duration::from_millis(1), Duration::from_millis(2)]);
    }

    #[test]
    fn run_zero_sleeps_on_first_try_success() {
        let p = RetryPolicy::default();
        let mut sleeps = 0usize;
        let out = p.run(|_| sleeps += 1, |_| Ok(42));
        assert_eq!(out.unwrap(), 42);
        assert_eq!(sleeps, 0, "first-try success must not sleep");
    }

    #[test]
    fn run_exhausts_at_max_attempts() {
        let p = RetryPolicy {
            max_attempts: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(100),
        };
        let mut slept: Vec<Duration> = Vec::new();
        let mut calls = 0u32;
        let out: Result<()> = p.run(
            |d| slept.push(d),
            |_| {
                calls += 1;
                Err(Error::Coordinator("still down".into()))
            },
        );
        assert!(out.is_err());
        assert_eq!(calls, 3, "must stop exactly at max_attempts");
        // the final failure is not followed by a sleep
        assert_eq!(slept, vec![Duration::from_millis(1), Duration::from_millis(2)]);
    }

    #[test]
    fn zero_max_attempts_still_runs_once() {
        let p = RetryPolicy { max_attempts: 0, ..RetryPolicy::default() };
        let mut calls = 0u32;
        let out: Result<()> = p.run(
            |_| {},
            |_| {
                calls += 1;
                Err(Error::Coordinator("down".into()))
            },
        );
        assert!(out.is_err());
        assert_eq!(calls, 1);
    }

    #[test]
    fn faulty_io_schedule_is_deterministic() {
        let cfg = FaultConfig::uniform(42, 0.3);
        let payload: Vec<u8> = (0u8..64).collect();
        let run = || {
            let io = FaultyIo::new(MemIo(payload.clone()), cfg.clone());
            let mut outcomes = Vec::new();
            for name in ["a", "b", "c"] {
                for _ in 0..32 {
                    outcomes.push(match io.read_raw(name) {
                        Ok(buf) => format!("ok:{}:{:08x}", buf.len(), crate::util::crc32::crc32(&buf)),
                        Err(e) => format!("err:{e}"),
                    });
                }
            }
            let s = io.stats();
            (outcomes, s.io_errors(), s.short_reads(), s.corruptions())
        };
        let (o1, e1, s1, c1) = run();
        let (o2, e2, s2, c2) = run();
        assert_eq!(o1, o2, "fault schedule not reproducible");
        assert_eq!((e1, s1, c1), (e2, s2, c2));
        assert!(e1 + s1 + c1 > 0, "0.3 rates over 96 reads injected nothing");
    }

    #[test]
    fn faulty_io_schedule_survives_interleaving() {
        // the k-th read of a given shard gets the same outcome no matter
        // how reads of other shards interleave with it
        let cfg = FaultConfig::uniform(7, 0.4);
        let payload: Vec<u8> = (0u8..32).collect();
        let outcome = |io: &FaultyIo<MemIo>, name: &str| match io.read_raw(name) {
            Ok(buf) => format!("ok:{buf:?}"),
            Err(_) => "err".to_string(),
        };
        let io1 = FaultyIo::new(MemIo(payload.clone()), cfg.clone());
        let a_then_b: Vec<String> = {
            let mut v: Vec<String> = (0..16).map(|_| outcome(&io1, "a")).collect();
            v.extend((0..16).map(|_| outcome(&io1, "b")));
            v
        };
        let io2 = FaultyIo::new(MemIo(payload), cfg);
        let interleaved: Vec<String> = {
            let pairs: Vec<(String, String)> =
                (0..16).map(|_| (outcome(&io2, "a"), outcome(&io2, "b"))).collect();
            let mut a: Vec<String> = pairs.iter().map(|(x, _)| x.clone()).collect();
            a.extend(pairs.into_iter().map(|(_, y)| y));
            a
        };
        assert_eq!(a_then_b, interleaved);
    }

    #[test]
    fn noop_config_injects_nothing() {
        assert!(FaultConfig::default().is_noop());
        let io = FaultyIo::new(MemIo(vec![1, 2, 3]), FaultConfig::default());
        for _ in 0..100 {
            assert_eq!(io.read_raw("x").unwrap(), vec![1, 2, 3]);
        }
        assert_eq!(io.stats().injected_failures(), 0);
        assert_eq!(io.stats().stalls(), 0);
    }

    #[test]
    fn corruption_always_changes_the_payload() {
        let payload: Vec<u8> = (0u8..64).collect();
        let cfg = FaultConfig { seed: 3, corrupt_rate: 1.0, ..FaultConfig::default() };
        let io = FaultyIo::new(MemIo(payload.clone()), cfg);
        for _ in 0..64 {
            let got = io.read_raw("w").unwrap();
            assert_eq!(got.len(), payload.len());
            assert_ne!(got, payload, "corruption fault returned clean bytes");
        }
        assert_eq!(io.stats().corruptions(), 64);
    }

    #[test]
    fn short_read_always_shortens() {
        let payload: Vec<u8> = (0u8..64).collect();
        let cfg = FaultConfig { seed: 5, short_read_rate: 1.0, ..FaultConfig::default() };
        let io = FaultyIo::new(MemIo(payload.clone()), cfg);
        for _ in 0..64 {
            let got = io.read_raw("w").unwrap();
            assert!(got.len() < payload.len(), "short read returned {} bytes", got.len());
        }
        assert_eq!(io.stats().short_reads(), 64);
    }
}
