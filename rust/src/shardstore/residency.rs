//! Byte-budgeted shard residency: LRU eviction over unpinned shards, with
//! pinned (always-hot) entries tracked separately.
//!
//! The manager is the single accounting authority for "what is in RAM" on
//! the paged serving path. Its invariants, property-tested below:
//!
//! * **Budget**: the summed bytes of *unpinned* resident shards never
//!   exceed the budget after any `admit_fault`, provided every individual
//!   shard fits in the budget by itself. (A shard larger than the whole
//!   budget is admitted anyway — refusing would deadlock serving — and is
//!   evicted as soon as anything else faults; this shows up as
//!   `resident_bytes > budget` and a `log::warn`.)
//! * **Pinning**: pinned entries are never evicted and never count against
//!   the budget. Pins hold what must stay hot regardless of traffic
//!   (embeddings, LayerNorm, biases — the FP32 remainder).
//! * **LRU**: eviction removes the least-recently-used unpinned shard
//!   first, where "use" is a `get` hit or the original admit. Recency is a
//!   monotonic counter, not wall time, so behavior is deterministic.
//! * **Prefetch never evicts**: `admit_prefetch` only caches when the shard
//!   fits in the spare budget; speculative reads can never push demand-
//!   fetched shards out.
//!
//! Shared residency: the manager sits behind the `Arc` inside
//! [`crate::shardstore::PagedModel`], so N serving replicas cloned from one
//! paged model hold ~1× resident shard bytes between them, matching the
//! `ParamStore::share` semantics of `tests/integration_share.rs`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::format::ShardData;
use crate::util::sync::lock_recover;

/// Counter snapshot (see [`ResidencyManager::counters`]). The first three
/// are surfaced as serving metrics
/// ([`crate::coordinator::Metrics::shard_faults`] & co).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResidencyCounters {
    /// demand misses: a needed shard was not resident and was read from disk
    pub shard_faults: usize,
    /// unpinned shards pushed out to fit a faulting shard under the budget
    pub shard_evictions: usize,
    /// total bytes read from the shard file (faults + prefetches + pins)
    pub bytes_paged_in: usize,
    /// `get` calls answered from residency
    pub shard_hits: usize,
    /// shards cached ahead of use by sequential prefetch
    pub shard_prefetches: usize,
    /// current unpinned resident bytes (the budget-governed figure)
    pub resident_bytes: usize,
    /// current pinned resident bytes (not budget-governed)
    pub pinned_bytes: usize,
    /// high-water mark of `resident_bytes`
    pub peak_resident_bytes: usize,
    /// cumulative wall time (ns) spent in demand-fault disk reads — the
    /// serving coordinator diffs this around each batch to attribute fault
    /// time in the per-request latency breakdown
    pub fault_ns: u64,
    /// shard reads whose payload failed its CRC (or decode) — detected
    /// corruption, each one retried under the paged model's `RetryPolicy`
    pub integrity_failures: usize,
    /// re-read attempts after a failed shard read (transient IO error or
    /// integrity failure); first-try successes contribute nothing
    pub io_retries: usize,
    /// shards whose reads exhausted every retry attempt and were
    /// quarantined — subsequent fetches fail fast per-request instead of
    /// hammering a bad disk region
    pub shards_quarantined: usize,
}

struct Slot {
    data: Arc<ShardData>,
    bytes: usize,
    pinned: bool,
    last_use: u64,
}

struct Inner {
    slots: HashMap<String, Slot>,
    clock: u64,
    c: ResidencyCounters,
    /// shards already warned about as over-budget — a shard larger than
    /// the whole budget re-faults every pass, and one warn per fault would
    /// flood stderr on the serving hot path
    warned_oversized: std::collections::HashSet<String>,
}

/// Budgeted LRU cache of materialized shards. All methods take `&self`; the
/// interior `Mutex` makes one manager safely shareable across serving
/// replicas and worker threads.
pub struct ResidencyManager {
    budget: usize,
    inner: Mutex<Inner>,
}

impl ResidencyManager {
    /// `budget` bounds the summed bytes of unpinned resident shards. Use
    /// `usize::MAX` for an effectively unbounded (fully resident) cache.
    pub fn new(budget: usize) -> ResidencyManager {
        ResidencyManager {
            budget,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                clock: 0,
                c: ResidencyCounters::default(),
                warned_oversized: std::collections::HashSet::new(),
            }),
        }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Look up a resident shard, refreshing its recency. `None` means the
    /// caller must fault it in via [`ResidencyManager::admit_fault`].
    pub fn get(&self, name: &str) -> Option<Arc<ShardData>> {
        let mut g = lock_recover(&self.inner);
        g.clock += 1;
        let clock = g.clock;
        match g.slots.get_mut(name) {
            Some(slot) => {
                slot.last_use = clock;
                let data = Arc::clone(&slot.data);
                g.c.shard_hits += 1;
                Some(data)
            }
            None => None,
        }
    }

    /// Admit a demand-faulted shard, evicting LRU unpinned shards until it
    /// fits the budget. Returns the resident handle — if another thread won
    /// the race, theirs (the bytes just read are dropped, nothing double-
    /// counted as resident).
    pub fn admit_fault(&self, name: &str, data: Arc<ShardData>, bytes: usize) -> Arc<ShardData> {
        let mut g = lock_recover(&self.inner);
        if let Some(slot) = g.slots.get(name) {
            return Arc::clone(&slot.data);
        }
        g.c.shard_faults += 1;
        g.c.bytes_paged_in += bytes;
        evict_until_fits(&mut g, bytes, self.budget);
        if g.c.resident_bytes + bytes > self.budget && g.warned_oversized.insert(name.to_string())
        {
            log::warn!(
                "shard {name:?} ({bytes} B) exceeds the residency budget \
                 ({} B) even with everything evictable evicted; admitting over \
                 budget (warned once; it will re-fault every pass)",
                self.budget
            );
        }
        insert(&mut g, name, data, bytes, false)
    }

    /// Speculatively cache a shard *only if* it fits the spare budget — a
    /// prefetch must never evict demand-fetched shards. Returns whether the
    /// shard was cached (either by this call or already resident).
    pub fn admit_prefetch(&self, name: &str, data: Arc<ShardData>, bytes: usize) -> bool {
        let mut g = lock_recover(&self.inner);
        if g.slots.contains_key(name) {
            return true;
        }
        if g.c.resident_bytes + bytes > self.budget {
            return false;
        }
        g.c.shard_prefetches += 1;
        g.c.bytes_paged_in += bytes;
        insert(&mut g, name, data, bytes, false);
        true
    }

    /// Admit a pinned (never evicted, not budget-governed) shard — the
    /// always-hot set loaded at open.
    pub fn admit_pinned(&self, name: &str, data: Arc<ShardData>, bytes: usize) -> Arc<ShardData> {
        let mut g = lock_recover(&self.inner);
        if let Some(slot) = g.slots.get(name) {
            return Arc::clone(&slot.data);
        }
        g.c.bytes_paged_in += bytes;
        insert(&mut g, name, data, bytes, true)
    }

    /// Whether a prefetch of `bytes` would be cached right now (spare
    /// budget, no eviction). Racy by nature — callers use it to skip the
    /// disk read, `admit_prefetch` re-checks under the lock.
    pub fn fits_without_eviction(&self, bytes: usize) -> bool {
        let g = lock_recover(&self.inner);
        g.c.resident_bytes + bytes <= self.budget
    }

    pub fn is_resident(&self, name: &str) -> bool {
        lock_recover(&self.inner).slots.contains_key(name)
    }

    pub fn is_pinned(&self, name: &str) -> bool {
        lock_recover(&self.inner).slots.get(name).map(|s| s.pinned).unwrap_or(false)
    }

    /// Add `ns` of demand-fault disk-read wall time to
    /// [`ResidencyCounters::fault_ns`]. Called by the paged reader around
    /// the actual disk read (always on — the serving latency breakdown
    /// needs it whether or not tracing is enabled).
    pub fn note_fault_time(&self, ns: u64) {
        lock_recover(&self.inner).c.fault_ns += ns;
    }

    /// Count a detected-corruption read (CRC or decode failure). The read
    /// is retried by the paged model; this counts detections, not losses.
    pub fn note_integrity_failure(&self) {
        lock_recover(&self.inner).c.integrity_failures += 1;
    }

    /// Count one re-read attempt after a failed shard read.
    pub fn note_io_retry(&self) {
        lock_recover(&self.inner).c.io_retries += 1;
    }

    /// Count a shard quarantined after exhausting its retry budget.
    pub fn note_quarantine(&self) {
        lock_recover(&self.inner).c.shards_quarantined += 1;
    }

    /// Counter snapshot (cheap clone under the lock).
    pub fn counters(&self) -> ResidencyCounters {
        lock_recover(&self.inner).c.clone()
    }
}

impl std::fmt::Debug for ResidencyManager {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let c = self.counters();
        f.debug_struct("ResidencyManager")
            .field("budget", &self.budget)
            .field("counters", &c)
            .finish()
    }
}

fn insert(
    g: &mut Inner,
    name: &str,
    data: Arc<ShardData>,
    bytes: usize,
    pinned: bool,
) -> Arc<ShardData> {
    g.clock += 1;
    let slot = Slot { data: Arc::clone(&data), bytes, pinned, last_use: g.clock };
    if pinned {
        g.c.pinned_bytes += bytes;
    } else {
        g.c.resident_bytes += bytes;
        g.c.peak_resident_bytes = g.c.peak_resident_bytes.max(g.c.resident_bytes);
    }
    g.slots.insert(name.to_string(), slot);
    data
}

fn evict_until_fits(g: &mut Inner, incoming: usize, budget: usize) {
    while g.c.resident_bytes + incoming > budget {
        let victim = g
            .slots
            .iter()
            .filter(|(_, s)| !s.pinned)
            .min_by_key(|(_, s)| s.last_use)
            .map(|(n, _)| n.clone());
        let Some(victim) = victim else { break };
        let Some(slot) = g.slots.remove(&victim) else { break };
        g.c.resident_bytes -= slot.bytes;
        g.c.shard_evictions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::proptest::check;

    fn shard(v: f32) -> Arc<ShardData> {
        Arc::new(ShardData::Fp32(Arc::new(Tensor::full(&[1], v))))
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let m = ResidencyManager::new(100);
        m.admit_fault("a", shard(0.0), 40);
        m.admit_fault("b", shard(1.0), 40);
        m.get("a"); // b is now LRU
        m.admit_fault("c", shard(2.0), 40);
        assert!(m.is_resident("a"));
        assert!(!m.is_resident("b"), "b was MRU-evicted instead of LRU");
        assert!(m.is_resident("c"));
        let c = m.counters();
        assert_eq!(c.shard_evictions, 1);
        assert_eq!(c.resident_bytes, 80);
        assert!(c.peak_resident_bytes <= 100);
    }

    #[test]
    fn pinned_never_evicted_and_not_budgeted() {
        let m = ResidencyManager::new(50);
        m.admit_pinned("pin", shard(9.0), 1000);
        for i in 0..20 {
            m.admit_fault(&format!("s{i}"), shard(i as f32), 30);
        }
        assert!(m.is_resident("pin"));
        assert!(m.is_pinned("pin"));
        let c = m.counters();
        assert_eq!(c.pinned_bytes, 1000);
        assert!(c.resident_bytes <= 50, "unpinned {} over budget", c.resident_bytes);
    }

    #[test]
    fn prefetch_never_evicts() {
        let m = ResidencyManager::new(100);
        m.admit_fault("hot", shard(1.0), 90);
        assert!(!m.fits_without_eviction(20));
        assert!(!m.admit_prefetch("spec", shard(2.0), 20));
        assert!(m.is_resident("hot"), "prefetch evicted a demand shard");
        assert!(!m.is_resident("spec"));
        assert!(m.admit_prefetch("small", shard(3.0), 10));
        assert_eq!(m.counters().shard_prefetches, 1);
    }

    #[test]
    fn racing_admits_deduplicate() {
        let m = ResidencyManager::new(100);
        let first = m.admit_fault("x", shard(1.0), 10);
        let second = m.admit_fault("x", shard(2.0), 10);
        assert!(Arc::ptr_eq(&first, &second));
        let c = m.counters();
        assert_eq!(c.shard_faults, 1);
        assert_eq!(c.resident_bytes, 10);
    }

    #[test]
    fn oversized_shard_admitted_over_budget() {
        // refusing would deadlock serving; it must be evicted on next fault
        let m = ResidencyManager::new(10);
        m.admit_fault("huge", shard(1.0), 50);
        assert!(m.is_resident("huge"));
        assert_eq!(m.counters().resident_bytes, 50);
        m.admit_fault("next", shard(2.0), 5);
        assert!(!m.is_resident("huge"));
        assert_eq!(m.counters().resident_bytes, 5);
    }

    // ---- ISSUE-3 satellite: the LRU/residency property test
    #[test]
    fn property_budget_and_pinning_invariants() {
        check("residency invariants", 40, |rng| {
            let n_shards = rng.range(2, 12);
            let sizes: Vec<usize> = (0..n_shards).map(|_| rng.range(1, 64)).collect();
            let max_size = *sizes.iter().max().unwrap();
            // budget at least the largest shard, sometimes comfortably more
            let budget = max_size + rng.below(128);
            let m = ResidencyManager::new(budget);
            let n_pinned = rng.below(3);
            for p in 0..n_pinned {
                m.admit_pinned(&format!("pin{p}"), shard(p as f32), rng.range(1, 64));
            }
            let accesses = rng.range(10, 120);
            for _ in 0..accesses {
                let i = rng.below(n_shards);
                let name = format!("s{i}");
                if m.get(&name).is_none() {
                    m.admit_fault(&name, shard(i as f32), sizes[i]);
                }
                let c = m.counters();
                // never exceed the budget (every shard fits by itself)
                assert!(
                    c.resident_bytes <= budget,
                    "resident {} > budget {budget}",
                    c.resident_bytes
                );
                assert!(c.peak_resident_bytes <= budget);
                // pinned entries survive arbitrary traffic
                for p in 0..n_pinned {
                    assert!(m.is_resident(&format!("pin{p}")), "pin{p} evicted");
                }
            }
        });
    }

    #[test]
    fn property_ample_budget_never_evicts() {
        check("budget >= payload ⇒ zero evictions", 25, |rng| {
            let n_shards = rng.range(2, 10);
            let sizes: Vec<usize> = (0..n_shards).map(|_| rng.range(1, 64)).collect();
            let m = ResidencyManager::new(sizes.iter().sum());
            for _ in 0..rng.range(10, 60) {
                let i = rng.below(n_shards);
                let name = format!("s{i}");
                if m.get(&name).is_none() {
                    m.admit_fault(&name, shard(i as f32), sizes[i]);
                }
            }
            let c = m.counters();
            assert_eq!(c.shard_evictions, 0, "evicted under an ample budget");
            assert!(c.shard_faults <= n_shards, "re-faulted a resident shard");
        });
    }
}
