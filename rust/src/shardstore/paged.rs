//! [`PagedModel`]: lazy shard materialization over a [`ShardReader`] +
//! [`ResidencyManager`] — the "models larger than RAM" serving form.
//!
//! ## Pagable vs pinned
//!
//! A shard is **pagable** when it is a rank-2 quantized weight outside the
//! embedding block — exactly the set [`crate::model::QuantizedBert`]
//! executes through the fused split-dequant matmul. Everything else
//! (embeddings, LayerNorm, position, biases — the FP32 remainder plus the
//! token embedding) is **pinned**: loaded once at open, never evicted, not
//! counted against the byte budget. Pinned shards are both tiny and touched
//! on every request, so paging them would only add faults.
//!
//! ## Fetch path
//!
//! `fetch(name)` returns the resident [`ShardData`] or faults it in (one
//! seek + one read), evicting LRU pagable shards to stay under
//! `residency_budget_bytes`. After a demand fault, the next
//! `prefetch_depth` shards along the **qbert execution order** (attn.q →
//! attn.k → attn.v → attn.out → ffn.in → ffn.out per layer, then pooler,
//! then classifier) are read ahead — but only into spare budget; prefetch
//! never evicts.
//!
//! ## Replicas
//!
//! `PagedModel` is a cheap [`Arc`]-backed clone: N serving replicas share
//! one reader, one residency manager and therefore ~1× resident shard
//! bytes — the paged twin of `ParamStore::share`
//! (`tests/integration_share.rs`).

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::fault::{FaultConfig, FaultStats, FaultyIo, RetryPolicy, ShardIo};
use super::format::{ShardData, ShardKind, ShardReader};
use super::residency::{ResidencyCounters, ResidencyManager};
use crate::util::sync::lock_recover;

/// Knobs for [`PagedModel::open`]. The serving coordinator threads
/// `ServeConfig::residency_budget_bytes` (and the fault-tolerance knobs)
/// into this.
#[derive(Debug, Clone)]
pub struct PagedConfig {
    /// Byte budget for pagable (unpinned) resident shards, in on-disk
    /// record bytes. `usize::MAX` keeps everything resident after first use.
    pub residency_budget_bytes: usize,
    /// How many execution-order successors to read ahead after a demand
    /// fault (0 disables prefetch).
    pub prefetch_depth: usize,
    /// Bounded retry-with-backoff applied around every shard read (demand
    /// fault and prefetch alike). A read that exhausts its attempts
    /// quarantines the shard: subsequent fetches fail fast per-request.
    pub retry: RetryPolicy,
    /// Deterministic fault injection on the shard IO seam — `None` (the
    /// default) installs nothing, so the fault-free path pays nothing.
    pub fault: Option<FaultConfig>,
}

impl Default for PagedConfig {
    fn default() -> Self {
        PagedConfig {
            residency_budget_bytes: usize::MAX,
            prefetch_depth: 1,
            retry: RetryPolicy::default(),
            fault: None,
        }
    }
}

struct PagedInner {
    reader: Arc<ShardReader>,
    /// every runtime shard read goes through this seam — the reader itself,
    /// or a [`FaultyIo`] decorator when fault injection is configured
    io: Arc<dyn ShardIo>,
    retry: RetryPolicy,
    /// injection accounting when a [`FaultyIo`] is installed (chaos tests
    /// reconcile serving metrics against it)
    fault_stats: Option<Arc<FaultStats>>,
    /// shards that exhausted their read retries — fetches fail fast instead
    /// of hammering a bad disk region on every request
    quarantined: Mutex<HashSet<String>>,
    residency: ResidencyManager,
    /// pagable shard names in qbert execution order
    order: Vec<String>,
    /// name → position in `order` (prefetch successor lookup)
    pos: HashMap<String, usize>,
    prefetch_depth: usize,
    /// dequantized forms of *pinned quantized* shards (the token
    /// embedding), materialized once and shared by every replica built via
    /// [`PagedModel::pinned_fp32`] — N replicas hold one FP32 copy.
    dequant_pins: Mutex<HashMap<String, Arc<Tensor>>>,
}

/// Lazily-materialized sharded model. Clone freely — clones share the
/// reader and residency (see module docs).
#[derive(Clone)]
pub struct PagedModel {
    inner: Arc<PagedInner>,
}

impl PagedModel {
    /// Open a `SQSH0001` file: reads the index, pins the always-hot set
    /// (FP32 remainder + embeddings), and leaves every pagable shard on
    /// disk until first use.
    pub fn open(path: &Path, cfg: PagedConfig) -> Result<PagedModel> {
        let reader = Arc::new(ShardReader::open(path)?);
        let (io, fault_stats): (Arc<dyn ShardIo>, Option<Arc<FaultStats>>) = match &cfg.fault {
            Some(fc) if !fc.is_noop() => {
                let faulty = FaultyIo::new(Arc::clone(&reader), fc.clone());
                let stats = faulty.stats();
                (Arc::new(faulty), Some(stats))
            }
            _ => (Arc::clone(&reader) as Arc<dyn ShardIo>, None),
        };
        let residency = ResidencyManager::new(cfg.residency_budget_bytes);

        let mut order: Vec<String> = Vec::new();
        for name in reader.names() {
            // sq-lint: allow(no-panic-in-serving) — `names()` iterates the index itself, so the entry is present by construction; also open-time, not the request path
            let e = reader.entry(name).expect("indexed name");
            // the ONE fused-linear predicate, shared with QuantizedBert::new
            if e.kind == ShardKind::Quant
                && crate::model::qbert::is_fused_linear(name, &e.shape)
            {
                order.push(name.clone());
            } else {
                // pinned: load now, stays hot forever
                let bytes = e.len as usize;
                let data = reader.read(name)?;
                residency.admit_pinned(name, Arc::new(data), bytes);
            }
        }
        order.sort_by_key(|n| execution_rank(n));
        let pos = order.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();

        Ok(PagedModel {
            inner: Arc::new(PagedInner {
                reader,
                io,
                retry: cfg.retry,
                fault_stats,
                quarantined: Mutex::new(HashSet::new()),
                residency,
                order,
                pos,
                prefetch_depth: cfg.prefetch_depth,
                dequant_pins: Mutex::new(HashMap::new()),
            }),
        })
    }

    /// Read + verify + parse one record through the IO seam under the
    /// bounded [`RetryPolicy`]: transient IO errors and integrity failures
    /// (CRC/decode) are re-read with deterministic backoff; exhausting the
    /// attempts quarantines the shard so later fetches fail fast. Every
    /// event lands in the residency counters and the trace stream.
    fn read_verified(&self, name: &str) -> Result<ShardData> {
        let inner = &*self.inner;
        if lock_recover(&inner.quarantined).contains(name) {
            return Err(Error::Checkpoint(format!(
                "shard {name:?} is quarantined after exhausting read retries"
            )));
        }
        let res = inner.retry.run(std::thread::sleep, |attempt| {
            if attempt > 1 {
                inner.residency.note_io_retry();
                crate::trace::instant(
                    crate::trace::Category::Shard,
                    "shard-retry",
                    attempt as u64,
                    0,
                );
            }
            let raw = inner.io.read_raw(name)?;
            inner.reader.decode(name, &raw).inspect_err(|_| {
                inner.residency.note_integrity_failure();
                crate::trace::instant(
                    crate::trace::Category::Shard,
                    "shard-integrity-fail",
                    attempt as u64,
                    0,
                );
            })
        });
        res.inspect_err(|e| {
            lock_recover(&inner.quarantined).insert(name.to_string());
            inner.residency.note_quarantine();
            crate::trace::instant(crate::trace::Category::Shard, "shard-quarantine", 0, 0);
            log::error!(
                "shard {name:?} quarantined after {} read attempt(s): {e}",
                inner.retry.max_attempts.max(1)
            );
        })
    }

    /// Resident handle for `name`, faulting it in if needed. Pinned shards
    /// always hit; pagable shards may evict LRU peers (see
    /// [`ResidencyManager`]). Prefetches execution-order successors into
    /// spare budget after a demand fault.
    pub fn fetch(&self, name: &str) -> Result<Arc<ShardData>> {
        let inner = &*self.inner;
        if let Some(data) = inner.residency.get(name) {
            return Ok(data);
        }
        let traced = crate::trace::enabled();
        let fault_sp = crate::trace::span_args(
            crate::trace::Category::Shard,
            if traced { crate::trace::intern(name) } else { "shard-fault" },
            0,
            0,
        );
        let bytes = self.record_bytes(name)?;
        let t0 = std::time::Instant::now();
        let data = Arc::new(self.read_verified(name)?);
        // always on: the serving latency breakdown attributes fault time
        // whether or not tracing is enabled
        inner.residency.note_fault_time(t0.elapsed().as_nanos() as u64);
        let evictions0 = if traced { inner.residency.counters().shard_evictions } else { 0 };
        let data = inner.residency.admit_fault(name, data, bytes);
        if traced {
            crate::trace::instant(
                crate::trace::Category::Shard,
                "shard-fault",
                bytes as u64,
                0,
            );
            let evicted = inner.residency.counters().shard_evictions - evictions0;
            if evicted > 0 {
                crate::trace::instant(
                    crate::trace::Category::Shard,
                    "shard-evict",
                    evicted as u64,
                    0,
                );
            }
        }
        drop(fault_sp);

        if let Some(&p) = inner.pos.get(name) {
            for next in inner.order.iter().skip(p + 1).take(inner.prefetch_depth) {
                if inner.residency.is_resident(next) {
                    continue;
                }
                let Ok(nb) = self.record_bytes(next) else { break };
                if !inner.residency.fits_without_eviction(nb) {
                    break; // no spare budget: prefetch must not evict
                }
                match self.read_verified(next) {
                    Ok(d) => {
                        if inner.residency.admit_prefetch(next, Arc::new(d), nb) {
                            crate::trace::instant(
                                crate::trace::Category::Shard,
                                "shard-prefetch",
                                nb as u64,
                                0,
                            );
                        }
                    }
                    Err(e) => {
                        // best-effort: the demand fetch already succeeded;
                        // a later demand fault will surface the error
                        log::warn!("prefetch of shard {next:?} failed: {e}");
                        break;
                    }
                }
            }
        }
        Ok(data)
    }

    /// The quantized tensor behind `name`, or an error if the shard holds
    /// FP32 data.
    pub fn fetch_quant(&self, name: &str) -> Result<Arc<ShardData>> {
        let data = self.fetch(name)?;
        match &*data {
            ShardData::Quant(_) => Ok(data),
            ShardData::Fp32(_) => {
                Err(Error::Quant(format!("shard {name:?} is FP32, expected quantized")))
            }
        }
    }

    /// The FP32 working form of a **pinned** shard, shared across replicas:
    /// FP32 shards return the cached allocation directly; pinned quantized
    /// shards (the token embedding) are dequantized once per `PagedModel`
    /// — not once per replica — and every caller gets the same `Arc`.
    pub fn pinned_fp32(&self, name: &str) -> Result<Arc<Tensor>> {
        match &*self.fetch(name)? {
            ShardData::Fp32(t) => Ok(Arc::clone(t)),
            ShardData::Quant(q) => {
                let mut cache = lock_recover(&self.inner.dequant_pins);
                if let Some(t) = cache.get(name) {
                    return Ok(Arc::clone(t));
                }
                let t = Arc::new(q.dequantize());
                cache.insert(name.to_string(), Arc::clone(&t));
                Ok(t)
            }
        }
    }

    /// Shared residency accounting (counters feed serving [`Metrics`]).
    ///
    /// [`Metrics`]: crate::coordinator::Metrics
    pub fn residency(&self) -> &ResidencyManager {
        &self.inner.residency
    }

    /// Injection ground truth when fault injection is configured
    /// ([`PagedConfig::fault`]); `None` on the fault-free path. Chaos tests
    /// reconcile the serving metrics against these counts.
    pub fn fault_stats(&self) -> Option<Arc<FaultStats>> {
        self.inner.fault_stats.as_ref().map(Arc::clone)
    }

    /// Whether `name` has been quarantined (its reads exhausted the retry
    /// budget). Quarantined shards fail every fetch fast.
    pub fn is_quarantined(&self, name: &str) -> bool {
        lock_recover(&self.inner.quarantined).contains(name)
    }

    /// The retry contract applied around shard reads.
    pub fn retry_policy(&self) -> &RetryPolicy {
        &self.inner.retry
    }

    /// Counter snapshot — convenience for executors.
    pub fn counters(&self) -> ResidencyCounters {
        self.inner.residency.counters()
    }

    /// Pagable shard names in execution order.
    pub fn pagable(&self) -> &[String] {
        &self.inner.order
    }

    /// All entry names in file order (pinned + pagable).
    pub fn names(&self) -> &[String] {
        self.inner.reader.names()
    }

    /// Whether `name` pages in and out (false ⇒ pinned or unknown).
    pub fn is_pagable(&self, name: &str) -> bool {
        self.inner.pos.contains_key(name)
    }

    pub fn bits(&self) -> u8 {
        self.inner.reader.bits()
    }

    /// Total on-disk record bytes (pinned + pagable).
    pub fn payload_bytes(&self) -> usize {
        self.inner.reader.payload_bytes()
    }

    /// On-disk bytes of the pagable set — what the budget pages over.
    pub fn pagable_bytes(&self) -> usize {
        self.inner
            .order
            .iter()
            .filter_map(|n| self.inner.reader.entry(n))
            .map(|e| e.len as usize)
            .sum()
    }

    /// Largest single pagable record — the minimum workable budget.
    pub fn max_shard_bytes(&self) -> usize {
        self.inner
            .order
            .iter()
            .filter_map(|n| self.inner.reader.entry(n))
            .map(|e| e.len as usize)
            .max()
            .unwrap_or(0)
    }

    /// The FP32-equivalent bytes of a pagable weight (shape product × 4).
    pub fn fp32_equivalent_bytes(&self) -> usize {
        self.inner
            .order
            .iter()
            .filter_map(|n| self.inner.reader.entry(n))
            .map(|e| e.shape.iter().product::<usize>() * 4)
            .sum()
    }

    /// Whether two handles share one residency manager (replica check —
    /// the paged analogue of `ParamStore::shares_tensor`).
    pub fn shares_residency(&self, other: &PagedModel) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    fn record_bytes(&self, name: &str) -> Result<usize> {
        self.inner
            .reader
            .entry(name)
            .map(|e| e.len as usize)
            .ok_or_else(|| Error::Checkpoint(format!("no shard {name:?}")))
    }
}

impl std::fmt::Debug for PagedModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedModel")
            .field("entries", &self.inner.reader.names().len())
            .field("pagable", &self.inner.order.len())
            .field("residency", &self.inner.residency)
            .finish()
    }
}

/// Sort key placing pagable weights in qbert execution order. Unknown names
/// sort after the known ones, keeping their relative file order (stable
/// sort).
fn execution_rank(name: &str) -> (u8, usize, u8) {
    if let Some(rest) = name.strip_prefix("encoder.") {
        if let Some((idx, sub)) = rest.split_once('.') {
            if let Ok(layer) = idx.parse::<usize>() {
                let sub_rank = match sub {
                    "attn.q.weight" => 0,
                    "attn.k.weight" => 1,
                    "attn.v.weight" => 2,
                    "attn.out.weight" => 3,
                    "ffn.in.weight" => 4,
                    "ffn.out.weight" => 5,
                    _ => 6,
                };
                return (0, layer, sub_rank);
            }
        }
    }
    match name {
        "pooler.weight" => (1, 0, 0),
        "classifier.weight" => (1, 1, 0),
        _ => (2, 0, 0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;
    use crate::model::params::ParamStore;
    use crate::quant::PackedModel;
    use crate::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
    use crate::util::rng::Rng;

    fn shard_file(tag: &str, layers: usize) -> (BertConfig, PackedModel, std::path::PathBuf) {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(7);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, qm) = quantize_store(&store, &q, &SplitQuantConfig::new(2)).unwrap();
        let pm = PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join(format!("sq_paged_{tag}.sqsh"));
        pm.save_sharded(&path).unwrap();
        (cfg, pm, path)
    }

    #[test]
    fn execution_order_is_the_forward_pass_order() {
        let (_, _, path) = shard_file("order", 2);
        let paged = PagedModel::open(&path, PagedConfig::default()).unwrap();
        std::fs::remove_file(&path).ok();
        let expect = [
            "encoder.0.attn.q.weight",
            "encoder.0.attn.k.weight",
            "encoder.0.attn.v.weight",
            "encoder.0.attn.out.weight",
            "encoder.0.ffn.in.weight",
            "encoder.0.ffn.out.weight",
            "encoder.1.attn.q.weight",
            "encoder.1.attn.k.weight",
            "encoder.1.attn.v.weight",
            "encoder.1.attn.out.weight",
            "encoder.1.ffn.in.weight",
            "encoder.1.ffn.out.weight",
            "pooler.weight",
            "classifier.weight",
        ];
        assert_eq!(paged.pagable(), &expect);
    }

    #[test]
    fn pinned_set_is_fp32_plus_embeddings() {
        let (_, pm, path) = shard_file("pins", 1);
        let paged = PagedModel::open(&path, PagedConfig::default()).unwrap();
        std::fs::remove_file(&path).ok();
        for (name, _) in &pm.fp32 {
            assert!(paged.residency().is_pinned(name), "{name} not pinned");
        }
        assert!(paged.residency().is_pinned("embeddings.token"));
        for name in paged.pagable() {
            assert!(!paged.residency().is_pinned(name), "{name} wrongly pinned");
            assert!(!paged.residency().is_resident(name), "{name} resident before use");
        }
    }

    #[test]
    fn fetch_faults_once_then_hits() {
        let (_, pm, path) = shard_file("fetch", 1);
        let paged =
            PagedModel::open(&path, PagedConfig { prefetch_depth: 0, ..Default::default() })
                .unwrap();
        let name = "encoder.0.attn.q.weight";
        let a = paged.fetch(name).unwrap();
        let c1 = paged.counters();
        assert_eq!(c1.shard_faults, 1);
        let b = paged.fetch(name).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let c2 = paged.counters();
        assert_eq!(c2.shard_faults, 1);
        assert!(c2.shard_hits > c1.shard_hits);
        // the fetched tensor matches the original
        match &*a {
            ShardData::Quant(q) => assert_eq!(*q, pm.qmodel.tensors[name]),
            ShardData::Fp32(_) => panic!("wrong kind"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prefetch_pulls_the_next_layer_in() {
        let (_, _, path) = shard_file("prefetch", 1);
        let paged =
            PagedModel::open(&path, PagedConfig { prefetch_depth: 2, ..Default::default() })
                .unwrap();
        paged.fetch("encoder.0.attn.q.weight").unwrap();
        assert!(paged.residency().is_resident("encoder.0.attn.k.weight"));
        assert!(paged.residency().is_resident("encoder.0.attn.v.weight"));
        let c = paged.counters();
        assert_eq!(c.shard_faults, 1);
        assert_eq!(c.shard_prefetches, 2);
        // the prefetched shard now hits without faulting
        paged.fetch("encoder.0.attn.k.weight").unwrap();
        assert_eq!(paged.counters().shard_faults, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tight_budget_pages_in_and_out() {
        let (_, _, path) = shard_file("budget", 2);
        let probe = PagedModel::open(&path, PagedConfig::default()).unwrap();
        let budget = probe.max_shard_bytes() * 2;
        assert!(budget < probe.pagable_bytes(), "model too small for the test");
        drop(probe);
        let paged = PagedModel::open(
            &path,
            PagedConfig { residency_budget_bytes: budget, prefetch_depth: 1, ..Default::default() },
        )
        .unwrap();
        for name in paged.pagable().to_vec() {
            paged.fetch(&name).unwrap();
            let c = paged.counters();
            assert!(
                c.resident_bytes <= budget,
                "{name}: resident {} > budget {budget}",
                c.resident_bytes
            );
        }
        let c = paged.counters();
        assert!(c.shard_evictions > 0, "no evictions under a tight budget");
        assert!(c.peak_resident_bytes <= budget);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replicas_share_residency() {
        let (_, _, path) = shard_file("replica", 1);
        let a = PagedModel::open(&path, PagedConfig::default()).unwrap();
        let b = a.clone();
        assert!(a.shares_residency(&b));
        a.fetch("encoder.0.attn.q.weight").unwrap();
        // the replica sees the shard without faulting
        let before = b.counters().shard_faults;
        b.fetch("encoder.0.attn.q.weight").unwrap();
        assert_eq!(b.counters().shard_faults, before);
        // an independent open does NOT share
        let c = PagedModel::open(&path, PagedConfig::default()).unwrap();
        assert!(!a.shares_residency(&c));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn persistent_corruption_retries_then_quarantines() {
        let (_, _, path) = shard_file("quarantine", 1);
        // corrupt one record's payload on disk, permanently
        let victim = "encoder.0.attn.q.weight";
        let (off, len) = {
            let r = ShardReader::open(&path).unwrap();
            let e = r.entry(victim).unwrap();
            (e.offset as usize, e.len as usize)
        };
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[off + len / 2] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let retry = RetryPolicy {
            max_attempts: 3,
            base: std::time::Duration::ZERO,
            cap: std::time::Duration::ZERO,
        };
        let paged = PagedModel::open(
            &path,
            PagedConfig { prefetch_depth: 0, retry, ..Default::default() },
        )
        .unwrap();
        assert!(paged.fetch(victim).is_err(), "corrupt shard must not decode");
        let c = paged.counters();
        assert_eq!(c.integrity_failures, 3, "every attempt sees the bad CRC");
        assert_eq!(c.io_retries, 2, "attempts 2 and 3 are retries");
        assert_eq!(c.shards_quarantined, 1);
        assert!(paged.is_quarantined(victim));
        // second fetch fails fast without touching the disk again
        let err = paged.fetch(victim).unwrap_err();
        assert!(format!("{err}").contains("quarantined"), "{err}");
        let c2 = paged.counters();
        assert_eq!(c2.integrity_failures, 3);
        assert_eq!(c2.io_retries, 2);
        assert_eq!(c2.shards_quarantined, 1);
        // siblings are unaffected
        paged.fetch("encoder.0.attn.k.weight").unwrap();
        assert!(!paged.is_quarantined("encoder.0.attn.k.weight"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn injected_faults_reconcile_with_serving_counters() {
        let (_, pm, path) = shard_file("chaos", 1);
        let retry = RetryPolicy {
            max_attempts: 10,
            base: std::time::Duration::ZERO,
            cap: std::time::Duration::ZERO,
        };
        let paged = PagedModel::open(
            &path,
            PagedConfig {
                prefetch_depth: 0,
                retry,
                fault: Some(FaultConfig::uniform(1234, 0.2)),
                ..Default::default()
            },
        )
        .unwrap();
        let mut quarantined = 0usize;
        for name in paged.pagable().to_vec() {
            match paged.fetch(&name) {
                // a fetch that survives the injection is byte-exact
                Ok(data) => match &*data {
                    ShardData::Quant(q) => assert_eq!(*q, pm.qmodel.tensors[&name]),
                    ShardData::Fp32(_) => panic!("wrong kind"),
                },
                Err(_) => {
                    quarantined += 1;
                    assert!(paged.is_quarantined(&name));
                }
            }
        }
        let stats = paged.fault_stats().expect("fault injection configured");
        let c = paged.counters();
        assert!(stats.injected_failures() > 0, "0.2 rates injected nothing");
        assert_eq!(
            c.integrity_failures as u64,
            stats.short_reads() + stats.corruptions(),
            "every short read / corruption must be caught by the CRC layer"
        );
        assert_eq!(
            stats.injected_failures(),
            (c.io_retries + c.shards_quarantined) as u64,
            "every injected failure is either retried or exhausts a retry budget"
        );
        assert_eq!(c.shards_quarantined, quarantined);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fault_free_path_installs_no_decorator() {
        let (_, _, path) = shard_file("noopfault", 1);
        let paged = PagedModel::open(&path, PagedConfig::default()).unwrap();
        assert!(paged.fault_stats().is_none());
        // an all-zero FaultConfig is recognized as a no-op too
        let paged2 = PagedModel::open(
            &path,
            PagedConfig { fault: Some(FaultConfig::default()), ..Default::default() },
        )
        .unwrap();
        assert!(paged2.fault_stats().is_none());
        std::fs::remove_file(&path).ok();
    }
}
