//! Shard-paged quantized model store — serve models larger than RAM.
//!
//! PR 2 made N serving replicas share ~1× resident weight bytes (`Arc`
//! copy-on-write `ParamStore`); this subsystem is the other half of that
//! sharding story: the *one* resident copy no longer has to be the whole
//! model. Packed per-layer shards page in from disk on demand under a byte
//! budget, so a [`crate::model::QuantizedBert`] can serve from a working
//! set smaller than the model (the SplitQuant deployment scenario — only
//! packed low-bit codes are ever resident, and only the hot ones).
//!
//! ```text
//!            SQSH0001 file                         RAM
//!  ┌────────────────────────────┐
//!  │ magic ─ bits ─ n_entries   │
//!  │ index: name kind shape     │──open──▶ ShardReader (index in memory)
//!  │        offset len  …       │
//!  ├────────────────────────────┤          ResidencyManager (byte budget)
//!  │ record: embeddings.token   │──open──▶   pinned   (embeddings, LN,
//!  │ record: embeddings.ln.γ/β  │──open──▶   pinned    biases, position)
//!  ├────────────────────────────┤
//!  │ record: …attn.q.weight     │──fault─▶ ┌────────── LRU, ≤ budget ───┐
//!  │ record: …attn.k.weight     │─prefetch▶│ packed codes + cid + params│
//!  │ record: …ffn.out.weight    │ (spare   │  … evicted least-recently- │
//!  │ record: pooler.weight      │  budget  │    used when over budget   │
//!  │ record: classifier.weight  │  only)   └────────────────────────────┘
//!  └────────────────────────────┘                 ▲
//!                               PagedModel::fetch ┘ (QuantizedBert paged
//!                                                    linears, per matmul)
//! ```
//!
//! * [`format`] — the on-disk format, current version `SQSH0002`: the
//!   `SQQM0001` record encoding re-framed behind a per-tensor offset index
//!   (any layer is one seek + one read away), with a header checksum and a
//!   per-record CRC-32 verified on every read. Version-1 (`SQSH0001`) files
//!   still read byte-compatibly.
//! * [`residency`] — [`ResidencyManager`]: byte budget, LRU eviction,
//!   pinning, fault/eviction/paged-bytes counters (now including integrity
//!   failures, retries and quarantines).
//! * [`paged`] — [`PagedModel`]: lazy [`ShardData`] materialization with
//!   sequential prefetch along the qbert execution order; `Arc`-shared
//!   across replicas so N replicas page through one budget.
//! * [`fault`] — fault tolerance: the [`ShardIo`] read seam, the seeded
//!   deterministic [`FaultyIo`] injector, and the bounded [`RetryPolicy`]
//!   the paged model wraps around every read. A shard whose reads exhaust
//!   the retry budget is quarantined — its requests error, the process
//!   never dies.
//!
//! Serving integration: `ServeConfig::residency_budget_bytes` +
//! `QuantExecutor::paged` ([`crate::coordinator`]) put a paged model behind
//! the batcher, with faults/evictions/paged-bytes surfaced in
//! [`crate::coordinator::Metrics`]. See `examples/serve_paged.rs` and
//! `tests/integration_paged.rs` for the end-to-end path (budget ≤ 50 % of
//! the payload, logits byte-identical to fully-resident), and
//! `tests/integration_chaos.rs` for serving under injected faults.

pub mod fault;
pub mod format;
pub mod paged;
pub mod residency;

pub use fault::{FaultConfig, FaultStats, FaultyIo, RetryPolicy, ShardIo};
pub use format::{write_sharded, ShardData, ShardIndexEntry, ShardKind, ShardReader};
pub use paged::{PagedConfig, PagedModel};
pub use residency::{ResidencyCounters, ResidencyManager};
