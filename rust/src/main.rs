//! `splitquant` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train           train BERT-Tiny on a synthetic task via the AOT train step
//!   train-cnn       train the CNN on synthetic images
//!   eval            evaluate a checkpoint (optionally PTQ-quantized)
//!   table1          regenerate the paper's Table 1
//!   serve           load-test the serving coordinator
//!   verify-runtime  cross-check pure-Rust executor vs PJRT executables
//!   lint            sq-lint the source tree (invariant linter)
//!   trace           traced self-contained paged serving run (telemetry demo)
//!   doctor          self-contained quantization numeric-health report
//!   shard-verify    offline shard integrity check (CRC every record)
//!   info            print manifest / artifact inventory
//!
//! (Hand-rolled arg parsing: the offline registry has no clap.)

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use splitquant::coordinator::{PjrtExecutor, ServeConfig, Server};
use splitquant::data::{emotion, images, pad_to_batches, spam, HashTokenizer, TextBatcher};
use splitquant::error::Result;
use splitquant::eval::{accuracy_pjrt, accuracy_rust, calibrate, prepare_store, WeightMethod};
use splitquant::model::{BertModel, CnnModel, ParamStore};
use splitquant::quant::QConfig;
use splitquant::report::{pct, pct_delta, Table};
use splitquant::runtime::Runtime;
use splitquant::splitquant::{ActQuantMode, SplitQuantConfig};
use splitquant::train::{LrSchedule, Trainer};
use splitquant::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

/// Tiny flag parser: `--key value` pairs (bare `--flag` means `true`).
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut m = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(k) = args[i].strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    m.insert(k.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    m.insert(k.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Flags(m)
    }

    fn get(&self, k: &str, default: &str) -> String {
        self.0.get(k).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, k: &str, default: usize) -> usize {
        self.0.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn u64(&self, k: &str, default: u64) -> u64 {
        self.0.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn f32(&self, k: &str, default: f32) -> f32 {
        self.0.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..]);
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "train-cnn" => cmd_train_cnn(&flags),
        "eval" => cmd_eval(&flags),
        "table1" => cmd_table1(&flags),
        "quantize" => cmd_quantize(&flags),
        "autotune" => cmd_autotune(&flags),
        "analyze" => cmd_analyze(&flags),
        "serve" => cmd_serve(&flags),
        "verify-runtime" => cmd_verify(&flags),
        "lint" => cmd_lint(&flags),
        "trace" => cmd_trace(&flags),
        "doctor" => cmd_doctor(&flags),
        "shard-verify" => cmd_shard_verify(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n");
            print_usage();
            std::process::exit(2);
        }
    }
}

fn print_usage() {
    println!(
        "splitquant — SplitQuant reproduction (Rust + JAX + Pallas)\n\n\
         usage: splitquant <command> [--flag value]...\n\n\
         commands:\n\
           train           --task emotion|spam --steps N --lr F --seed S --out ckpt.bin\n\
           train-cnn       --steps N --lr F --seed S --out ckpt.bin\n\
           eval            --task T --ckpt F [--bits B] [--method none|baseline|percentile|entropy|splitquant|ocs]\n\
                           [--act-quant none|tensor|split] [--engine rust|pjrt]\n\
           table1          --ckpt-emotion F --ckpt-spam F [--bits 2,4,8]\n\
           quantize        --ckpt F --bits B [--out F.sqq]  write a packed model\n\
           autotune        --ckpt F [--budget-bytes N] [--bits 2,4,8] [--calib-batches 2]\n\
                           [--out plan.json] [--pack F.sqsh]   mixed-precision bit plan\n\
           analyze         --ckpt F [--bits 2] [--k 3]   per-tensor split analysis\n\
           serve           --ckpt F --requests N [--workers W]\n\
           verify-runtime  [--ckpt F]\n\
           lint            [--root rust/src]   machine-check the bit-exactness /\n\
                           determinism / concurrency contracts (sq-lint)\n\
           trace           [--requests N] [--out trace.json]   traced paged serving\n\
                           run: Prometheus text to stdout, Chrome JSON to --out\n\
           doctor          [--requests N] [--shadow-rate N] [--seed S] [--bits B]\n\
                           self-contained numeric-health report (drift, cluster\n\
                           occupancy, shadow fidelity); see `doctor --help`\n\
           shard-verify    --shards F.sqsh [--demo-out F.sqsh]   offline shard\n\
                           integrity check: CRC-verify and parse every record\n\
           info\n\n\
         common flags: --artifacts DIR (default ./artifacts)"
    );
}

fn artifacts_dir(flags: &Flags) -> PathBuf {
    PathBuf::from(flags.get("artifacts", "artifacts"))
}

fn load_task(
    task: &str,
    seed: u64,
) -> Result<(splitquant::data::TextDataset, splitquant::data::TextDataset)> {
    match task {
        "emotion" => Ok(emotion::load(seed)),
        // the spam protocol evaluates on the full training corpus (paper §5)
        "spam" => {
            let d = spam::load(seed);
            Ok((d.clone(), d))
        }
        other => Err(splitquant::Error::Model(format!("unknown task {other:?}"))),
    }
}

fn cmd_train(flags: &Flags) -> Result<()> {
    let task = flags.get("task", "emotion");
    let steps = flags.usize("steps", 400);
    let seed = flags.u64("seed", 0);
    let lr = flags.f32("lr", 3e-4);
    let out = flags.get("out", &format!("checkpoints/{task}.bin"));
    let rt = Runtime::new(&artifacts_dir(flags))?;
    let cfg = rt.manifest.bert.clone();

    let (train_set, _) = load_task(&task, seed)?;
    println!(
        "[train] task={task} samples={} classes={} steps={steps} lr={lr}",
        train_set.len(),
        train_set.num_classes
    );
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let mut batcher = TextBatcher::new(&train_set, &tok, 32);
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let mut trainer = Trainer::new(&rt, "bert_train_step_b32", store)?;
    let schedule = LrSchedule::WarmupLinear { peak: lr, warmup: steps / 10 + 1, floor: lr * 0.1 };
    let t0 = std::time::Instant::now();
    trainer.train_text(&mut batcher, steps, &schedule, &mut rng, 20, |e| {
        println!(
            "  step {:4}  loss {:.4}  lr {:.2e}  ({:?}/step)",
            e.step, e.loss, e.lr, e.elapsed
        );
    })?;
    println!("[train] done in {:?}; final loss {:.4}", t0.elapsed(), trainer.final_loss(20));
    trainer.store.save(Path::new(&out))?;
    println!("[train] checkpoint -> {out}");
    Ok(())
}

fn cmd_train_cnn(flags: &Flags) -> Result<()> {
    let steps = flags.usize("steps", 300);
    let seed = flags.u64("seed", 0);
    let lr = flags.f32("lr", 1e-2);
    let out = flags.get("out", "checkpoints/cnn.bin");
    let rt = Runtime::new(&artifacts_dir(flags))?;
    let ccfg = rt.manifest.cnn.clone();
    let (train, test) = images::load(seed, 4096, 512);
    let mut rng = Rng::new(seed ^ 0xF00D);
    let store = ParamStore::init_cnn(&ccfg.param_order(), &mut rng);
    let mut trainer = Trainer::new(&rt, "cnn_train_step_b32", store)?;
    let schedule = LrSchedule::WarmupLinear { peak: lr, warmup: 20, floor: lr * 0.1 };
    let mut cursor = 0usize;
    let mut first_loss = None;
    for s in 0..steps {
        let (imgs, labels) = train.batch(cursor, 32);
        cursor = (cursor + 32) % train.len();
        let loss = trainer.step_images(&imgs, &labels, schedule.lr_at(s, steps))?;
        first_loss.get_or_insert(loss);
        if (s + 1) % 50 == 0 {
            println!("  step {:4}  loss {:.4}", s + 1, loss);
        }
    }
    println!(
        "[train-cnn] first loss {:.4} final loss {:.4}",
        first_loss.unwrap_or(f32::NAN),
        trainer.final_loss(20)
    );
    let model = CnnModel::new(ccfg, trainer.store.clone())?;
    let acc = model.accuracy(&test.images, &test.labels);
    println!("[train-cnn] test accuracy {}", pct(acc));
    trainer.store.save(Path::new(&out))?;
    println!("[train-cnn] checkpoint -> {out}");
    Ok(())
}

fn parse_method(flags: &Flags, bits: u8) -> WeightMethod {
    match flags.get("method", "none").as_str() {
        "none" => WeightMethod::None,
        "baseline" => WeightMethod::Baseline(QConfig::baseline(bits)),
        "percentile" => WeightMethod::Baseline(QConfig::percentile(bits, 99.0)),
        "entropy" => WeightMethod::Baseline(QConfig {
            observer: splitquant::quant::Observer::Entropy { bins: 512 },
            ..QConfig::baseline(bits)
        }),
        "splitquant" => WeightMethod::SplitQuant(SplitQuantConfig::new(bits)),
        "ocs" => WeightMethod::Ocs(QConfig::baseline(bits), 0.05),
        other => {
            eprintln!("unknown method {other:?}, using none");
            WeightMethod::None
        }
    }
}

fn cmd_eval(flags: &Flags) -> Result<()> {
    let task = flags.get("task", "emotion");
    let ckpt = flags.get("ckpt", &format!("checkpoints/{task}.bin"));
    let bits = flags.usize("bits", 8) as u8;
    let engine = flags.get("engine", "rust");
    let seed = flags.u64("seed", 0);
    let method = parse_method(flags, bits);

    let rt = Runtime::new(&artifacts_dir(flags))?;
    let cfg = rt.manifest.bert.clone();
    let store = ParamStore::load(Path::new(&ckpt))?;
    store.check_order(&cfg.param_order())?;
    let (_, test_set) = load_task(&task, seed)?;
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, n) = pad_to_batches(&test_set, &tok, 32);

    let (eval_store, bytes) = prepare_store(&store, &method)?;
    let act_mode = flags.get("act-quant", "none");
    let act_params = if act_mode != "none" {
        let cal = calibrate(&cfg, &store, &batches[..batches.len().min(8)])?;
        let mode =
            if act_mode == "split" { ActQuantMode::Split } else { ActQuantMode::PerTensor };
        Some(cal.to_params(bits, mode))
    } else {
        None
    };

    let t0 = std::time::Instant::now();
    let acc = match (engine.as_str(), &act_params) {
        ("pjrt", Some(a)) => {
            splitquant::eval::accuracy_pjrt_actquant(&rt, &eval_store, &batches, n, a)?
        }
        ("pjrt", None) => accuracy_pjrt(&rt, "bert_fwd_b32", &eval_store, &batches, n)?,
        _ => accuracy_rust(&cfg, &eval_store, &batches, n, act_params.as_ref())?,
    };
    println!(
        "[eval] task={task} method=[{}] act={act_mode} engine={engine} n={n}",
        method.label()
    );
    if let Some(b) = bytes {
        println!("[eval] packed weight bytes: {}", splitquant::report::bytes(b));
    }
    println!("[eval] accuracy {} ({:?})", pct(acc), t0.elapsed());
    Ok(())
}

fn cmd_table1(flags: &Flags) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(flags))?;
    let cfg = rt.manifest.bert.clone();
    let bits_list: Vec<u8> =
        flags.get("bits", "2,4,8").split(',').filter_map(|s| s.parse().ok()).collect();
    let seed = flags.u64("seed", 0);

    let mut table = Table::new(
        "Table 1 — BERT-Tiny accuracy, baseline vs SplitQuant",
        &["Dataset", "FP32", "Bits", "Baseline", "SplitQuant", "Diff"],
    );
    for task in ["emotion", "spam"] {
        let ckpt = flags.get(&format!("ckpt-{task}"), &format!("checkpoints/{task}.bin"));
        if !Path::new(&ckpt).exists() {
            eprintln!("[table1] missing checkpoint {ckpt}; run `splitquant train --task {task}`");
            continue;
        }
        let store = ParamStore::load(Path::new(&ckpt))?;
        let (_, test_set) = load_task(task, seed)?;
        let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
        let (batches, n) = pad_to_batches(&test_set, &tok, 32);
        let fp32 = accuracy_rust(&cfg, &store, &batches, n, None)?;
        for &bits in &bits_list {
            let (base_store, _) =
                prepare_store(&store, &WeightMethod::Baseline(QConfig::baseline(bits)))?;
            let base = accuracy_rust(&cfg, &base_store, &batches, n, None)?;
            let (sq_store, _) =
                prepare_store(&store, &WeightMethod::SplitQuant(SplitQuantConfig::new(bits)))?;
            let sq = accuracy_rust(&cfg, &sq_store, &batches, n, None)?;
            table.row(vec![
                task.to_string(),
                pct(fp32),
                format!("INT{bits}"),
                pct(base),
                pct(sq),
                pct_delta(sq - base),
            ]);
        }
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_quantize(flags: &Flags) -> Result<()> {
    let ckpt = flags.get("ckpt", "checkpoints/emotion.bin");
    let bits = flags.usize("bits", 2) as u8;
    let out = flags.get("out", &format!("{ckpt}.int{bits}.sqq"));
    let store = ParamStore::load(Path::new(&ckpt))?;
    let quantizable = splitquant::splitquant::default_quantizable(&store);
    let t0 = std::time::Instant::now();
    let (_, qmodel) = splitquant::splitquant::quantize_store(
        &store,
        &quantizable,
        &SplitQuantConfig::new(bits).with_k(flags.usize("k", 3)),
    )?;
    let pm = splitquant::quant::PackedModel::assemble(&store, &qmodel);
    pm.save(Path::new(&out))?;
    let fp32 = std::fs::metadata(Path::new(&ckpt))?.len();
    let packed = std::fs::metadata(Path::new(&out))?.len();
    println!(
        "[quantize] INT{bits} SplitQuant: {} quantized tensors in {:?}",
        qmodel.tensors.len(),
        t0.elapsed()
    );
    println!(
        "[quantize] {} ({}) -> {} ({}, {:.1}% of FP32)",
        ckpt,
        splitquant::report::bytes(fp32 as usize),
        out,
        splitquant::report::bytes(packed as usize),
        100.0 * packed as f64 / fp32 as f64,
    );
    Ok(())
}

/// Sensitivity sweep → budgeted bit allocation → (optionally) a packed
/// mixed-precision model validated against the budget. Pure-Rust path — no
/// AOT artifacts needed. Default budget: the uniform-INT4 packed size, so
/// the plan answers "what is the best sub-INT4-sized model?".
fn cmd_autotune(flags: &Flags) -> Result<()> {
    let task = flags.get("task", "emotion");
    let ckpt = flags.get("ckpt", &format!("checkpoints/{task}.bin"));
    let seed = flags.u64("seed", 0);
    let out = flags.get("out", &format!("checkpoints/{task}.bitplan.json"));
    // manifest config when artifacts exist (same shapes train/eval use);
    // the stock BERT-Tiny config otherwise — the sweep itself is pure Rust
    let cfg = match Runtime::new(&artifacts_dir(flags)) {
        Ok(rt) => rt.manifest.bert.clone(),
        Err(_) => splitquant::model::config::BertConfig::default(),
    };
    let store = if Path::new(&ckpt).exists() {
        println!("[autotune] checkpoint {ckpt}");
        let s = ParamStore::load(Path::new(&ckpt))?;
        s.check_order(&cfg.param_order())?;
        s
    } else {
        eprintln!("[autotune] no checkpoint at {ckpt}; sweeping a random init (fidelity only)");
        ParamStore::init_bert(&cfg.param_order(), &mut Rng::new(seed ^ 0xA070))
    };
    let (train_set, test_set) = load_task(&task, seed)?;
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    // tokenize only the calibration slice, not the full training corpus
    let ncal = flags.usize("calib-batches", 2).max(1);
    let take = (ncal * 32).min(train_set.len());
    let calib_set = splitquant::data::TextDataset {
        name: train_set.name.clone(),
        texts: train_set.texts[..take].to_vec(),
        labels: train_set.labels[..take].to_vec(),
        num_classes: train_set.num_classes,
        class_names: train_set.class_names.clone(),
    };
    let (calib, _) = pad_to_batches(&calib_set, &tok, 32);
    let mut candidates: Vec<u8> = Vec::new();
    for part in flags.get("bits", "2,4,8").split(',') {
        candidates.push(part.trim().parse().map_err(|_| {
            splitquant::Error::Quant(format!("--bits: invalid width {part:?} (use e.g. 2,4,8)"))
        })?);
    }
    let sweep_cfg = splitquant::autotune::SweepConfig {
        candidates,
        ..splitquant::autotune::SweepConfig::default()
    };

    let t0 = std::time::Instant::now();
    let table = splitquant::autotune::sweep(&cfg, &store, &calib, &sweep_cfg)?;
    println!(
        "[autotune] swept {} layer groups x {} widths over {} calibration examples in {:?}",
        table.layers.len(),
        table.layers.first().map(|l| l.options.len()).unwrap_or(0),
        table.examples,
        t0.elapsed()
    );

    let budget = match flags.usize("budget-bytes", 0) {
        0 => table.uniform_bytes(4).ok_or_else(|| {
            splitquant::Error::Quant(
                "no --budget-bytes given and INT4 not among the sweep candidates".into(),
            )
        })?,
        b => b,
    };
    let plan = splitquant::autotune::allocate(&table, budget)?;

    let widths: Vec<u8> = table
        .layers
        .first()
        .map(|l| l.options.iter().map(|o| o.bits).collect())
        .unwrap_or_default();
    let headers: Vec<String> = std::iter::once("layer".to_string())
        .chain(widths.iter().map(|b| format!("KL@INT{b}")))
        .chain(["plan".to_string(), "plan bytes".to_string()])
        .collect();
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("per-layer sensitivity (mean calibration KL vs FP32)", &hrefs);
    for l in &table.layers {
        let bits = plan.layers[&l.layer];
        let chosen = l.options.iter().find(|o| o.bits == bits).expect("plan bits swept");
        let mut row = vec![l.layer.clone()];
        row.extend(l.options.iter().map(|o| format!("{:.3e}", o.kl)));
        row.push(format!("INT{bits}"));
        row.push(splitquant::report::bytes(chosen.bytes));
        t.row(row);
    }
    println!("{}", t.render());
    println!(
        "[autotune] budget {} -> plan {} ({} planned, predicted KL {:.3e})",
        splitquant::report::bytes(budget),
        plan.summary(),
        splitquant::report::bytes(plan.planned_bytes),
        plan.planned_kl
    );
    plan.save(Path::new(&out))?;
    println!("[autotune] bit plan -> {out}");

    if let Some(pack) = flags.0.get("pack") {
        let artifact = splitquant::quant::QuantPipeline::new()
            .pass(splitquant::autotune::AutoTunePass::new(plan.clone(), sweep_cfg.base))
            .run(&store)?;
        let qm = artifact.quantized_model();
        let pm = splitquant::quant::PackedModel::assemble(&store, &qm);
        pm.save_sharded(Path::new(pack))?;
        let realized = plan.validate_sharded(Path::new(pack))?;
        let (eval_batches, n) = pad_to_batches(&test_set, &tok, 32);
        let agree =
            splitquant::eval::agreement_rust(&cfg, &store, &artifact.eval, &eval_batches, n)?;
        println!(
            "[autotune] packed sharded model -> {pack} ({} quantized payload, \
             validated against the {} budget)",
            splitquant::report::bytes(realized),
            splitquant::report::bytes(budget)
        );
        println!("[autotune] provenance: {:?}", artifact.provenance);
        println!("[autotune] plan fidelity vs FP32 argmax on {n} test examples: {}", pct(agree));
    }
    Ok(())
}

fn cmd_analyze(flags: &Flags) -> Result<()> {
    let ckpt = flags.get("ckpt", "checkpoints/emotion.bin");
    let bits = flags.usize("bits", 2) as u8;
    let k = flags.usize("k", 3);
    let store = ParamStore::load(Path::new(&ckpt))?;
    let quantizable = splitquant::splitquant::default_quantizable(&store);
    let cfg = SplitQuantConfig::new(bits).with_k(k);
    let analyses =
        splitquant::splitquant::analysis::analyze_store(&store, &quantizable, &cfg)?;
    println!("{}", splitquant::splitquant::analysis::render_report(&analyses).render());
    let mean_gain: f64 = analyses.iter().map(|a| a.resolution_gain()).sum::<f64>()
        / analyses.len().max(1) as f64;
    println!(
        "mean resolution gain at INT{bits}, k={k}: {mean_gain:.1}x (paper §4: SplitQuant\n\
         raises the scaling factor S by shrinking each split's α−β)"
    );
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let ckpt = flags.get("ckpt", "checkpoints/emotion.bin");
    let requests = flags.usize("requests", 500);
    let workers = flags.usize("workers", 2);
    let seed = flags.u64("seed", 0);
    let rt = Arc::new(Runtime::new(&artifacts_dir(flags))?);
    let cfg = rt.manifest.bert.clone();
    let store = ParamStore::load(Path::new(&ckpt))?;
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);

    let exec = Arc::new(PjrtExecutor::new(&rt, &store, &[1, 8, 32])?);
    let server = Server::start(
        exec,
        tok,
        ServeConfig {
            max_wait: Duration::from_millis(2),
            workers,
            queue_cap: 4096,
            ..ServeConfig::default()
        },
    );

    let (_, test_set) = load_task("emotion", seed)?;
    println!("[serve] sending {requests} requests...");
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| server.submit(&test_set.texts[i % test_set.len()]))
        .collect::<Result<Vec<_>>>()?;
    let mut ok = 0;
    for rx in rxs {
        if rx.recv_timeout(Duration::from_secs(60)).is_ok_and(|r| r.is_ok()) {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let m = server.shutdown();
    println!("[serve] {ok}/{requests} ok in {wall:?}");
    println!("[serve] {}", m.summary());
    Ok(())
}

fn cmd_verify(flags: &Flags) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(flags))?;
    let cfg = rt.manifest.bert.clone();
    let seed = flags.u64("seed", 7);
    let mut rng = Rng::new(seed);
    let store = match flags.0.get("ckpt") {
        Some(p) => ParamStore::load(Path::new(p))?,
        None => ParamStore::init_bert(&cfg.param_order(), &mut rng),
    };
    let (_, test_set) = emotion::load_small(seed, 10, 64);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (batches, _) = pad_to_batches(&test_set, &tok, 32);

    let model = BertModel::new(cfg.clone(), store.clone())?;
    let exe = rt.load("bert_fwd_b32")?;
    let mut max_gap = 0.0f32;
    for b in &batches {
        let rust_logits = model.forward(&b.ids, &b.mask);
        let mut inputs: Vec<splitquant::runtime::literal::Value> =
            store.flat_tensors().map(|t| t.clone().into()).collect();
        inputs.push(b.ids.clone().into());
        inputs.push(b.mask.clone().into());
        let pjrt_logits = exe.run_f32(&inputs)?;
        max_gap = max_gap.max(rust_logits.max_abs_diff(&pjrt_logits));
    }
    println!("[verify] max |rust - pjrt| over {} batches: {max_gap:.3e}", batches.len());
    if max_gap > 1e-3 {
        return Err(splitquant::Error::Runtime(format!(
            "executor divergence {max_gap} exceeds 1e-3"
        )));
    }
    println!("[verify] OK — executors agree");
    Ok(())
}

/// §Static analysis: run `sq-lint` over the source tree. Prints every
/// unallowed finding and fails (exit 1) when any remain; allowed findings
/// are counted but never fail the run. CI's `sq-lint` lane is exactly this
/// command, and `analysis::tests::repo_source_tree_lints_clean` enforces
/// the same zero-finding state from `cargo test`.
fn cmd_lint(flags: &Flags) -> Result<()> {
    let root = PathBuf::from(flags.get("root", "rust/src"));
    let report = splitquant::analysis::lint_tree(&root)?;
    for f in report.unallowed() {
        println!("{f}");
    }
    let unallowed = report.unallowed().count();
    println!(
        "[lint] {} files, {unallowed} unallowed finding(s), {} allowed",
        report.files,
        report.allowed_count()
    );
    if unallowed > 0 {
        return Err(splitquant::Error::Lint(unallowed));
    }
    println!("[lint] OK — all contracts hold");
    Ok(())
}

/// `splitquant trace`: a self-contained traced serving run — quantize a
/// small random BERT-Tiny, serve it shard-paged under a residency budget
/// with the trace recorder enabled, print the Prometheus-style telemetry
/// exposition, and write a Chrome trace-event JSON file (load it at
/// `ui.perfetto.dev`). Needs no artifacts, checkpoints or network.
fn cmd_trace(flags: &Flags) -> Result<()> {
    use splitquant::coordinator::QuantExecutor;
    use splitquant::model::config::BertConfig;
    use splitquant::quant::PackedModel;
    use splitquant::shardstore::{PagedConfig, PagedModel};
    use splitquant::splitquant::{default_quantizable, quantize_store};

    let requests = flags.usize("requests", 64);
    let out = PathBuf::from(flags.get("out", "trace.json"));
    splitquant::trace::set_enabled(true);

    let cfg = BertConfig {
        vocab_size: 2048,
        hidden: 32,
        layers: 2,
        heads: 2,
        ffn: 64,
        max_len: 32,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = Rng::new(7);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);
    let (_, qm) = quantize_store(&store, &quantizable, &SplitQuantConfig::new(2))?;
    let pm = PackedModel::assemble(&store, &qm);
    let shards = std::env::temp_dir().join("sq_trace_cmd.sqsh");
    pm.save_sharded(&shards)?;
    let pagable = PagedModel::open(&shards, PagedConfig::default())?.pagable_bytes();

    let serve_cfg = ServeConfig {
        max_wait: Duration::from_millis(2),
        workers: 2,
        queue_cap: 1024,
        parallel: splitquant::parallel::ParallelConfig::default(),
        // a budget below the pagable payload so the run exercises the
        // fault / prefetch / eviction events, not just the hit path
        residency_budget_bytes: Some((pagable * 35 / 100).max(1)),
        ..ServeConfig::default()
    };
    let exec = Arc::new(QuantExecutor::paged(cfg.clone(), &shards, vec![1, 8], &serve_cfg)?);
    let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
    let (_, pool) = emotion::load_small(1, 10, 256);
    let server = Server::start(exec, tok, serve_cfg);
    let mut done = 0usize;
    let mut i = 0usize;
    while done < requests {
        let window = 8.min(requests - done);
        let rxs: Vec<_> = (0..window)
            .map(|k| server.submit(&pool.texts[(i + k) % pool.len()]))
            .collect::<Result<Vec<_>>>()?;
        i += window;
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(60))
                .map_err(|_| splitquant::Error::Coordinator("trace run timeout".into()))??;
            done += 1;
        }
    }
    println!("{}", server.telemetry_text());
    let m = server.shutdown();
    println!("[trace] {}", m.summary());
    let snap = splitquant::trace::snapshot();
    splitquant::trace::chrome::write_chrome_trace(&out, &snap)?;
    println!(
        "[trace] wrote {} trace events ({} dropped) to {}",
        snap.total_events(),
        snap.dropped,
        out.display()
    );
    std::fs::remove_file(&shards).ok();
    Ok(())
}

/// `splitquant doctor`: self-contained quantization numeric-health report.
/// Quantizes a small random BERT-Tiny, serves `--requests` seeded forwards
/// through the integer engine with the qhealth recorder armed, routes a
/// deterministic 1-in-`--shadow-rate` subset through the FP32 shadow
/// reference path, and prints the sorted per-site / per-layer report
/// ([`splitquant::qhealth::render`]). Needs no artifacts, checkpoints or
/// network, and is byte-deterministic for a fixed seed: two runs with the
/// same flags print identical bytes (the CI `qhealth-smoke` lane diffs
/// them).
fn cmd_doctor(flags: &Flags) -> Result<()> {
    use splitquant::model::config::BertConfig;
    use splitquant::model::QuantizedBert;
    use splitquant::parallel::KernelKind;
    use splitquant::qhealth::ShadowConfig;
    use splitquant::quant::QParams;
    use splitquant::splitquant::{default_quantizable, quantize_store, ActQuantParams};
    use splitquant::tensor::{IntTensor, Tensor};

    if flags.0.contains_key("help") {
        println!(
            "splitquant doctor — quantization numeric-health report\n\n\
             Runs a seeded, self-contained serving drill (random BERT-Tiny,\n\
             SplitQuant weights, integer engine) with the numeric-health\n\
             recorder armed and prints the per-site drift, per-layer cluster\n\
             occupancy / outlier-hatch, and shadow-fidelity report.\n\n\
             flags:\n\
               --requests N     forwards to run (default 48)\n\
               --shadow-rate N  route 1-in-N requests through the FP32\n\
                                shadow reference path (0 = never, default 8)\n\
               --seed S         RNG + shadow-schedule seed (default 7)\n\
               --bits B         SplitQuant weight width (default 4)\n\n\
             Output is byte-deterministic for fixed flags."
        );
        return Ok(());
    }

    let requests = flags.usize("requests", 48);
    let shadow_rate = flags.u64("shadow-rate", 8);
    let seed = flags.u64("seed", 7);
    let bits = flags.usize("bits", 4) as u8;

    let cfg = BertConfig {
        vocab_size: 2048,
        hidden: 32,
        layers: 2,
        heads: 2,
        ffn: 64,
        max_len: 32,
        num_classes: 6,
        ln_eps: 1e-12,
    };
    let mut rng = Rng::new(seed);
    let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
    let quantizable = default_quantizable(&store);
    let (_, qm) = quantize_store(&store, &quantizable, &SplitQuantConfig::new(bits))?;
    let mut model = QuantizedBert::new(cfg.clone(), &store, &qm)?;
    model.set_kernel(KernelKind::Int8);
    let p = QParams::from_range(-3.0, 3.0, 8);
    model.set_act_params(ActQuantParams {
        per_site: vec![[p, p, p]; cfg.act_sites().len()],
        bits: 8,
    });
    model.set_act_ocs_ratio(3.0);
    let rec = model.enable_qhealth();
    splitquant::qhealth::set_enabled(true);

    let shadow = ShadowConfig { seed, rate: shadow_rate };
    let mut shadowed = 0u64;
    for seq in 0..requests as u64 {
        let ids: Vec<i32> = (0..cfg.max_len).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        let ids = IntTensor::new(&[1, cfg.max_len], ids)?;
        let mask = Tensor::new(&[1, cfg.max_len], vec![1.0; cfg.max_len])?;
        model.forward(&ids, &mask)?;
        if shadow.fires(seq) {
            model.shadow_sample(&ids, &mask)?;
            shadowed += 1;
        }
    }
    let snap = rec.snapshot();
    splitquant::qhealth::set_enabled(false);
    print!("{}", splitquant::qhealth::render(&snap));
    println!(
        "[doctor] requests={requests} shadowed={shadowed} shadow-rate={shadow_rate} \
         seed={seed} bits=INT{bits}"
    );
    Ok(())
}

/// `splitquant shard-verify`: offline shard integrity check — open a
/// `.sqsh` file and fault in **every** record through the CRC-verified
/// read path (the same [`splitquant::shardstore::ShardReader`] the paged
/// server uses). A truncated header, header-checksum mismatch or corrupt
/// record payload surfaces as a clean non-zero exit, never a panic — the
/// contract the CI `chaos-smoke` lane pins by flipping a byte on disk.
///
/// `--demo-out F.sqsh` first writes a small random quantized model as a
/// v2 sharded file (pure Rust, no artifacts needed) and then verifies it —
/// the fixture generator for that same CI lane.
fn cmd_shard_verify(flags: &Flags) -> Result<()> {
    use splitquant::model::config::BertConfig;
    use splitquant::quant::PackedModel;
    use splitquant::shardstore::{ShardData, ShardReader};
    use splitquant::splitquant::{default_quantizable, quantize_store};

    let path = match flags.0.get("demo-out") {
        Some(p) => {
            let cfg = BertConfig {
                vocab_size: 512,
                hidden: 16,
                layers: 2,
                heads: 2,
                ffn: 32,
                max_len: 16,
                num_classes: 6,
                ln_eps: 1e-12,
            };
            let mut rng = Rng::new(7);
            let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
            let quantizable = default_quantizable(&store);
            let (_, qm) = quantize_store(&store, &quantizable, &SplitQuantConfig::new(2))?;
            let pm = PackedModel::assemble(&store, &qm);
            pm.save_sharded(Path::new(p))?;
            println!("[shard-verify] wrote demo shards -> {p}");
            PathBuf::from(p)
        }
        None => PathBuf::from(flags.get("shards", "model.sqsh")),
    };
    let reader = ShardReader::open(&path)?;
    let mut quant = 0usize;
    let mut fp32 = 0usize;
    for name in reader.names() {
        match reader.read(name)? {
            ShardData::Quant(_) => quant += 1,
            ShardData::Fp32(_) => fp32 += 1,
        }
    }
    println!(
        "[shard-verify] {}: {} records ok ({quant} quantized, {fp32} fp32), {} payload",
        path.display(),
        quant + fp32,
        splitquant::report::bytes(reader.payload_bytes())
    );
    Ok(())
}

fn cmd_info(flags: &Flags) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(flags))?;
    println!("platform: {}", rt.platform());
    println!("bert: {:?}", rt.manifest.bert);
    let mut t = Table::new("artifacts", &["executable", "inputs", "outputs", "file"]);
    for (name, spec) in &rt.manifest.executables {
        t.row(vec![
            name.clone(),
            spec.inputs.len().to_string(),
            spec.outputs.len().to_string(),
            spec.file.clone(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
