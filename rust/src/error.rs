//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every subsystem of the crate.
#[derive(Error, Debug)]
pub enum Error {
    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("quantization error: {0}")]
    Quant(String),

    #[error("clustering error: {0}")]
    Clustering(String),

    #[error("model error: {0}")]
    Model(String),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("json error at byte {at}: {msg}")]
    Json { at: usize, msg: String },

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("checkpoint error: {0}")]
    Checkpoint(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
