//! Crate-wide error type.
//!
//! Hand-implemented `Display`/`Error` (the offline sandbox has no
//! `thiserror`; the derive would be the only proc-macro dependency in the
//! crate).

use std::fmt;

/// Unified error for every subsystem of the crate.
#[derive(Debug)]
pub enum Error {
    Shape(String),
    Quant(String),
    Clustering(String),
    Model(String),
    Manifest(String),
    Json { at: usize, msg: String },
    Runtime(String),
    Checkpoint(String),
    Coordinator(String),
    Io(std::io::Error),
    Xla(String),
    /// `sq-lint` found this many unallowed invariant violations.
    Lint(usize),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Quant(m) => write!(f, "quantization error: {m}"),
            Error::Clustering(m) => write!(f, "clustering error: {m}"),
            Error::Model(m) => write!(f, "model error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Json { at, msg } => write!(f, "json error at byte {at}: {msg}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Lint(n) => write!(f, "sq-lint: {n} unallowed finding(s)"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(Error::Shape("2 vs 3".into()).to_string(), "shape mismatch: 2 vs 3");
        assert_eq!(
            Error::Json { at: 7, msg: "bad token".into() }.to_string(),
            "json error at byte 7: bad token"
        );
    }

    #[test]
    fn io_source_is_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
