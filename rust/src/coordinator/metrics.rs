//! Serving metrics: latency distribution, batch-size histogram, throughput.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::stats::LatencyStats;

/// Aggregated serving metrics (guarded by a mutex in the server).
#[derive(Debug, Clone)]
pub struct Metrics {
    pub started: Instant,
    pub completed: usize,
    pub latency: LatencyStats,
    /// dispatched batches per compiled batch size
    pub batches_by_size: BTreeMap<usize, usize>,
    /// total request slots padded (wasted compute)
    pub padded_slots: usize,
    /// total real request slots
    pub real_slots: usize,
    /// executor time only (excludes queueing)
    pub exec_time: Duration,
    /// requests rejected by admission control (queue full)
    pub shed: usize,
    /// batcher wake-ups that did not dispatch (idle-spin detector: the
    /// Condvar batcher should wake only on enqueue or deadline, so this
    /// stays near zero while the queue is empty — regression-tested)
    pub batcher_polls: usize,
    /// shard demand misses served from disk (paged executors only; zero on
    /// fully-resident executors — see [`crate::shardstore`])
    pub shard_faults: usize,
    /// shards evicted to stay under `ServeConfig::residency_budget_bytes`
    pub shard_evictions: usize,
    /// total bytes paged in from the shard file (faults + prefetch + pins)
    pub bytes_paged_in: usize,
    /// code/cid plane decodes on the paged hot path (paged executors only —
    /// each is a full unpack of one shard's low-bit planes)
    pub plane_decodes: usize,
    /// plane decodes skipped because the shard was still resident and its
    /// decoded planes were cached ([`crate::model::QuantizedBert`]'s plane
    /// cache) — the paged-matmul fast path
    pub plane_reuses: usize,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            completed: 0,
            latency: LatencyStats::default(),
            batches_by_size: BTreeMap::new(),
            padded_slots: 0,
            real_slots: 0,
            exec_time: Duration::ZERO,
            shed: 0,
            batcher_polls: 0,
            shard_faults: 0,
            shard_evictions: 0,
            bytes_paged_in: 0,
            plane_decodes: 0,
            plane_reuses: 0,
        }
    }
}

impl Metrics {
    pub fn record_batch(&mut self, real: usize, size: usize, exec: Duration) {
        *self.batches_by_size.entry(size).or_insert(0) += 1;
        self.real_slots += real;
        self.padded_slots += size - real;
        self.exec_time += exec;
    }

    pub fn record_done(&mut self, latency: Duration) {
        self.completed += 1;
        self.latency.record(latency);
    }

    /// Requests per second since start.
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }

    /// Fraction of executed slots that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.real_slots + self.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.padded_slots as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        let paging = if self.shard_faults + self.shard_evictions > 0 {
            format!(
                " faults={} evictions={} paged_in={}B decodes={} reuses={}",
                self.shard_faults,
                self.shard_evictions,
                self.bytes_paged_in,
                self.plane_decodes,
                self.plane_reuses
            )
        } else {
            String::new()
        };
        format!(
            "served={} shed={} qps={:.1} latency[{}] pad={:.1}% polls={} batches={:?}{paging}",
            self.completed,
            self.shed,
            self.throughput(),
            self.latency.summary(),
            self.padding_fraction() * 100.0,
            self.batcher_polls,
            self.batches_by_size,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut m = Metrics::default();
        m.record_batch(5, 8, Duration::from_millis(3));
        m.record_batch(32, 32, Duration::from_millis(10));
        for _ in 0..37 {
            m.record_done(Duration::from_millis(4));
        }
        assert_eq!(m.completed, 37);
        assert_eq!(m.padded_slots, 3);
        assert_eq!(m.real_slots, 37);
        assert!((m.padding_fraction() - 3.0 / 40.0).abs() < 1e-9);
        assert_eq!(m.batches_by_size[&8], 1);
        assert!(m.throughput() > 0.0);
    }
}
