//! Serving metrics: latency distribution, batch-size histogram, throughput.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::report::bench_json::BenchRecord;
use crate::util::json::{obj, Json};
use crate::util::stats::LogHistogram;

/// Aggregated serving metrics (guarded by a mutex in the server).
///
/// All latency distributions are bounded-memory [`LogHistogram`]s: the
/// server can run forever without the metrics growing (the unbounded
/// `Vec<u64>`-backed `LatencyStats` remains available for benches and
/// observers that want exact percentiles over a finite run).
#[derive(Debug, Clone)]
pub struct Metrics {
    pub started: Instant,
    pub completed: usize,
    /// end-to-end request latency (submit → response)
    pub latency: LogHistogram,
    /// time from submit until the batcher formed the request's batch
    pub queue_us: LogHistogram,
    /// time from batch formation until the executor started (pad + handoff)
    pub batch_us: LogHistogram,
    /// executor classify time attributed to the request's batch
    pub exec_us: LogHistogram,
    /// per-request share of shard demand-fault disk time in its batch
    /// (zero on fully-resident executors)
    pub fault_us: LogHistogram,
    /// dispatched batches per compiled batch size
    pub batches_by_size: BTreeMap<usize, usize>,
    /// total request slots padded (wasted compute)
    pub padded_slots: usize,
    /// total real request slots
    pub real_slots: usize,
    /// executor time only (excludes queueing)
    pub exec_time: Duration,
    /// requests rejected by admission control (queue full)
    pub shed: usize,
    /// batcher wake-ups that did not dispatch (idle-spin detector: the
    /// Condvar batcher should wake only on enqueue or deadline, so this
    /// stays near zero while the queue is empty — regression-tested)
    pub batcher_polls: usize,
    /// shard demand misses served from disk (paged executors only; zero on
    /// fully-resident executors — see [`crate::shardstore`])
    pub shard_faults: usize,
    /// shards evicted to stay under `ServeConfig::residency_budget_bytes`
    pub shard_evictions: usize,
    /// total bytes paged in from the shard file (faults + prefetch + pins)
    pub bytes_paged_in: usize,
    /// code/cid plane decodes on the paged hot path (paged executors only —
    /// each is a full unpack of one shard's low-bit planes)
    pub plane_decodes: usize,
    /// plane decodes skipped because the shard was still resident and its
    /// decoded planes were cached ([`crate::model::QuantizedBert`]'s plane
    /// cache) — the paged-matmul fast path
    pub plane_reuses: usize,
    /// executor panics caught at the batch boundary: the batch's requests
    /// errored, the worker re-armed, the process survived (graceful
    /// degradation — see the coordinator's panic-containment contract)
    pub exec_panics: usize,
    /// shard reads whose decoded payload failed CRC / parse verification
    /// (paged executors only — see [`crate::shardstore::fault`])
    pub integrity_failures: usize,
    /// shard read attempts beyond the first (bounded by
    /// `RetryPolicy::max_attempts` per read — see
    /// [`crate::shardstore::RetryPolicy`])
    pub io_retries: usize,
    /// shards quarantined after exhausting their retry budget: requests
    /// needing them error fast instead of re-reading known-bad data
    pub shards_quarantined: usize,
    /// queued requests shed because they outlived `ServeConfig::expire_after`
    /// before a batch formed (each got an error response; distinct from
    /// `shed`, which rejects at ingress when the queue is full)
    pub shed_expired: usize,
    /// numeric-health snapshot folded from the executor
    /// ([`crate::qhealth`]) — `None` when the executor has no recorder
    /// installed (monitoring off)
    pub qhealth: Option<crate::qhealth::QHealthSnapshot>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            completed: 0,
            latency: LogHistogram::default(),
            queue_us: LogHistogram::default(),
            batch_us: LogHistogram::default(),
            exec_us: LogHistogram::default(),
            fault_us: LogHistogram::default(),
            batches_by_size: BTreeMap::new(),
            padded_slots: 0,
            real_slots: 0,
            exec_time: Duration::ZERO,
            shed: 0,
            batcher_polls: 0,
            shard_faults: 0,
            shard_evictions: 0,
            bytes_paged_in: 0,
            plane_decodes: 0,
            plane_reuses: 0,
            exec_panics: 0,
            integrity_failures: 0,
            io_retries: 0,
            shards_quarantined: 0,
            shed_expired: 0,
            qhealth: None,
        }
    }
}

impl Metrics {
    pub fn record_batch(&mut self, real: usize, size: usize, exec: Duration) {
        *self.batches_by_size.entry(size).or_insert(0) += 1;
        self.real_slots += real;
        self.padded_slots += size - real;
        self.exec_time += exec;
    }

    pub fn record_done(&mut self, latency: Duration) {
        self.completed += 1;
        self.latency.record(latency);
    }

    /// Record one completed request with its lifecycle breakdown:
    /// `total` = submit → response, `queue` = submit → batch formed,
    /// `batch` = batch formed → executor start, `exec` = executor time for
    /// the request's batch, `fault` = the request's share of shard
    /// demand-fault disk time in that batch.
    pub fn record_request(
        &mut self,
        total: Duration,
        queue: Duration,
        batch: Duration,
        exec: Duration,
        fault: Duration,
    ) {
        self.record_done(total);
        self.queue_us.record(queue);
        self.batch_us.record(batch);
        self.exec_us.record(exec);
        self.fault_us.record(fault);
    }

    /// The five lifecycle stages as `(name, histogram)` pairs, in fixed
    /// order (shared by [`Metrics::to_json`] and
    /// [`Metrics::breakdown_records`]).
    fn stages(&self) -> [(&'static str, &LogHistogram); 5] {
        [
            ("total", &self.latency),
            ("queue", &self.queue_us),
            ("batch", &self.batch_us),
            ("exec", &self.exec_us),
            ("fault", &self.fault_us),
        ]
    }

    /// Deterministic sorted-key JSON view of the counters and stage
    /// histograms. Wall-clock-dependent figures (`throughput`) are
    /// excluded so repeated calls over unchanged metrics are identical.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("batcher_polls", Json::from(self.batcher_polls)),
            ("bytes_paged_in", Json::from(self.bytes_paged_in)),
            ("completed", Json::from(self.completed)),
            ("exec_panics", Json::from(self.exec_panics)),
            ("exec_time_us", Json::from(self.exec_time.as_micros() as f64)),
            ("integrity_failures", Json::from(self.integrity_failures)),
            ("io_retries", Json::from(self.io_retries)),
            ("padded_slots", Json::from(self.padded_slots)),
            ("plane_decodes", Json::from(self.plane_decodes)),
            ("plane_reuses", Json::from(self.plane_reuses)),
            ("real_slots", Json::from(self.real_slots)),
            ("shard_evictions", Json::from(self.shard_evictions)),
            ("shard_faults", Json::from(self.shard_faults)),
            ("shards_quarantined", Json::from(self.shards_quarantined)),
            ("shed", Json::from(self.shed)),
            ("shed_expired", Json::from(self.shed_expired)),
        ];
        let batches: Vec<(String, Json)> = self
            .batches_by_size
            .iter()
            .map(|(size, n)| (size.to_string(), Json::from(*n)))
            .collect();
        pairs.push((
            "batches_by_size",
            obj(batches.iter().map(|(k, v)| (k.as_str(), v.clone())).collect()),
        ));
        let stages = self.stages();
        let stage_objs: Vec<(&str, Json)> = stages
            .iter()
            .map(|(name, h)| {
                (
                    *name,
                    obj(vec![
                        ("count", Json::from(h.len())),
                        ("mean_us", Json::from(h.mean_us())),
                        ("p50_us", Json::from(h.quantile_us(0.50) as f64)),
                        ("p95_us", Json::from(h.quantile_us(0.95) as f64)),
                        ("p99_us", Json::from(h.quantile_us(0.99) as f64)),
                        ("p999_us", Json::from(h.quantile_us(0.999) as f64)),
                        ("max_us", Json::from(h.quantile_us(1.0) as f64)),
                    ]),
                )
            })
            .collect();
        pairs.push(("stages", obj(stage_objs)));
        if let Some(q) = &self.qhealth {
            // summary view; the full per-layer rows go to BENCH_serving.json
            // and the doctor report ([`crate::qhealth::bench_rows`]/`render`)
            let clipped: u64 = q.sites.iter().map(|s| s.clipped).sum();
            let values: u64 = q.sites.iter().map(|s| s.values).sum();
            let dead: u32 = q.layers.iter().map(|l| l.dead_clusters).sum();
            pairs.push((
                "qhealth",
                obj(vec![
                    ("act_clipped", Json::from(clipped as f64)),
                    ("act_values", Json::from(values as f64)),
                    ("dead_clusters", Json::from(dead as usize)),
                    ("drift_alarm", Json::from(q.drift_alarmed())),
                    ("layers", Json::from(q.layers.len())),
                    ("shadow_kl_max_micro_nats", Json::from(q.shadow.kl_max_micro_nats as f64)),
                    ("shadow_samples", Json::from(q.shadow.samples as f64)),
                    ("shadow_top1_agree", Json::from(q.shadow.top1_agree as f64)),
                    ("sites", Json::from(q.sites.len())),
                ]),
            ));
        }
        obj(pairs)
    }

    /// Per-request latency-breakdown rows for `BENCH_serving.json`
    /// (`bench` = `breakdown-<stage>`, keyed by `(bench, shape, engine)` so
    /// [`crate::report::bench_json::merge_write`] replaces rows in place —
    /// re-running a serving bench never duplicates them). Stages with no
    /// samples are skipped.
    pub fn breakdown_records(&self, shape: &str, engine: &str) -> Vec<BenchRecord> {
        let mut rows = Vec::new();
        for (name, h) in self.stages() {
            if h.is_empty() {
                continue;
            }
            rows.push(BenchRecord {
                bench: format!("breakdown-{name}"),
                shape: shape.to_string(),
                engine: engine.to_string(),
                ns_per_iter: h.mean_us() * 1e3,
                gb_per_s: 0.0,
                extra: vec![
                    ("count".to_string(), h.len() as f64),
                    ("p50_us".to_string(), h.quantile_us(0.50) as f64),
                    ("p95_us".to_string(), h.quantile_us(0.95) as f64),
                    ("p99_us".to_string(), h.quantile_us(0.99) as f64),
                    ("p999_us".to_string(), h.quantile_us(0.999) as f64),
                ],
            });
        }
        rows
    }

    /// Requests per second since start.
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }

    /// Fraction of executed slots that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.real_slots + self.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.padded_slots as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        let paging = if self.shard_faults + self.shard_evictions > 0 {
            format!(
                " faults={} evictions={} paged_in={}B decodes={} reuses={}",
                self.shard_faults,
                self.shard_evictions,
                self.bytes_paged_in,
                self.plane_decodes,
                self.plane_reuses
            )
        } else {
            String::new()
        };
        let degraded = if self.exec_panics
            + self.integrity_failures
            + self.io_retries
            + self.shards_quarantined
            + self.shed_expired
            > 0
        {
            format!(
                " DEGRADED panics={} integrity_failures={} retries={} quarantined={} expired={}",
                self.exec_panics,
                self.integrity_failures,
                self.io_retries,
                self.shards_quarantined,
                self.shed_expired
            )
        } else {
            String::new()
        };
        format!(
            "served={} shed={} qps={:.1} latency[{}] pad={:.1}% polls={} batches={:?}{paging}{degraded}",
            self.completed,
            self.shed,
            self.throughput(),
            self.latency.summary(),
            self.padding_fraction() * 100.0,
            self.batcher_polls,
            self.batches_by_size,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut m = Metrics::default();
        m.record_batch(5, 8, Duration::from_millis(3));
        m.record_batch(32, 32, Duration::from_millis(10));
        for _ in 0..37 {
            m.record_done(Duration::from_millis(4));
        }
        assert_eq!(m.completed, 37);
        assert_eq!(m.padded_slots, 3);
        assert_eq!(m.real_slots, 37);
        assert!((m.padding_fraction() - 3.0 / 40.0).abs() < 1e-9);
        assert_eq!(m.batches_by_size[&8], 1);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn record_request_fills_stage_histograms() {
        let mut m = Metrics::default();
        m.record_request(
            Duration::from_millis(10),
            Duration::from_millis(4),
            Duration::from_millis(1),
            Duration::from_millis(5),
            Duration::from_millis(2),
        );
        assert_eq!(m.completed, 1);
        assert_eq!(m.latency.len(), 1);
        assert_eq!(m.queue_us.len(), 1);
        assert_eq!(m.fault_us.quantile_us(1.0), 2_000);
    }

    #[test]
    fn to_json_is_deterministic_and_sorted() {
        let mut m = Metrics::default();
        m.record_batch(5, 8, Duration::from_millis(3));
        for _ in 0..5 {
            m.record_request(
                Duration::from_millis(7),
                Duration::from_millis(2),
                Duration::from_millis(1),
                Duration::from_millis(3),
                Duration::ZERO,
            );
        }
        let a = m.to_json().to_string();
        let b = m.to_json().to_string();
        assert_eq!(a, b, "repeated serialization is byte-identical");
        // BTreeMap-backed objects serialize with sorted keys
        let batcher = a.find("\"batcher_polls\"").expect("key present");
        let shed = a.find("\"shed\"").expect("key present");
        assert!(batcher < shed, "{a}");
        let parsed = crate::util::json::Json::parse(&a).expect("valid JSON");
        assert_eq!(parsed.get("completed").and_then(Json::as_usize).unwrap_or(0), 5);
        assert!(parsed.get("stages").is_ok(), "{a}");
    }

    #[test]
    fn summary_flags_degradation_only_when_present() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("DEGRADED"), "{}", m.summary());
        m.exec_panics = 1;
        m.shards_quarantined = 2;
        let s = m.summary();
        assert!(s.contains("DEGRADED"), "{s}");
        assert!(s.contains("panics=1"), "{s}");
        assert!(s.contains("quarantined=2"), "{s}");
        // the degradation counters also appear in the JSON view
        let j = m.to_json().to_string();
        assert!(j.contains("\"exec_panics\":1"), "{j}");
        assert!(j.contains("\"shards_quarantined\":2"), "{j}");
        assert!(j.contains("\"shed_expired\":0"), "{j}");
    }

    #[test]
    fn breakdown_records_key_by_stage() {
        let mut m = Metrics::default();
        m.record_request(
            Duration::from_millis(10),
            Duration::from_millis(4),
            Duration::from_millis(1),
            Duration::from_millis(5),
            Duration::ZERO,
        );
        let rows = m.breakdown_records("paged35", "simd");
        let benches: Vec<&str> = rows.iter().map(|r| r.bench.as_str()).collect();
        assert!(benches.contains(&"breakdown-total"), "{benches:?}");
        assert!(benches.contains(&"breakdown-queue"), "{benches:?}");
        assert!(benches.contains(&"breakdown-fault"), "fault stage recorded (zero) {benches:?}");
        for r in &rows {
            assert_eq!(r.shape, "paged35");
            assert_eq!(r.engine, "simd");
            assert!(r.extra.iter().any(|(k, _)| k == "p99_us"));
        }
    }
}
