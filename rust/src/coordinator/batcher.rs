//! Dynamic batching policy (pure logic, unit-testable without threads).

use std::time::Duration;

/// Ceiling on acceptable padding waste for a deadline dispatch: a batch may
/// execute at most 2× the pending work. Above this the policy prefers the
/// largest compiled size that fits *under* the pending count (dispatch a
/// full sub-batch now, leave the remainder queued) — e.g. 9 pending with
/// compiled sizes [1, 8, 32] dispatches (8, 8) instead of padding to 32
/// (3.5× wasted FLOPs, the bug this constant regression-guards).
pub const MAX_PADDING_OVERHEAD: f64 = 2.0;

/// Size/deadline batching policy over a fixed set of compiled batch shapes.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available compiled batch sizes, ascending (e.g. [1, 8, 32]).
    sizes: Vec<usize>,
    /// Max time the oldest queued request may wait before dispatch.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>, max_wait: Duration) -> Self {
        assert!(!sizes.is_empty());
        sizes.sort_unstable();
        sizes.dedup();
        BatchPolicy { sizes, max_wait }
    }

    #[allow(clippy::unwrap_used)]
    pub fn max_batch(&self) -> usize {
        // sq-lint: allow(no-panic-in-serving) — `new` asserts `sizes` non-empty, so `last()` is always `Some`
        *self.sizes.last().unwrap()
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Smallest compiled size that fits `n` requests (or the max size).
    pub fn fit(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        self.max_batch()
    }

    /// Largest compiled size that `n` requests can fill completely.
    pub fn floor_fit(&self, n: usize) -> Option<usize> {
        self.sizes.iter().rev().find(|&&s| s <= n).copied()
    }

    /// Decide whether to dispatch now.
    ///
    /// * A full batch (pending ≥ max size) dispatches immediately.
    /// * Otherwise dispatch only once the oldest request has waited
    ///   `max_wait`: take everything padded to the smallest compiled size
    ///   that fits — unless that wastes more than
    ///   [`MAX_PADDING_OVERHEAD`]× the pending work, in which case take a
    ///   zero-padding sub-batch of the largest compiled size ≤ pending and
    ///   leave the remainder queued for the next tick. Only when pending is
    ///   below the smallest compiled size is an over-threshold pad
    ///   unavoidable (there is no smaller executable to run).
    ///
    /// Returns the number of requests to take and the compiled batch size.
    pub fn decide(&self, pending: usize, oldest_age: Duration) -> Option<(usize, usize)> {
        if pending == 0 {
            return None;
        }
        if pending >= self.max_batch() {
            return Some((self.max_batch(), self.max_batch()));
        }
        if oldest_age >= self.max_wait {
            let size = self.fit(pending);
            if self.padding_overhead(pending, size) <= MAX_PADDING_OVERHEAD {
                return Some((pending, size));
            }
            if let Some(floor) = self.floor_fit(pending) {
                return Some((floor, floor));
            }
            return Some((pending, size)); // pending < smallest compiled size
        }
        None
    }

    /// Padding overhead ratio for a dispatch decision (1.0 = no padding).
    pub fn padding_overhead(&self, take: usize, size: usize) -> f64 {
        size as f64 / take.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![32, 1, 8], Duration::from_millis(2))
    }

    #[test]
    fn sizes_sorted_deduped() {
        let p = policy();
        assert_eq!(p.sizes(), &[1, 8, 32]);
        assert_eq!(p.max_batch(), 32);
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let p = policy();
        assert_eq!(p.decide(32, Duration::ZERO), Some((32, 32)));
        assert_eq!(p.decide(100, Duration::ZERO), Some((32, 32)));
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let p = policy();
        assert_eq!(p.decide(5, Duration::from_millis(1)), None);
        assert_eq!(p.decide(5, Duration::from_millis(2)), Some((5, 8)));
        assert_eq!(p.decide(1, Duration::from_millis(3)), Some((1, 1)));
        // 9 pending must NOT pad to 32 (3.5× overhead): dispatch the full
        // sub-batch of 8 now and leave 1 queued for the next tick
        assert_eq!(p.decide(9, Duration::from_millis(2)), Some((8, 8)));
        // 2 pending: padding to 8 would be 4×; run the b1 executable instead
        assert_eq!(p.decide(2, Duration::from_millis(2)), Some((1, 1)));
    }

    #[test]
    fn floor_fit_picks_largest_below() {
        let p = policy();
        assert_eq!(p.floor_fit(9), Some(8));
        assert_eq!(p.floor_fit(8), Some(8));
        assert_eq!(p.floor_fit(40), Some(32));
        assert_eq!(p.floor_fit(1), Some(1));
        let coarse = BatchPolicy::new(vec![8, 32], Duration::from_millis(2));
        assert_eq!(coarse.floor_fit(5), None);
    }

    #[test]
    fn padding_overhead_bounded_when_pending_fills_smallest_size() {
        // regression for the 9 → 32 blowup: for every pending count at or
        // above the smallest compiled size, a deadline dispatch may never
        // waste more than MAX_PADDING_OVERHEAD× the pending work
        for sizes in [vec![1usize, 8, 32], vec![8, 32], vec![1, 4, 8, 64]] {
            let p = BatchPolicy::new(sizes.clone(), Duration::from_millis(2));
            let smallest = p.sizes()[0];
            for pending in 1..=2 * p.max_batch() {
                let Some((take, size)) = p.decide(pending, Duration::from_millis(2)) else {
                    panic!("deadline reached with {pending} pending must dispatch");
                };
                assert!(take >= 1 && take <= pending, "take {take} of {pending}");
                assert!(p.sizes().contains(&size), "{size} not a compiled size");
                assert!(take <= size, "take {take} exceeds batch {size}");
                if pending >= smallest {
                    let overhead = p.padding_overhead(take, size);
                    assert!(
                        overhead <= MAX_PADDING_OVERHEAD,
                        "sizes {sizes:?}, pending {pending}: ({take}, {size}) \
                         overhead {overhead}"
                    );
                }
            }
        }
    }

    #[test]
    fn below_smallest_size_still_dispatches_at_deadline() {
        // with no b1 executable a lone request must still be served, even
        // though the pad ratio exceeds the bound (there is no alternative)
        let p = BatchPolicy::new(vec![8, 32], Duration::from_millis(2));
        assert_eq!(p.decide(2, Duration::from_millis(2)), Some((2, 8)));
    }

    #[test]
    fn empty_queue_never_dispatches() {
        let p = policy();
        assert_eq!(p.decide(0, Duration::from_secs(10)), None);
    }

    #[test]
    fn fit_picks_smallest() {
        let p = policy();
        assert_eq!(p.fit(1), 1);
        assert_eq!(p.fit(2), 8);
        assert_eq!(p.fit(8), 8);
        assert_eq!(p.fit(9), 32);
        assert_eq!(p.fit(64), 32);
    }

    #[test]
    fn overhead_accounting() {
        let p = policy();
        assert_eq!(p.padding_overhead(8, 8), 1.0);
        assert_eq!(p.padding_overhead(2, 8), 4.0);
    }
}
