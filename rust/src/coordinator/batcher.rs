//! Dynamic batching policy (pure logic, unit-testable without threads).

use std::time::Duration;

/// Size/deadline batching policy over a fixed set of compiled batch shapes.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available compiled batch sizes, ascending (e.g. [1, 8, 32]).
    sizes: Vec<usize>,
    /// Max time the oldest queued request may wait before dispatch.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>, max_wait: Duration) -> Self {
        assert!(!sizes.is_empty());
        sizes.sort_unstable();
        sizes.dedup();
        BatchPolicy { sizes, max_wait }
    }

    pub fn max_batch(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Smallest compiled size that fits `n` requests (or the max size).
    pub fn fit(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        self.max_batch()
    }

    /// Decide whether to dispatch now.
    ///
    /// * A full batch (pending ≥ max size) dispatches immediately.
    /// * Otherwise dispatch only once the oldest request has waited
    ///   `max_wait`, using the smallest compiled size that fits.
    ///
    /// Returns the number of requests to take and the compiled batch size.
    pub fn decide(&self, pending: usize, oldest_age: Duration) -> Option<(usize, usize)> {
        if pending == 0 {
            return None;
        }
        if pending >= self.max_batch() {
            return Some((self.max_batch(), self.max_batch()));
        }
        if oldest_age >= self.max_wait {
            let take = pending;
            return Some((take, self.fit(take)));
        }
        None
    }

    /// Padding overhead ratio for a dispatch decision (1.0 = no padding).
    pub fn padding_overhead(&self, take: usize, size: usize) -> f64 {
        size as f64 / take.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![32, 1, 8], Duration::from_millis(2))
    }

    #[test]
    fn sizes_sorted_deduped() {
        let p = policy();
        assert_eq!(p.sizes(), &[1, 8, 32]);
        assert_eq!(p.max_batch(), 32);
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let p = policy();
        assert_eq!(p.decide(32, Duration::ZERO), Some((32, 32)));
        assert_eq!(p.decide(100, Duration::ZERO), Some((32, 32)));
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let p = policy();
        assert_eq!(p.decide(5, Duration::from_millis(1)), None);
        assert_eq!(p.decide(5, Duration::from_millis(2)), Some((5, 8)));
        assert_eq!(p.decide(1, Duration::from_millis(3)), Some((1, 1)));
        assert_eq!(p.decide(9, Duration::from_millis(2)), Some((9, 32)));
    }

    #[test]
    fn empty_queue_never_dispatches() {
        let p = policy();
        assert_eq!(p.decide(0, Duration::from_secs(10)), None);
    }

    #[test]
    fn fit_picks_smallest() {
        let p = policy();
        assert_eq!(p.fit(1), 1);
        assert_eq!(p.fit(2), 8);
        assert_eq!(p.fit(8), 8);
        assert_eq!(p.fit(9), 32);
        assert_eq!(p.fit(64), 32);
    }

    #[test]
    fn overhead_accounting() {
        let p = policy();
        assert_eq!(p.padding_overhead(8, 8), 1.0);
        assert_eq!(p.padding_overhead(2, 8), 4.0);
    }
}
