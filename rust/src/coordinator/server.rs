//! The serving server: bounded ingress queue, batcher thread, worker pool.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::data::tokenizer::HashTokenizer;
use crate::error::{Error, Result};
use crate::model::bert::{argmax_rows, BertModel};
use crate::model::config::BertConfig;
use crate::model::params::ParamStore;
use crate::runtime::literal::Value;
use crate::runtime::Runtime;
use crate::tensor::{IntTensor, Tensor};

use super::batcher::BatchPolicy;
use super::metrics::Metrics;

/// Abstract batched classifier — PJRT in production, pure-Rust in tests.
pub trait BatchExecutor: Send + Sync {
    /// Classify a padded batch; returns one label per row.
    fn classify(&self, ids: &IntTensor, mask: &Tensor, batch_size: usize) -> Result<Vec<i32>>;
    /// Compiled batch sizes this executor supports.
    fn batch_sizes(&self) -> Vec<usize>;
}

/// PJRT-backed executor over `bert_fwd_b{N}` executables with pre-staged
/// parameter values (parameters are converted once, not per request).
pub struct PjrtExecutor {
    exes: Vec<(usize, Arc<crate::runtime::LoadedExe>)>,
    params: Vec<Value>,
}

impl PjrtExecutor {
    pub fn new(rt: &Runtime, store: &ParamStore, batch_sizes: &[usize]) -> Result<Self> {
        let mut exes = Vec::new();
        for &b in batch_sizes {
            exes.push((b, rt.load(&format!("bert_fwd_b{b}"))?));
        }
        let params: Vec<Value> =
            store.flat().iter().map(|t| Value::F32(t.clone())).collect();
        Ok(PjrtExecutor { exes, params })
    }
}

impl BatchExecutor for PjrtExecutor {
    fn classify(&self, ids: &IntTensor, mask: &Tensor, batch_size: usize) -> Result<Vec<i32>> {
        let exe = self
            .exes
            .iter()
            .find(|(b, _)| *b == batch_size)
            .map(|(_, e)| e.clone())
            .ok_or_else(|| {
                Error::Coordinator(format!("no executable for batch size {batch_size}"))
            })?;
        let mut inputs = self.params.clone();
        inputs.push(Value::I32(ids.clone()));
        inputs.push(Value::F32(mask.clone()));
        let logits = exe.run_f32(&inputs)?;
        Ok(argmax_rows(&logits))
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.exes.iter().map(|(b, _)| *b).collect()
    }
}

/// Pure-Rust executor (tests / artifact-free operation).
pub struct RustExecutor {
    model: BertModel,
    sizes: Vec<usize>,
}

impl RustExecutor {
    pub fn new(cfg: BertConfig, store: ParamStore, sizes: Vec<usize>) -> Result<Self> {
        Ok(RustExecutor { model: BertModel::new(cfg, store)?, sizes })
    }
}

impl BatchExecutor for RustExecutor {
    fn classify(&self, ids: &IntTensor, mask: &Tensor, _batch: usize) -> Result<Vec<i32>> {
        Ok(self.model.predict(ids, mask))
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub max_wait: Duration,
    pub workers: usize,
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_wait: Duration::from_millis(2), workers: 2, queue_cap: 1024 }
    }
}

/// Completed classification.
#[derive(Debug, Clone)]
pub struct ClassifyResponse {
    pub label: i32,
    pub batch_size: usize,
    pub latency: Duration,
}

struct Pending {
    ids: Vec<i32>,
    mask: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<ClassifyResponse>,
}

struct WorkBatch {
    requests: Vec<Pending>,
    size: usize,
}

enum Ingress {
    Req(Box<Pending>),
    Shutdown,
}

/// A running server: ingress queue + batcher + workers.
pub struct Server {
    tx: mpsc::SyncSender<Ingress>,
    tokenizer: HashTokenizer,
    metrics: Arc<Mutex<Metrics>>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the pipeline.
    pub fn start(
        executor: Arc<dyn BatchExecutor>,
        tokenizer: HashTokenizer,
        cfg: ServeConfig,
    ) -> Server {
        let policy = BatchPolicy::new(executor.batch_sizes(), cfg.max_wait);
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let (tx, rx) = mpsc::sync_channel::<Ingress>(cfg.queue_cap);
        // bounded work queue: when all workers are busy the batcher blocks
        // here, its staged queue fills, then the ingress channel fills, and
        // `try_submit` starts shedding — backpressure end to end
        let (work_tx, work_rx) = mpsc::sync_channel::<WorkBatch>(cfg.workers.max(1));
        let work_rx = Arc::new(Mutex::new(work_rx));
        let max_len = tokenizer.max_len;

        // ---- batcher thread
        let batcher = {
            let metrics = metrics.clone();
            std::thread::Builder::new()
                .name("sq-batcher".into())
                .spawn(move || {
                    let mut queue: Vec<Pending> = Vec::new();
                    let mut open = true;
                    // backpressure: stop draining the ingress channel once
                    // enough work is staged — under overload the bounded
                    // channel then fills and `try_submit` sheds instead of
                    // queueing unboundedly (keeps tail latency finite)
                    let stage_cap = 4 * policy.max_batch();
                    while open || !queue.is_empty() {
                        // drain what we can without blocking
                        while queue.len() < stage_cap {
                            match rx.try_recv() {
                                Ok(Ingress::Req(p)) => queue.push(*p),
                                Ok(Ingress::Shutdown) => open = false,
                                Err(mpsc::TryRecvError::Empty) => break,
                                Err(mpsc::TryRecvError::Disconnected) => {
                                    open = false;
                                    break;
                                }
                            }
                        }
                        let oldest = queue
                            .first()
                            .map(|p| p.submitted.elapsed())
                            .unwrap_or(Duration::ZERO);
                        let force_flush = !open && !queue.is_empty();
                        let decision = if force_flush {
                            Some((queue.len().min(policy.max_batch()), {
                                let take = queue.len().min(policy.max_batch());
                                policy.fit(take)
                            }))
                        } else {
                            policy.decide(queue.len(), oldest)
                        };
                        match decision {
                            Some((take, size)) => {
                                let requests: Vec<Pending> = queue.drain(..take).collect();
                                let _ = metrics; // metrics recorded by workers
                                if work_tx.send(WorkBatch { requests, size }).is_err() {
                                    break;
                                }
                            }
                            None => {
                                if open {
                                    // nap briefly; granularity ≪ max_wait
                                    std::thread::park_timeout(Duration::from_micros(200));
                                }
                            }
                        }
                    }
                })
                .expect("spawn batcher")
        };

        // ---- worker pool
        let mut workers = Vec::new();
        for wi in 0..cfg.workers.max(1) {
            let work_rx = work_rx.clone();
            let executor = executor.clone();
            let metrics = metrics.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sq-worker-{wi}"))
                    .spawn(move || loop {
                        let batch = {
                            let guard = work_rx.lock().unwrap();
                            guard.recv()
                        };
                        let Ok(WorkBatch { requests, size }) = batch else { break };
                        let real = requests.len();
                        // pad to the compiled shape with zero-mask rows
                        let mut ids = vec![0i32; size * max_len];
                        let mut mask = vec![0.0f32; size * max_len];
                        for (i, p) in requests.iter().enumerate() {
                            ids[i * max_len..(i + 1) * max_len].copy_from_slice(&p.ids);
                            mask[i * max_len..(i + 1) * max_len].copy_from_slice(&p.mask);
                        }
                        let ids = IntTensor::new(&[size, max_len], ids).unwrap();
                        let mask = Tensor::new(&[size, max_len], mask).unwrap();
                        let t0 = Instant::now();
                        let labels = match executor.classify(&ids, &mask, size) {
                            Ok(l) => l,
                            Err(e) => {
                                log::error!("worker: classify failed: {e}");
                                continue;
                            }
                        };
                        let exec = t0.elapsed();
                        {
                            let mut m = metrics.lock().unwrap();
                            m.record_batch(real, size, exec);
                            for p in &requests {
                                m.record_done(p.submitted.elapsed());
                            }
                        }
                        for (i, p) in requests.into_iter().enumerate() {
                            let _ = p.resp.send(ClassifyResponse {
                                label: labels[i],
                                batch_size: size,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        Server { tx, tokenizer, metrics, batcher: Some(batcher), workers }
    }

    /// Non-blocking submit with admission control: rejects immediately when
    /// the ingress queue is at capacity (load shedding; the shed count is
    /// visible in [`Metrics`]). Use under open-loop load (trace replay).
    pub fn try_submit(&self, text: &str) -> Result<mpsc::Receiver<ClassifyResponse>> {
        let (ids, mask) = self.tokenizer.encode(text);
        let (rtx, rrx) = mpsc::channel();
        let req = Ingress::Req(Box::new(Pending {
            ids,
            mask,
            submitted: Instant::now(),
            resp: rtx,
        }));
        match self.tx.try_send(req) {
            Ok(()) => Ok(rrx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.lock().unwrap().shed += 1;
                Err(Error::Coordinator("overloaded: ingress queue full".into()))
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(Error::Coordinator("server is shut down".into()))
            }
        }
    }

    /// Submit a text; returns a receiver for the response.
    pub fn submit(&self, text: &str) -> Result<mpsc::Receiver<ClassifyResponse>> {
        let (ids, mask) = self.tokenizer.encode(text);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Ingress::Req(Box::new(Pending {
                ids,
                mask,
                submitted: Instant::now(),
                resp: rtx,
            })))
            .map_err(|_| Error::Coordinator("server is shut down".into()))?;
        Ok(rrx)
    }

    /// Blocking classify convenience.
    pub fn classify(&self, text: &str) -> Result<ClassifyResponse> {
        self.submit(text)?
            .recv()
            .map_err(|_| Error::Coordinator("response channel closed".into()))
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.tx.send(Ingress::Shutdown);
        if let Some(b) = self.batcher.take() {
            b.thread().unpark();
            let _ = b.join();
        }
        // dropping the work sender (inside batcher) ends workers
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        Arc::try_unwrap(std::mem::take(&mut self.metrics))
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|arc| arc.lock().unwrap().clone())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Ingress::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rust_executor() -> (Arc<dyn BatchExecutor>, HashTokenizer) {
        let cfg = BertConfig {
            vocab_size: 512,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 16,
            num_classes: 6,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
        let ex = RustExecutor::new(cfg, store, vec![1, 4, 8]).unwrap();
        (Arc::new(ex), tok)
    }

    #[test]
    fn serve_roundtrip() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            ServeConfig { max_wait: Duration::from_millis(1), workers: 2, queue_cap: 64 },
        );
        let r = server.classify("hello there friend").unwrap();
        assert!((0..6).contains(&r.label));
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn serve_many_batches() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            ServeConfig { max_wait: Duration::from_millis(1), workers: 2, queue_cap: 256 },
        );
        let rxs: Vec<_> =
            (0..50).map(|i| server.submit(&format!("message number {i}")).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert!((0..6).contains(&r.label));
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 50);
        assert!(m.real_slots >= 50);
        // under burst load, some batching must have happened
        let batched: usize = m
            .batches_by_size
            .iter()
            .filter(|(&s, _)| s > 1)
            .map(|(_, &c)| c)
            .sum();
        assert!(batched > 0, "expected batched dispatches: {:?}", m.batches_by_size);
    }

    #[test]
    fn padding_is_inert() {
        // a request classified alone == classified inside a padded batch
        let (ex, tok) = rust_executor();
        let (ids, mask) = tok.encode("the exact same text");
        let one = {
            let ids = IntTensor::new(&[1, 16], ids.clone()).unwrap();
            let mask = Tensor::new(&[1, 16], mask.clone()).unwrap();
            ex.classify(&ids, &mask, 1).unwrap()[0]
        };
        let padded = {
            let mut idp = ids.clone();
            let mut mp = mask.clone();
            idp.extend(vec![0i32; 3 * 16]);
            mp.extend(vec![0.0f32; 3 * 16]);
            let ids = IntTensor::new(&[4, 16], idp).unwrap();
            let mask = Tensor::new(&[4, 16], mp).unwrap();
            ex.classify(&ids, &mask, 4).unwrap()[0]
        };
        assert_eq!(one, padded);
    }

    #[test]
    fn admission_control_sheds_on_overload() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            // tiny queue + long deadline: the queue must fill
            ServeConfig { max_wait: Duration::from_secs(60), workers: 1, queue_cap: 4 },
        );
        let mut accepted = 0usize;
        let mut shed = 0usize;
        let mut rxs = Vec::new();
        // flood faster than the batcher's 200µs drain cadence until the
        // 4-slot queue rejects (bounded to keep the test finite)
        for i in 0..10_000 {
            match server.try_submit(&format!("req {i}")) {
                Ok(rx) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(_) => shed += 1,
            }
            if shed > 0 && accepted >= 4 {
                break;
            }
        }
        assert!(shed > 0, "expected shedding with queue_cap=4");
        assert!(accepted >= 4);
        let m = server.shutdown();
        assert_eq!(m.shed, shed);
        assert_eq!(m.completed, accepted);
    }

    #[test]
    fn shutdown_flushes_queue() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            // very long deadline: only the shutdown flush can dispatch these
            ServeConfig { max_wait: Duration::from_secs(60), workers: 1, queue_cap: 64 },
        );
        let rxs: Vec<_> = (0..3).map(|_| server.submit("drain me").unwrap()).collect();
        std::thread::sleep(Duration::from_millis(10));
        let m = server.shutdown();
        assert_eq!(m.completed, 3);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }
}
