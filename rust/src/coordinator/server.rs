//! The serving server: bounded ingress queue, batcher thread, worker pool.
//!
//! The ingress queue is a `Mutex<VecDeque>` + two `Condvar`s rather than a
//! channel: the batcher needs to *inspect* the queue (pending count, oldest
//! age) without consuming it, and it must sleep until either new work
//! arrives (`not_empty`, signalled on enqueue — wake is immediate) or the
//! oldest request's `max_wait` deadline passes (`wait_timeout`). The
//! previous design drained a channel into a staged Vec and napped on
//! `park_timeout(200µs)`, burning a core while idle; the Condvar batcher's
//! idle wake-ups are counted in [`Metrics::batcher_polls`] and
//! regression-tested to stay near zero.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::data::tokenizer::HashTokenizer;
use crate::error::{Error, Result};
use crate::model::bert::{argmax_rows, BertModel};
use crate::model::config::BertConfig;
use crate::model::params::ParamStore;
use crate::model::QuantizedBert;
use crate::runtime::literal::{f32_literal, i32_literal};
use crate::runtime::Runtime;
use crate::shardstore::{PagedConfig, PagedModel, ResidencyCounters};
use crate::splitquant::QuantizedModel;
use crate::tensor::{IntTensor, Tensor};
use crate::util::sync::{into_inner_recover, lock_recover, wait_recover, wait_timeout_recover};

use super::batcher::BatchPolicy;
use super::metrics::Metrics;

/// Abstract batched classifier — PJRT in production, pure-Rust in tests.
pub trait BatchExecutor: Send + Sync {
    /// Classify a padded batch; returns one label per row.
    fn classify(&self, ids: &IntTensor, mask: &Tensor, batch_size: usize) -> Result<Vec<i32>>;
    /// Compiled batch sizes this executor supports.
    fn batch_sizes(&self) -> Vec<usize>;
    /// Shard-paging counters, when this executor pages weights in and out
    /// under a residency budget ([`crate::shardstore`]). Fully-resident
    /// executors return `None`; the server folds `Some` counters into
    /// [`Metrics`] on read.
    fn residency(&self) -> Option<ResidencyCounters> {
        None
    }

    /// `(plane_decodes, plane_reuses)` of the paged plane cache
    /// ([`crate::model::QuantizedBert::plane_stats`]), `None` when this
    /// executor never decodes planes at matmul time. Folded into
    /// [`Metrics`] on read alongside the residency counters.
    fn plane_stats(&self) -> Option<(usize, usize)> {
        None
    }

    /// Numeric-health shadow probe ([`crate::qhealth`]): re-run one served
    /// row through the executor's reference path and record fidelity. The
    /// server calls this *after* the batch's responses are sent — never on
    /// the hot path. Default: no-op (executors without a health story).
    fn shadow_sample(&self, _ids: &IntTensor, _mask: &Tensor) {}

    /// Numeric-health snapshot, when this executor records one. Folded
    /// into [`Metrics::qhealth`] on metrics reads. Default: `None`.
    fn qhealth(&self) -> Option<crate::qhealth::QHealthSnapshot> {
        None
    }
}

/// One compiled forward executable plus its staged parameter literals.
struct StagedExe {
    batch: usize,
    exe: Arc<crate::runtime::LoadedExe>,
    /// Parameter literals in manifest order, converted **once** at
    /// construction and shared across every batch-size executable (their
    /// param slots are batch-independent — validated in `new`); every
    /// request borrows them (never cloned, never re-converted — see
    /// `assemble_literal_refs`).
    params: Arc<Vec<xla::Literal>>,
}

/// PJRT-backed executor over `bert_fwd_b{N}` executables. Parameter
/// literals are staged once per executable and shared by reference across
/// all requests and serving workers; `classify` converts only the
/// per-request `ids`/`mask` (ROADMAP "pool-aware PJRT executor" — the
/// previous version deep-cloned every staged parameter `Value` per call).
pub struct PjrtExecutor {
    exes: Vec<StagedExe>,
}

/// Per-request input assembly: borrow the staged parameter literals and
/// append the request literals. Split out so the zero-re-materialization
/// property is unit-testable without a PJRT backend.
fn assemble_literal_refs<'a>(
    staged: &'a [xla::Literal],
    request: &'a [xla::Literal],
) -> Vec<&'a xla::Literal> {
    staged.iter().chain(request.iter()).collect()
}

impl PjrtExecutor {
    pub fn new(rt: &Runtime, store: &ParamStore, batch_sizes: &[usize]) -> Result<Self> {
        let nparams = store.len();
        let mut loaded = Vec::new();
        for &b in batch_sizes {
            let exe = rt.load(&format!("bert_fwd_b{b}"))?;
            if exe.spec.inputs.len() != nparams + 2 {
                return Err(Error::Coordinator(format!(
                    "bert_fwd_b{b}: {} inputs do not match {} params + ids + mask",
                    exe.spec.inputs.len(),
                    nparams
                )));
            }
            loaded.push((b, exe));
        }
        let Some((b0, first)) = loaded.first() else {
            return Ok(PjrtExecutor { exes: Vec::new() });
        };
        // only the trailing ids/mask slots depend on the batch size, so ONE
        // staged literal set serves every executable (no per-size weight
        // copies) — but verify that against the manifest instead of assuming
        for (b, exe) in &loaded[1..] {
            for (i, (a, c)) in first.spec.inputs[..nparams]
                .iter()
                .zip(&exe.spec.inputs[..nparams])
                .enumerate()
            {
                if a.shape != c.shape || a.dtype != c.dtype {
                    return Err(Error::Coordinator(format!(
                        "bert_fwd_b{b}: param slot {i} spec {:?}/{:?} differs \
                         from bert_fwd_b{b0}'s {:?}/{:?}",
                        c.shape, c.dtype, a.shape, a.dtype
                    )));
                }
            }
        }
        let params = Arc::new(
            store
                .flat_tensors()
                .zip(&first.spec.inputs[..nparams])
                .map(|(t, spec)| f32_literal(t, spec))
                .collect::<Result<Vec<_>>>()?,
        );
        let exes = loaded
            .into_iter()
            .map(|(batch, exe)| StagedExe { batch, exe, params: Arc::clone(&params) })
            .collect();
        Ok(PjrtExecutor { exes })
    }
}

// `PjrtExecutor` relies on auto-derived `Send`/`Sync`: the staged literals
// are immutable host-side buffers read concurrently by the serving workers.
// If a real `xla` crate with `!Send` literal handles is swapped in, the
// resulting compile error at `Arc<dyn BatchExecutor>` is the prompt to
// decide (and document) thread safety explicitly, as `LoadedExe` does —
// do not pre-suppress it with a blanket `unsafe impl`.

impl BatchExecutor for PjrtExecutor {
    fn classify(&self, ids: &IntTensor, mask: &Tensor, batch_size: usize) -> Result<Vec<i32>> {
        let staged = self
            .exes
            .iter()
            .find(|s| s.batch == batch_size)
            .ok_or_else(|| {
                Error::Coordinator(format!("no executable for batch size {batch_size}"))
            })?;
        let n = staged.params.len();
        let (ids_spec, mask_spec) =
            match (staged.exe.spec.inputs.get(n), staged.exe.spec.inputs.get(n + 1)) {
                (Some(a), Some(b)) => (a, b),
                _ => {
                    return Err(Error::Coordinator(format!(
                        "bert_fwd_b{batch_size}: manifest lost its ids/mask input slots"
                    )))
                }
            };
        let request = [i32_literal(ids, ids_spec)?, f32_literal(mask, mask_spec)?];
        let inputs = assemble_literal_refs(&staged.params, &request);
        let logits = staged.exe.run_f32_refs(&inputs)?;
        Ok(argmax_rows(&logits))
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.exes.iter().map(|s| s.batch).collect()
    }
}

/// Pure-Rust executor (tests / artifact-free operation). Forward passes run
/// on the process-wide [`crate::parallel`] worker pool: multiple serving
/// workers calling `classify` concurrently share one set of kernel threads
/// instead of each spawning their own (no oversubscription).
///
/// Replicas are cheap: pass [`ParamStore::share`] views and N executors
/// hold one copy of the weights (copy-on-write `ParamStore`).
pub struct RustExecutor {
    model: BertModel,
    sizes: Vec<usize>,
}

impl RustExecutor {
    /// `store` is typically a [`ParamStore::share`] view — constructing a
    /// replica copies no tensor data.
    pub fn new(cfg: BertConfig, store: ParamStore, sizes: Vec<usize>) -> Result<Self> {
        Ok(RustExecutor { model: BertModel::new(cfg, store)?, sizes })
    }

    /// The executor's parameter view (sharing checks / introspection).
    pub fn params(&self) -> &ParamStore {
        &self.model.params
    }
}

impl BatchExecutor for RustExecutor {
    fn classify(&self, ids: &IntTensor, mask: &Tensor, _batch: usize) -> Result<Vec<i32>> {
        Ok(self.model.predict(ids, mask))
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }
}

/// Quantized-weight executor over [`QuantizedBert`] — the deployment path
/// behind the batcher. Two forms:
///
/// * [`QuantExecutor::resident`]: every fused linear unpacked in RAM
///   (fastest; resident bytes ≈ 50 % of FP32).
/// * [`QuantExecutor::paged`] / [`QuantExecutor::from_paged`]: packed
///   shards page in from a `SQSH0001` file under
///   [`ServeConfig::residency_budget_bytes`] — the "model larger than RAM"
///   form. Logits are byte-identical to the resident form (same planes,
///   same fused kernel); the residency counters surface in [`Metrics`].
pub struct QuantExecutor {
    model: QuantizedBert,
    sizes: Vec<usize>,
}

impl QuantExecutor {
    /// Fully-resident quantized executor.
    pub fn resident(
        cfg: BertConfig,
        store: &ParamStore,
        qm: &QuantizedModel,
        sizes: Vec<usize>,
    ) -> Result<Self> {
        Ok(QuantExecutor { model: QuantizedBert::new(cfg, store, qm)?, sizes })
    }

    /// Open `shards` and serve under `serve.residency_budget_bytes`
    /// (unset ⇒ unbounded: everything stays resident after first use).
    pub fn paged(
        cfg: BertConfig,
        shards: &std::path::Path,
        sizes: Vec<usize>,
        serve: &ServeConfig,
    ) -> Result<Self> {
        let paged = PagedModel::open(
            shards,
            PagedConfig {
                residency_budget_bytes: serve.residency_budget_bytes.unwrap_or(usize::MAX),
                retry: serve.retry.clone(),
                fault: serve.fault.clone(),
                ..PagedConfig::default()
            },
        )?;
        Self::from_paged(cfg, paged, sizes)
    }

    /// Build over an existing [`PagedModel`] — pass `paged.clone()` to
    /// stand up N replicas sharing one residency budget (~1× resident
    /// shard bytes total, the paged analogue of `ParamStore::share`).
    pub fn from_paged(cfg: BertConfig, paged: PagedModel, sizes: Vec<usize>) -> Result<Self> {
        Ok(QuantExecutor { model: QuantizedBert::from_paged(cfg, paged)?, sizes })
    }

    pub fn model(&self) -> &QuantizedBert {
        &self.model
    }

    /// Install a numeric-health recorder on the underlying model and
    /// return a handle to it (call before `Server::start`; recording also
    /// needs the process-wide [`crate::qhealth::set_enabled`] switch on).
    pub fn enable_qhealth(&mut self) -> Arc<crate::qhealth::Recorder> {
        self.model.enable_qhealth()
    }
}

impl BatchExecutor for QuantExecutor {
    fn classify(&self, ids: &IntTensor, mask: &Tensor, _batch: usize) -> Result<Vec<i32>> {
        self.model.predict(ids, mask)
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }

    fn residency(&self) -> Option<ResidencyCounters> {
        self.model.paged().map(|p| p.counters())
    }

    fn plane_stats(&self) -> Option<(usize, usize)> {
        self.model.paged().map(|_| self.model.plane_stats())
    }

    fn shadow_sample(&self, ids: &IntTensor, mask: &Tensor) {
        // a failed shadow fault is telemetry lost, not a request lost
        if let Err(e) = self.model.shadow_sample(ids, mask) {
            log::debug!("shadow sample skipped: {e}");
        }
    }

    fn qhealth(&self) -> Option<crate::qhealth::QHealthSnapshot> {
        self.model.qhealth_snapshot()
    }
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub max_wait: Duration,
    /// Serving worker threads (batch executors). These share the single
    /// process-wide kernel pool configured by `parallel`; raising `workers`
    /// overlaps batch dispatches, it does not multiply kernel threads.
    pub workers: usize,
    pub queue_cap: usize,
    /// Kernel-engine tuning, applied process-wide at `Server::start` (first
    /// configuration wins; see [`crate::parallel::configure`]).
    pub parallel: crate::parallel::ParallelConfig,
    /// Byte budget for paged quantized shards ([`QuantExecutor::paged`]):
    /// the summed on-disk bytes of unpinned resident shards never exceed
    /// it (LRU eviction; embeddings/LN stay pinned outside the budget).
    /// `None` ⇒ unbounded — everything stays resident after first fault.
    /// Lets a server hold a model whose packed payload exceeds RAM.
    pub residency_budget_bytes: Option<usize>,
    /// Bounded retry/backoff around every paged shard read
    /// ([`crate::shardstore::RetryPolicy`]): transient IO errors and
    /// checksum mismatches re-read with deterministic backoff; a shard that
    /// exhausts its attempts is quarantined and its requests error.
    pub retry: crate::shardstore::RetryPolicy,
    /// Deterministic shard-fault injection for chaos testing
    /// ([`crate::shardstore::FaultyIo`]), threaded into
    /// [`QuantExecutor::paged`]. `None` (the default) installs nothing —
    /// the fault-free path pays zero overhead.
    pub fault: Option<crate::shardstore::FaultConfig>,
    /// Dead-work shedding: a queued request older than this is dropped
    /// before batch formation — its submitter gets an error immediately
    /// instead of stale work occupying a batch slot (counted as
    /// [`Metrics::shed_expired`], distinct from ingress `shed`). Must
    /// exceed `max_wait` to be meaningful, since the batcher normally
    /// dispatches the oldest request *at* `max_wait`. `None` disables
    /// expiry.
    pub expire_after: Option<Duration>,
    /// Deterministic 1-in-N shadow-fidelity sampling
    /// ([`crate::qhealth::ShadowConfig`]): sampled requests re-run through
    /// the executor's reference path *after* their batch has responded
    /// (via [`BatchExecutor::shadow_sample`]). Replayable — whether a
    /// request is sampled is a pure function of the schedule seed and its
    /// server-assigned sequence number. `None` (the default) samples
    /// nothing and costs nothing.
    pub shadow: Option<crate::qhealth::ShadowConfig>,
}

impl Default for ServeConfig {
    /// 2ms batching window, 2 serving workers, 1024-deep ingress queue,
    /// auto kernel threads, unbounded shard residency, default retry
    /// policy, no fault injection, no queue expiry.
    fn default() -> Self {
        ServeConfig {
            max_wait: Duration::from_millis(2),
            workers: 2,
            queue_cap: 1024,
            parallel: crate::parallel::ParallelConfig::default(),
            residency_budget_bytes: None,
            retry: crate::shardstore::RetryPolicy::default(),
            fault: None,
            expire_after: None,
            shadow: None,
        }
    }
}

/// Completed classification.
#[derive(Debug, Clone)]
pub struct ClassifyResponse {
    pub label: i32,
    pub batch_size: usize,
    pub latency: Duration,
}

struct Pending {
    ids: Vec<i32>,
    mask: Vec<f32>,
    submitted: Instant,
    /// Server-assigned submission sequence number — the replayable key the
    /// shadow-sampling schedule ([`ServeConfig::shadow`]) fires on.
    seq: u64,
    /// Per-request outcome channel: `Ok` with the classification, or `Err`
    /// when the request was degraded away (executor panic/failure, shard
    /// quarantine, queue expiry) — a submitter always hears back, it never
    /// hangs on a dead request.
    resp: mpsc::Sender<Result<ClassifyResponse>>,
}

struct WorkBatch {
    requests: Vec<Pending>,
    size: usize,
    /// When the batcher formed this batch — splits request latency into
    /// queue time (submit → formed) and batch time (formed → executor).
    formed: Instant,
}

struct IngressState {
    queue: VecDeque<Pending>,
    open: bool,
}

/// Bounded MPSC queue with Condvar signalling in both directions:
/// `not_empty` wakes the batcher the moment work arrives; `not_full` wakes
/// blocked submitters when the batcher drains a dispatch.
struct IngressQueue {
    state: Mutex<IngressState>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

enum PushError {
    Full,
    Closed,
}

impl IngressQueue {
    fn new(cap: usize) -> IngressQueue {
        IngressQueue {
            state: Mutex::new(IngressState { queue: VecDeque::new(), open: true }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Non-blocking enqueue (admission control).
    fn try_push(&self, p: Pending) -> std::result::Result<(), PushError> {
        let mut st = lock_recover(&self.state);
        if !st.open {
            return Err(PushError::Closed);
        }
        if st.queue.len() >= self.cap {
            return Err(PushError::Full);
        }
        st.queue.push_back(p);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking enqueue: waits for queue space (backpressure).
    fn push(&self, p: Pending) -> std::result::Result<(), PushError> {
        let mut st = lock_recover(&self.state);
        while st.open && st.queue.len() >= self.cap {
            st = wait_recover(&self.not_full, st);
        }
        if !st.open {
            return Err(PushError::Closed);
        }
        st.queue.push_back(p);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Close the queue: wakes the batcher (to flush + exit) and any
    /// blocked submitters (to fail fast).
    fn close(&self) {
        lock_recover(&self.state).open = false;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// A running server: ingress queue + batcher + workers.
pub struct Server {
    ingress: Arc<IngressQueue>,
    tokenizer: HashTokenizer,
    metrics: Arc<Mutex<Metrics>>,
    /// Batcher wake-ups that dispatched nothing; atomic so the batcher
    /// never touches the metrics mutex while holding the ingress lock.
    /// Folded into [`Metrics::batcher_polls`] on read.
    polls: Arc<AtomicUsize>,
    /// Queued requests shed because they outlived `expire_after` before
    /// batch formation (same lock-free pattern as `polls`). Folded into
    /// [`Metrics::shed_expired`] on read.
    expired: Arc<AtomicUsize>,
    /// Kept for metrics reads: shard-paging counters live in the executor's
    /// residency manager and are folded into [`Metrics`] on read.
    executor: Arc<dyn BatchExecutor>,
    /// Monotonic submission counter — assigns each request the replayable
    /// sequence number the shadow-sampling schedule keys on.
    seq: AtomicU64,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the pipeline.
    pub fn start(
        executor: Arc<dyn BatchExecutor>,
        tokenizer: HashTokenizer,
        cfg: ServeConfig,
    ) -> Server {
        // the kernel pool is process-wide; the first server to start (or
        // the first kernel dispatch) freezes its configuration
        if !crate::parallel::configure(cfg.parallel.clone())
            && *crate::parallel::config() != cfg.parallel
        {
            log::warn!(
                "ServeConfig.parallel ignored: kernel engine already configured \
                 as {:?}",
                crate::parallel::config()
            );
        }
        let policy = BatchPolicy::new(executor.batch_sizes(), cfg.max_wait);
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let polls = Arc::new(AtomicUsize::new(0));
        let expired = Arc::new(AtomicUsize::new(0));
        let ingress = Arc::new(IngressQueue::new(cfg.queue_cap));
        // bounded work queue: when all workers are busy the batcher blocks
        // here, the ingress queue fills behind it, and `try_submit` starts
        // shedding — backpressure end to end
        let (work_tx, work_rx) = mpsc::sync_channel::<WorkBatch>(cfg.workers.max(1));
        let work_rx = Arc::new(Mutex::new(work_rx));
        let max_len = tokenizer.max_len;

        // ---- batcher thread
        let batcher = {
            let ingress = ingress.clone();
            let polls = polls.clone();
            let expired = expired.clone();
            let expire_after = cfg.expire_after;
            std::thread::Builder::new()
                .name("sq-batcher".into())
                .spawn(move || {
                    'run: loop {
                        let batch = {
                            let mut st = lock_recover(&ingress.state);
                            loop {
                                // dead-work shedding, before batch-shape
                                // selection: a request that outlived its
                                // expiry would only waste a batch slot —
                                // fail it now so its submitter stops
                                // waiting (distinct from ingress `shed`)
                                if let Some(expiry) = expire_after {
                                    let before = st.queue.len();
                                    st.queue.retain(|p| {
                                        if p.submitted.elapsed() <= expiry {
                                            return true;
                                        }
                                        let _ = p.resp.send(Err(Error::Coordinator(
                                            "expired in queue before dispatch".into(),
                                        )));
                                        false
                                    });
                                    let dropped = before - st.queue.len();
                                    if dropped > 0 {
                                        expired.fetch_add(dropped, Ordering::Relaxed);
                                        crate::trace::instant(
                                            crate::trace::Category::Request,
                                            "shed-expired",
                                            dropped as u64,
                                            0,
                                        );
                                        ingress.not_full.notify_all();
                                    }
                                }
                                let pending = st.queue.len();
                                let decision = if st.open {
                                    let oldest = st
                                        .queue
                                        .front()
                                        .map(|p| p.submitted.elapsed())
                                        .unwrap_or(Duration::ZERO);
                                    policy.decide(pending, oldest)
                                } else if pending == 0 {
                                    break 'run; // closed + drained: exit
                                } else {
                                    // shutdown flush: treat the deadline as
                                    // expired so the padding-overhead cap
                                    // applies here too (always dispatches)
                                    policy.decide(pending, policy.max_wait)
                                };
                                if let Some((take, size)) = decision {
                                    let requests: Vec<Pending> =
                                        st.queue.drain(..take).collect();
                                    ingress.not_full.notify_all();
                                    let dispatch =
                                        WorkBatch { requests, size, formed: Instant::now() };
                                    break dispatch;
                                }
                                // nothing dispatchable: sleep until enqueue
                                // (not_empty) or the oldest deadline
                                polls.fetch_add(1, Ordering::Relaxed);
                                match st.queue.front().map(|p| p.submitted.elapsed()) {
                                    None => st = wait_recover(&ingress.not_empty, st),
                                    Some(oldest) => {
                                        let wait = policy
                                            .max_wait
                                            .saturating_sub(oldest)
                                            .max(Duration::from_micros(50));
                                        let (g, _timeout) = wait_timeout_recover(
                                            &ingress.not_empty,
                                            st,
                                            wait,
                                        );
                                        st = g;
                                    }
                                }
                            }
                        };
                        // emitted after the ingress lock is released
                        crate::trace::instant(
                            crate::trace::Category::Batch,
                            "batch-form",
                            batch.requests.len() as u64,
                            batch.size as u64,
                        );
                        if work_tx.send(batch).is_err() {
                            break;
                        }
                    }
                })
                // sq-lint: allow(no-panic-in-serving) — server construction, not the request path: no batcher thread means no server
                .expect("spawn batcher")
        };

        // ---- worker pool (serving workers; kernels share the global pool)
        let mut workers = Vec::new();
        for wi in 0..cfg.workers.max(1) {
            let work_rx = work_rx.clone();
            let executor = executor.clone();
            let metrics = metrics.clone();
            let shadow = cfg.shadow;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sq-worker-{wi}"))
                    .spawn(move || loop {
                        let batch = {
                            let guard = lock_recover(&work_rx);
                            guard.recv()
                        };
                        let Ok(WorkBatch { requests, size, formed }) = batch else { break };
                        let real = requests.len();
                        // pad to the compiled shape with zero-mask rows
                        let pad_sp = crate::trace::span_args(
                            crate::trace::Category::Batch,
                            "pad",
                            real as u64,
                            size as u64,
                        );
                        let mut ids = vec![0i32; size * max_len];
                        let mut mask = vec![0.0f32; size * max_len];
                        for (i, p) in requests.iter().enumerate() {
                            ids[i * max_len..(i + 1) * max_len].copy_from_slice(&p.ids);
                            mask[i * max_len..(i + 1) * max_len].copy_from_slice(&p.mask);
                        }
                        let (ids, mask) = match (
                            IntTensor::new(&[size, max_len], ids),
                            Tensor::new(&[size, max_len], mask),
                        ) {
                            (Ok(i), Ok(m)) => (i, m),
                            _ => {
                                log::error!(
                                    "worker: batch tensor shape mismatch \
                                     (size={size}, max_len={max_len})"
                                );
                                respond_all_err(requests, "batch tensor shape mismatch");
                                continue;
                            }
                        };
                        drop(pad_sp);
                        // shard demand-fault time attributed to this batch
                        // (delta of the executor's residency counter; an
                        // approximation under concurrent workers)
                        let fault0 = executor.residency().map(|c| c.fault_ns).unwrap_or(0);
                        let exec_sp = crate::trace::span_args(
                            crate::trace::Category::Batch,
                            "execute",
                            real as u64,
                            size as u64,
                        );
                        let t0 = Instant::now();
                        // panic containment at the batch boundary: a
                        // panicking executor (kernel bug, poisoned state)
                        // degrades this batch's requests to errors and the
                        // worker re-arms for the next batch — the process
                        // never dies on a request
                        let outcome = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                executor.classify(&ids, &mask, size)
                            }),
                        );
                        let exec = t0.elapsed();
                        drop(exec_sp);
                        let labels = match outcome {
                            Ok(Ok(l)) => l,
                            Ok(Err(e)) => {
                                log::error!("worker: classify failed: {e}");
                                respond_all_err(requests, &format!("classify failed: {e}"));
                                continue;
                            }
                            Err(_) => {
                                log::error!(
                                    "worker: executor panicked on a batch of {real} \
                                     request(s); worker re-armed"
                                );
                                lock_recover(&metrics).exec_panics += 1;
                                crate::trace::instant(
                                    crate::trace::Category::Batch,
                                    "exec-panic",
                                    real as u64,
                                    size as u64,
                                );
                                respond_all_err(requests, "executor panicked on this batch");
                                continue;
                            }
                        };
                        let fault_ns = executor
                            .residency()
                            .map(|c| c.fault_ns)
                            .unwrap_or(0)
                            .saturating_sub(fault0);
                        let fault_each =
                            Duration::from_nanos(fault_ns / real.max(1) as u64);
                        {
                            let mut m = lock_recover(&metrics);
                            m.record_batch(real, size, exec);
                            for p in &requests {
                                let total = p.submitted.elapsed();
                                let queue = formed.saturating_duration_since(p.submitted);
                                let wait = t0.saturating_duration_since(formed);
                                m.record_request(total, queue, wait, exec, fault_each);
                            }
                        }
                        if crate::trace::enabled() {
                            lifecycle_events(&requests, formed, t0, exec);
                        }
                        let resp_sp = crate::trace::span_args(
                            crate::trace::Category::Batch,
                            "respond",
                            real as u64,
                            size as u64,
                        );
                        if labels.len() < real {
                            log::error!(
                                "worker: executor returned {} labels for {real} requests",
                                labels.len()
                            );
                        }
                        // decide shadow rows before the requests are
                        // consumed by the respond loop: the schedule keys
                        // on each request's submission sequence number
                        let shadow_rows: Vec<usize> = match shadow {
                            Some(sc) => requests
                                .iter()
                                .enumerate()
                                .filter(|(_, p)| sc.fires(p.seq))
                                .map(|(i, _)| i)
                                .collect(),
                            None => Vec::new(),
                        };
                        for (i, p) in requests.into_iter().enumerate() {
                            let resp = match labels.get(i) {
                                Some(&label) => Ok(ClassifyResponse {
                                    label,
                                    batch_size: size,
                                    latency: p.submitted.elapsed(),
                                }),
                                None => Err(Error::Coordinator(format!(
                                    "executor returned {} labels for {real} requests",
                                    labels.len()
                                ))),
                            };
                            let _ = p.resp.send(resp);
                        }
                        drop(resp_sp);
                        // shadow-fidelity probes run strictly after the
                        // batch's responses went out — sampled rows re-run
                        // as singletons on the executor's reference path,
                        // so hot-batch latency never carries shadow cost.
                        // Same panic containment as classify: a panicking
                        // probe loses telemetry, never the worker.
                        for &i in &shadow_rows {
                            let (Some(rid), Some(rmk)) = (
                                ids.data().get(i * max_len..(i + 1) * max_len),
                                mask.data().get(i * max_len..(i + 1) * max_len),
                            ) else {
                                continue;
                            };
                            if let (Ok(sid), Ok(smk)) = (
                                IntTensor::new(&[1, max_len], rid.to_vec()),
                                Tensor::new(&[1, max_len], rmk.to_vec()),
                            ) {
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| {
                                        executor.shadow_sample(&sid, &smk);
                                    }),
                                );
                                crate::trace::instant(
                                    crate::trace::Category::Request,
                                    "shadow-sample",
                                    i as u64,
                                    size as u64,
                                );
                            }
                        }
                    })
                    // sq-lint: allow(no-panic-in-serving) — server construction, not the request path: no workers means no server
                    .expect("spawn worker"),
            );
        }

        Server {
            ingress,
            tokenizer,
            metrics,
            polls,
            expired,
            executor,
            seq: AtomicU64::new(0),
            batcher: Some(batcher),
            workers,
        }
    }

    /// Non-blocking submit with admission control: rejects immediately when
    /// the ingress queue is at capacity (load shedding; the shed count is
    /// visible in [`Metrics`]). Use under open-loop load (trace replay).
    /// The receiver yields `Err` when the request was degraded away
    /// (executor panic/failure, quarantined shard, queue expiry).
    pub fn try_submit(&self, text: &str) -> Result<mpsc::Receiver<Result<ClassifyResponse>>> {
        let (ids, mask) = self.tokenizer.encode(text);
        let (rtx, rrx) = mpsc::channel();
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let req = Pending { ids, mask, submitted: Instant::now(), seq, resp: rtx };
        match self.ingress.try_push(req) {
            Ok(()) => {
                crate::trace::instant(crate::trace::Category::Request, "ingress", 0, 0);
                Ok(rrx)
            }
            Err(PushError::Full) => {
                lock_recover(&self.metrics).shed += 1;
                crate::trace::instant(crate::trace::Category::Request, "shed", 0, 0);
                Err(Error::Coordinator("overloaded: ingress queue full".into()))
            }
            Err(PushError::Closed) => {
                Err(Error::Coordinator("server is shut down".into()))
            }
        }
    }

    /// Submit a text; returns a receiver for the response. Blocks while the
    /// ingress queue is full (backpressure). The receiver yields `Err` when
    /// the request was degraded away (executor panic/failure, quarantined
    /// shard, queue expiry) — it never hangs on a dead request.
    pub fn submit(&self, text: &str) -> Result<mpsc::Receiver<Result<ClassifyResponse>>> {
        let (ids, mask) = self.tokenizer.encode(text);
        let (rtx, rrx) = mpsc::channel();
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let req = Pending { ids, mask, submitted: Instant::now(), seq, resp: rtx };
        self.ingress
            .push(req)
            .map_err(|_| Error::Coordinator("server is shut down".into()))?;
        crate::trace::instant(crate::trace::Category::Request, "ingress", 0, 0);
        Ok(rrx)
    }

    /// Blocking classify convenience.
    pub fn classify(&self, text: &str) -> Result<ClassifyResponse> {
        self.submit(text)?
            .recv()
            .map_err(|_| Error::Coordinator("response channel closed".into()))?
    }

    pub fn metrics(&self) -> Metrics {
        let mut m = lock_recover(&self.metrics).clone();
        m.batcher_polls = self.polls.load(Ordering::Relaxed);
        m.shed_expired = self.expired.load(Ordering::Relaxed);
        fold_residency(&mut m, &*self.executor);
        m
    }

    /// Prometheus-style text exposition of the current metrics snapshot
    /// plus the global trace counters ([`crate::trace::prom`]). Safe to
    /// call while serving; also printed by the `splitquant trace`
    /// subcommand.
    pub fn telemetry_text(&self) -> String {
        crate::trace::prom::exposition(&self.metrics())
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) -> Metrics {
        self.ingress.close();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        // dropping the work sender (inside batcher) ends workers
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let mut m = Arc::try_unwrap(std::mem::take(&mut self.metrics))
            .map(into_inner_recover)
            .unwrap_or_else(|arc| lock_recover(&arc).clone());
        m.batcher_polls = self.polls.load(Ordering::Relaxed);
        m.shed_expired = self.expired.load(Ordering::Relaxed);
        fold_residency(&mut m, &*self.executor);
        m
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.ingress.close();
    }
}

/// Emit the per-request lifecycle slices for one completed batch as trace
/// `Complete` events (`req-queue` / `req-batch` / `req-exec` / `req-total`),
/// one set per request, with the request's batch-lane index as the lane so
/// the Chrome exporter can park each lane on its own track. Only called
/// when tracing is enabled.
fn lifecycle_events(requests: &[Pending], formed: Instant, exec_start: Instant, exec: Duration) {
    use crate::trace::{complete, epoch_ns, now_ns, Category};
    let formed_ns = epoch_ns(formed);
    let start_ns = epoch_ns(exec_start);
    let exec_ns = exec.as_nanos() as u64;
    for (lane, p) in requests.iter().enumerate() {
        let lane = lane as u64;
        let sub = epoch_ns(p.submitted);
        complete(Category::Request, "req-queue", sub, formed_ns.saturating_sub(sub), lane);
        complete(
            Category::Request,
            "req-batch",
            formed_ns,
            start_ns.saturating_sub(formed_ns),
            lane,
        );
        complete(Category::Request, "req-exec", start_ns, exec_ns, lane);
        complete(Category::Request, "req-total", sub, now_ns().saturating_sub(sub), lane);
    }
}

/// Degradation path: answer every request of a failed batch with an
/// [`Error::Coordinator`] response — affected requests error, waiting
/// submitters never hang, the process never dies.
fn respond_all_err(requests: Vec<Pending>, msg: &str) {
    for p in requests {
        let _ = p.resp.send(Err(Error::Coordinator(msg.to_string())));
    }
}

/// Copy the executor's shard-paging and plane-cache counters (if any) into
/// a metrics snapshot — that state lives in the executor, not the server.
fn fold_residency(m: &mut Metrics, ex: &dyn BatchExecutor) {
    if let Some(c) = ex.residency() {
        m.shard_faults = c.shard_faults;
        m.shard_evictions = c.shard_evictions;
        m.bytes_paged_in = c.bytes_paged_in;
        m.integrity_failures = c.integrity_failures;
        m.io_retries = c.io_retries;
        m.shards_quarantined = c.shards_quarantined;
    }
    if let Some((decodes, reuses)) = ex.plane_stats() {
        m.plane_decodes = decodes;
        m.plane_reuses = reuses;
    }
    m.qhealth = ex.qhealth();
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may panic freely; the rule guards the serving path
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rust_executor() -> (Arc<dyn BatchExecutor>, HashTokenizer) {
        let cfg = BertConfig {
            vocab_size: 512,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 16,
            num_classes: 6,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
        let ex = RustExecutor::new(cfg, store, vec![1, 4, 8]).unwrap();
        (Arc::new(ex), tok)
    }

    #[test]
    fn staged_param_literals_are_shared_not_recreated() {
        // regression for the per-call `self.params.clone()`: every request's
        // input list must point at the SAME staged literals, across repeated
        // calls — only the trailing request literals are fresh
        let staged: Vec<xla::Literal> =
            (0..3).map(|i| xla::Literal::vec1(&[i as f32])).collect();
        let request = [xla::Literal::vec1(&[9.0f32]), xla::Literal::vec1(&[8.0f32])];
        let a = assemble_literal_refs(&staged, &request);
        let b = assemble_literal_refs(&staged, &request);
        assert_eq!(a.len(), staged.len() + request.len());
        for (i, r) in a.iter().take(staged.len()).enumerate() {
            assert!(std::ptr::eq(*r, &staged[i]), "param {i} re-materialized");
            assert!(std::ptr::eq(*r, b[i]), "param {i} differs across calls");
        }
        assert!(std::ptr::eq(a[3], &request[0]));
        assert!(std::ptr::eq(a[4], &request[1]));
    }

    #[test]
    fn serve_roundtrip() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            ServeConfig {
                max_wait: Duration::from_millis(1),
                workers: 2,
                queue_cap: 64,
                ..ServeConfig::default()
            },
        );
        let r = server.classify("hello there friend").unwrap();
        assert!((0..6).contains(&r.label));
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn serve_many_batches() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            ServeConfig {
                max_wait: Duration::from_millis(1),
                workers: 2,
                queue_cap: 256,
                ..ServeConfig::default()
            },
        );
        let rxs: Vec<_> =
            (0..50).map(|i| server.submit(&format!("message number {i}")).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
            assert!((0..6).contains(&r.label));
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 50);
        assert!(m.real_slots >= 50);
        // under burst load, some batching must have happened
        let batched: usize = m
            .batches_by_size
            .iter()
            .filter(|(&s, _)| s > 1)
            .map(|(_, &c)| c)
            .sum();
        assert!(batched > 0, "expected batched dispatches: {:?}", m.batches_by_size);
    }

    #[test]
    fn shadow_sampling_and_qhealth_fold_into_metrics() {
        let _g = crate::qhealth::test_guard();
        let cfg = BertConfig {
            vocab_size: 512,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 16,
            num_classes: 6,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = crate::splitquant::default_quantizable(&store);
        let (_, qm) = crate::splitquant::quantize_store(
            &store,
            &q,
            &crate::splitquant::SplitQuantConfig::new(4),
        )
        .unwrap();
        let tok = HashTokenizer::new(cfg.vocab_size, cfg.max_len);
        let mut ex = QuantExecutor::resident(cfg, &store, &qm, vec![1, 4, 8]).unwrap();
        ex.enable_qhealth();
        crate::qhealth::set_enabled(true);
        let server = Server::start(
            Arc::new(ex),
            tok,
            ServeConfig {
                max_wait: Duration::from_millis(1),
                workers: 2,
                queue_cap: 64,
                // rate 1: every request shadow-sampled, so the expected
                // sample count is exact no matter how batches formed
                shadow: Some(crate::qhealth::ShadowConfig { seed: 7, rate: 1 }),
                ..ServeConfig::default()
            },
        );
        let rxs: Vec<_> = (0..12)
            .map(|i| server.submit(&format!("health check {i}")).unwrap())
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        let text = server.telemetry_text();
        let m = server.shutdown();
        crate::qhealth::set_enabled(false);
        let qh = m.qhealth.expect("executor recorder must fold into metrics");
        assert!(!qh.layers.is_empty(), "no dispatch telemetry recorded");
        assert!(!qh.sites.is_empty(), "no act-site telemetry recorded");
        assert_eq!(qh.shadow.samples, 12, "rate-1 schedule samples every request");
        // serving never deploys a calibrated range here, so drift can't alarm
        assert!(!qh.drift_alarmed());
        assert!(text.contains("splitquant_quant_drift"), "{text}");
        assert!(text.contains("splitquant_qhealth_shadow_samples_total"), "{text}");
        // metrics JSON carries the qhealth summary object
        let json = m.to_json().to_string();
        assert!(json.contains("\"qhealth\""), "{json}");
    }

    #[test]
    fn lifecycle_breakdown_recorded_without_tracing() {
        // the queue/batch/exec/fault stage histograms and the telemetry
        // text must populate from plain serving — no tracing required
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            ServeConfig {
                max_wait: Duration::from_millis(1),
                workers: 2,
                queue_cap: 64,
                ..ServeConfig::default()
            },
        );
        let rxs: Vec<_> =
            (0..10).map(|i| server.submit(&format!("breakdown {i}")).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        let text = server.telemetry_text();
        assert!(text.contains("splitquant_requests_completed_total 10"), "{text}");
        assert!(text.contains("splitquant_request_stage_us{stage=\"queue\""), "{text}");
        let m = server.shutdown();
        assert_eq!(m.completed, 10);
        assert_eq!(m.queue_us.len(), 10);
        assert_eq!(m.batch_us.len(), 10);
        assert_eq!(m.exec_us.len(), 10);
        assert_eq!(m.fault_us.len(), 10);
        let rows = m.breakdown_records("test", "rust");
        assert!(rows.iter().any(|r| r.bench == "breakdown-exec"), "{rows:?}");
    }

    #[test]
    fn padding_is_inert() {
        // a request classified alone == classified inside a padded batch
        let (ex, tok) = rust_executor();
        let (ids, mask) = tok.encode("the exact same text");
        let one = {
            let ids = IntTensor::new(&[1, 16], ids.clone()).unwrap();
            let mask = Tensor::new(&[1, 16], mask.clone()).unwrap();
            ex.classify(&ids, &mask, 1).unwrap()[0]
        };
        let padded = {
            let mut idp = ids.clone();
            let mut mp = mask.clone();
            idp.extend(vec![0i32; 3 * 16]);
            mp.extend(vec![0.0f32; 3 * 16]);
            let ids = IntTensor::new(&[4, 16], idp).unwrap();
            let mask = Tensor::new(&[4, 16], mp).unwrap();
            ex.classify(&ids, &mask, 4).unwrap()[0]
        };
        assert_eq!(one, padded);
    }

    #[test]
    fn admission_control_sheds_on_overload() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            // tiny queue + long deadline: the queue must fill
            ServeConfig {
                max_wait: Duration::from_secs(60),
                workers: 1,
                queue_cap: 4,
                ..ServeConfig::default()
            },
        );
        let mut accepted = 0usize;
        let mut shed = 0usize;
        let mut rxs = Vec::new();
        // with a 60s deadline nothing dispatches, so the 4-slot queue
        // rejects from the 5th request on (bounded to keep the test finite)
        for i in 0..10_000 {
            match server.try_submit(&format!("req {i}")) {
                Ok(rx) => {
                    accepted += 1;
                    rxs.push(rx);
                }
                Err(_) => shed += 1,
            }
            if shed > 0 && accepted >= 4 {
                break;
            }
        }
        assert!(shed > 0, "expected shedding with queue_cap=4");
        assert!(accepted >= 4);
        let m = server.shutdown();
        assert_eq!(m.shed, shed);
        assert_eq!(m.completed, accepted);
    }

    #[test]
    fn shutdown_flushes_queue() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            // very long deadline: only the shutdown flush can dispatch these
            ServeConfig {
                max_wait: Duration::from_secs(60),
                workers: 1,
                queue_cap: 64,
                ..ServeConfig::default()
            },
        );
        let rxs: Vec<_> = (0..3).map(|_| server.submit("drain me").unwrap()).collect();
        std::thread::sleep(Duration::from_millis(10));
        let m = server.shutdown();
        assert_eq!(m.completed, 3);
        for rx in rxs {
            assert!(rx.try_recv().expect("response present").is_ok());
        }
    }

    #[test]
    fn idle_batcher_does_not_spin() {
        // regression for the park_timeout(200µs) busy-wait: an idle batcher
        // slept ~1500 times over 300ms; the Condvar batcher blocks on
        // not_empty and wakes only on enqueue/close
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            ServeConfig {
                max_wait: Duration::from_millis(1),
                workers: 1,
                queue_cap: 64,
                ..ServeConfig::default()
            },
        );
        std::thread::sleep(Duration::from_millis(300));
        let m = server.shutdown();
        assert!(
            m.batcher_polls < 50,
            "idle batcher woke {} times in 300ms — busy-spin regression",
            m.batcher_polls
        );
    }

    #[test]
    fn deadline_dispatch_bounds_padding() {
        // end-to-end companion to the BatchPolicy unit tests: 9 requests
        // against sizes [1,4,8] must dispatch as 8+1, never padded waste
        // above 2×; verify via the padded/real slot accounting
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            ServeConfig {
                max_wait: Duration::from_millis(5),
                workers: 1,
                queue_cap: 64,
                ..ServeConfig::default()
            },
        );
        let rxs: Vec<_> =
            (0..9).map(|i| server.submit(&format!("padded {i}")).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 9);
        let executed = m.real_slots + m.padded_slots;
        assert!(
            (executed as f64) <= 2.0 * m.real_slots as f64,
            "padding overhead too high: executed {executed} for {} real",
            m.real_slots
        );
    }

    /// Executor that panics on its first `remaining_panics` classify calls,
    /// then serves label 0 — exercises the worker's panic containment.
    struct PanickyExecutor {
        remaining_panics: AtomicUsize,
        sizes: Vec<usize>,
    }

    impl BatchExecutor for PanickyExecutor {
        fn classify(&self, _ids: &IntTensor, _mask: &Tensor, batch: usize) -> Result<Vec<i32>> {
            if self
                .remaining_panics
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
                .is_ok()
            {
                panic!("injected executor panic");
            }
            Ok(vec![0; batch])
        }

        fn batch_sizes(&self) -> Vec<usize> {
            self.sizes.clone()
        }
    }

    #[test]
    fn executor_panic_degrades_to_errors_and_the_server_survives() {
        let ex = Arc::new(PanickyExecutor {
            remaining_panics: AtomicUsize::new(1),
            sizes: vec![1, 4, 8],
        });
        let tok = HashTokenizer::new(512, 16);
        let server = Server::start(
            ex,
            tok,
            ServeConfig {
                max_wait: Duration::from_millis(1),
                workers: 1,
                queue_cap: 64,
                ..ServeConfig::default()
            },
        );
        // first batch hits the injected panic: its request errors instead
        // of hanging, and the worker re-arms
        let err = server.classify("first request").unwrap_err();
        assert!(format!("{err}").contains("panicked"), "{err}");
        // the very next request is served normally by the same worker
        let ok = server.classify("second request").unwrap();
        assert_eq!(ok.label, 0);
        let m = server.shutdown();
        assert_eq!(m.exec_panics, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn expired_requests_are_shed_before_batch_formation() {
        let (ex, tok) = rust_executor();
        let server = Server::start(
            ex,
            tok,
            // expiry far below the batching window: every queued request
            // outlives it before the deadline dispatch can form a batch
            ServeConfig {
                max_wait: Duration::from_millis(40),
                workers: 1,
                queue_cap: 64,
                expire_after: Some(Duration::from_millis(5)),
                ..ServeConfig::default()
            },
        );
        let rxs: Vec<_> =
            (0..3).map(|i| server.submit(&format!("stale {i}")).unwrap()).collect();
        for rx in rxs {
            // the submitter hears back with an error — it does not hang
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            let err = resp.unwrap_err();
            assert!(format!("{err}").contains("expired"), "{err}");
        }
        let m = server.shutdown();
        assert_eq!(m.shed_expired, 3);
        assert_eq!(m.completed, 0);
        assert_eq!(m.shed, 0, "queue expiry must not count as ingress shedding");
    }
}
