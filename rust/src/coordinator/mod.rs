//! Serving coordinator (L3): request router + dynamic batcher + worker pool
//! over the AOT forward executables.
//!
//! Architecture (vLLM-router-like, scaled to this workload):
//!
//! ```text
//! clients ──submit()──▶ bounded queue ──▶ batcher thread ──▶ work queue ──▶ workers
//!                                           │  size/deadline policy          │
//!                                           └─ pads to a compiled batch      └─ PJRT (or
//!                                              shape (b1/b8/b32)                Rust) executor
//! ```
//!
//! The batcher groups requests to amortize executable dispatch; because XLA
//! executables are shape-specialized, it pads partial batches up to the
//! nearest compiled batch size (padding rows carry an all-zero attention
//! mask, so they cost compute but never change results — verified by the
//! `padding_is_inert` test). Padding waste is capped at
//! [`batcher::MAX_PADDING_OVERHEAD`]: when the ceiling size would exceed
//! it, the batcher dispatches the largest compiled size the pending
//! requests fill completely and leaves the remainder queued. The batcher
//! thread itself sleeps on a Condvar signalled by enqueue — idle wake-ups
//! are counted in [`Metrics::batcher_polls`] and regression-tested to stay
//! near zero (the 200µs `park_timeout` spin this replaced burned a core).
//! Kernel-level parallelism comes from the process-wide
//! [`crate::parallel`] pool, shared by all workers.

// The serving path must not panic on bad input (sq-lint rule
// `no-panic-in-serving`); clippy backs that up at compile time for this
// module tree. Test modules and provably-infallible sites opt out locally.
#![deny(clippy::unwrap_used)]

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{BatchPolicy, MAX_PADDING_OVERHEAD};
pub use metrics::Metrics;
pub use server::{
    BatchExecutor, ClassifyResponse, PjrtExecutor, QuantExecutor, RustExecutor, ServeConfig,
    Server,
};
