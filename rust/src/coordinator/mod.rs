//! Serving coordinator (L3): request router + dynamic batcher + worker pool
//! over the AOT forward executables.
//!
//! Architecture (vLLM-router-like, scaled to this workload):
//!
//! ```text
//! clients ──submit()──▶ bounded queue ──▶ batcher thread ──▶ work queue ──▶ workers
//!                                           │  size/deadline policy          │
//!                                           └─ pads to a compiled batch      └─ PJRT (or
//!                                              shape (b1/b8/b32)                Rust) executor
//! ```
//!
//! The batcher groups requests to amortize executable dispatch; because XLA
//! executables are shape-specialized, it pads partial batches up to the
//! nearest compiled batch size (padding rows carry an all-zero attention
//! mask, so they cost compute but never change results — verified by the
//! `padding_is_inert` test).

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::BatchPolicy;
pub use metrics::Metrics;
pub use server::{BatchExecutor, ClassifyResponse, PjrtExecutor, RustExecutor, ServeConfig, Server};
