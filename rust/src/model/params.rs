//! Ordered, named parameter storage + binary checkpoints.
//!
//! The store preserves the manifest's flat parameter order (the ABI with the
//! AOT executables) while offering name-based access for the quantization
//! passes. Checkpoints use a simple versioned little-endian binary format.
//!
//! ## Shared-memory semantics
//!
//! Tensors live behind [`Arc`] with copy-on-write semantics:
//!
//! * [`ParamStore::share`] (and plain `clone()`) produce an **O(1) replica
//!   view** — N serving replicas built from one store hold zero duplicated
//!   weight tensors (verified by `Arc::ptr_eq` in `tests/integration_share`).
//! * [`ParamStore::get`] returns a cheap borrowed view; [`ParamStore::handle`]
//!   returns the shared `Arc` handle itself.
//! * [`ParamStore::set`] and [`ParamStore::get_mut`] break sharing for **only
//!   the touched tensor** (clone-on-write); every other tensor stays shared
//!   with all replicas.
//!
//! This is what lets `RustExecutor` replicas, staged `PjrtExecutor`
//! parameters and the quantization pipeline's eval views coexist at ~1×
//! resident weight bytes (ROADMAP "Sharded ParamStore").

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::tensor::Tensor;
use crate::util::io::{read_f32_vec, read_u16, read_u32, read_u8, write_f32_slice};
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"SQCKPT1\n";

/// Ordered named tensors behind shared, copy-on-write storage.
#[derive(Debug, Clone)]
pub struct ParamStore {
    names: Vec<String>,
    tensors: Vec<Arc<Tensor>>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    /// Zero-initialized store following a (name, shape) order.
    pub fn zeros(order: &[(String, Vec<usize>)]) -> Self {
        let mut s = ParamStore { names: Vec::new(), tensors: Vec::new(), index: HashMap::new() };
        for (n, shape) in order {
            s.push(n.clone(), Tensor::zeros(shape));
        }
        s
    }

    /// BERT-style init: LayerNorm gamma = 1, biases/betas = 0, everything
    /// else N(0, 0.02) — matching common transformer initialization.
    pub fn init_bert(order: &[(String, Vec<usize>)], rng: &mut Rng) -> Self {
        let mut s = ParamStore { names: Vec::new(), tensors: Vec::new(), index: HashMap::new() };
        for (n, shape) in order {
            let t = if n.ends_with(".gamma") {
                Tensor::ones(shape)
            } else if n.ends_with(".beta") || n.ends_with(".bias") {
                Tensor::zeros(shape)
            } else {
                Tensor::randn(shape, 0.0, 0.02, rng)
            };
            s.push(n.clone(), t);
        }
        s
    }

    /// CNN init: BN gamma/var = 1, mean/beta/bias = 0, weights He-ish.
    pub fn init_cnn(order: &[(String, Vec<usize>)], rng: &mut Rng) -> Self {
        let mut s = ParamStore { names: Vec::new(), tensors: Vec::new(), index: HashMap::new() };
        for (n, shape) in order {
            let t = if n.ends_with(".gamma") || n.ends_with(".var") {
                Tensor::ones(shape)
            } else if n.ends_with(".beta") || n.ends_with(".bias") || n.ends_with(".mean") {
                Tensor::zeros(shape)
            } else {
                let fan_in: usize = shape[1..].iter().product::<usize>().max(1);
                let std = (2.0 / fan_in as f32).sqrt();
                Tensor::randn(shape, 0.0, std, rng)
            };
            s.push(n.clone(), t);
        }
        s
    }

    pub fn push(&mut self, name: String, t: Tensor) {
        self.push_shared(name, Arc::new(t));
    }

    /// Push an already-shared tensor handle without copying its data — the
    /// caller (e.g. [`crate::shardstore::PagedModel`]'s pinned set) keeps
    /// its `Arc` and both sides reference one allocation.
    pub fn push_shared(&mut self, name: String, t: Arc<Tensor>) {
        assert!(!self.index.contains_key(&name), "duplicate param {name}");
        self.index.insert(name.clone(), self.tensors.len());
        self.names.push(name);
        self.tensors.push(t);
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// O(1) replica view: every tensor is shared with `self` until one side
    /// writes to it (copy-on-write). This is the serving-replica entry point:
    /// N replicas cost ~1× the weight bytes, not N×.
    pub fn share(&self) -> ParamStore {
        self.clone()
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &*self.tensors[i])
            .ok_or_else(|| Error::Model(format!("no parameter named {name:?}")))
    }

    /// The shared handle behind `name` (for `Arc::ptr_eq` sharing checks and
    /// callers that want to hold a tensor past the store's lifetime).
    pub fn handle(&self, name: &str) -> Result<Arc<Tensor>> {
        self.index
            .get(name)
            .map(|&i| Arc::clone(&self.tensors[i]))
            .ok_or_else(|| Error::Model(format!("no parameter named {name:?}")))
    }

    /// Whether `name` is backed by the same allocation in both stores
    /// (true for untouched tensors of a [`ParamStore::share`] replica).
    pub fn shares_tensor(&self, other: &ParamStore, name: &str) -> bool {
        match (self.index.get(name), other.index.get(name)) {
            (Some(&i), Some(&j)) => Arc::ptr_eq(&self.tensors[i], &other.tensors[j]),
            _ => false,
        }
    }

    /// Mutable view; clones the tensor first if it is shared with a replica
    /// (copy-on-write), so writes never leak into other views.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        match self.index.get(name).copied() {
            Some(i) => Ok(Arc::make_mut(&mut self.tensors[i])),
            None => Err(Error::Model(format!("no parameter named {name:?}"))),
        }
    }

    /// Replace one tensor. Only this slot's sharing is broken; replicas keep
    /// the previous allocation.
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        self.set_shared(name, Arc::new(t))
    }

    /// Replace one tensor with an already-shared handle (no data copy; the
    /// slot now aliases the caller's allocation — same sharing semantics as
    /// a fresh [`ParamStore::share`] replica slot).
    pub fn set_shared(&mut self, name: &str, t: Arc<Tensor>) -> Result<()> {
        let i = match self.index.get(name).copied() {
            Some(i) => i,
            None => return Err(Error::Model(format!("no parameter named {name:?}"))),
        };
        if self.tensors[i].shape() != t.shape() {
            return Err(Error::Model(format!(
                "set {name:?}: shape {:?} != existing {:?}",
                t.shape(),
                self.tensors[i].shape()
            )));
        }
        self.tensors[i] = t;
        Ok(())
    }

    /// Shared tensor handles in flat (manifest) order.
    pub fn flat(&self) -> &[Arc<Tensor>] {
        &self.tensors
    }

    /// Tensor views in flat (manifest) order.
    pub fn flat_tensors(&self) -> impl Iterator<Item = &Tensor> {
        self.tensors.iter().map(|t| &**t)
    }

    /// Replace all tensors, keeping names (training-step output ingestion).
    pub fn replace_flat(&mut self, tensors: Vec<Tensor>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            return Err(Error::Model(format!(
                "replace_flat: {} tensors for {} slots",
                tensors.len(),
                self.tensors.len()
            )));
        }
        for (slot, t) in self.tensors.iter_mut().zip(tensors) {
            if slot.shape() != t.shape() {
                return Err(Error::Model(format!(
                    "replace_flat shape {:?} != {:?}",
                    t.shape(),
                    slot.shape()
                )));
            }
            *slot = Arc::new(t);
        }
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.names.iter().map(|s| s.as_str()).zip(self.flat_tensors())
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Total FP32 bytes (paper-§6 size accounting base). Counts every slot,
    /// shared or not; see [`ParamStore::resident_bytes`] for the deduplicated
    /// figure across replicas.
    pub fn byte_size(&self) -> usize {
        self.tensors.iter().map(|t| t.byte_size()).sum()
    }

    /// Unique resident FP32 bytes across a set of stores: tensors shared
    /// between replicas (same allocation) are counted once. For N fresh
    /// [`ParamStore::share`] replicas this equals one store's
    /// [`ParamStore::byte_size`].
    pub fn resident_bytes<'a, I>(stores: I) -> usize
    where
        I: IntoIterator<Item = &'a ParamStore>,
    {
        let mut seen = std::collections::HashSet::new();
        let mut total = 0usize;
        for s in stores {
            for t in &s.tensors {
                if seen.insert(Arc::as_ptr(t)) {
                    total += t.byte_size();
                }
            }
        }
        total
    }

    /// Save to a binary checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.len() as u32).to_le_bytes())?;
        for (name, t) in self.iter() {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(t.shape().len() as u8).to_le_bytes())?;
            for &d in t.shape() {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            // one buffered write per tensor payload (not one per f32)
            write_f32_slice(&mut f, t.data())?;
        }
        Ok(())
    }

    /// Load from a binary checkpoint.
    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Checkpoint(format!("{path:?}: bad magic {magic:?}")));
        }
        let count = read_u32(&mut f)? as usize;
        let mut s = ParamStore { names: Vec::new(), tensors: Vec::new(), index: HashMap::new() };
        for _ in 0..count {
            let nlen = read_u16(&mut f)? as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)
                .map_err(|e| Error::Checkpoint(format!("bad name: {e}")))?;
            let ndim = read_u8(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let data = read_f32_vec(&mut f, numel)?;
            s.push(name, Tensor::new(&shape, data)?);
        }
        Ok(s)
    }

    /// Validate against an expected (name, shape) order.
    pub fn check_order(&self, order: &[(String, Vec<usize>)]) -> Result<()> {
        if self.len() != order.len() {
            return Err(Error::Model(format!(
                "store has {} params, expected {}",
                self.len(),
                order.len()
            )));
        }
        for ((name, shape), (n2, t)) in order.iter().zip(self.iter()) {
            if name != n2 || shape.as_slice() != t.shape() {
                return Err(Error::Model(format!(
                    "param mismatch: expected {name:?}{shape:?}, got {n2:?}{:?}",
                    t.shape()
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;

    #[test]
    fn init_and_access() {
        let cfg = BertConfig { vocab_size: 50, hidden: 8, layers: 1, ..Default::default() };
        let mut rng = Rng::new(0);
        let s = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        assert_eq!(s.len(), 24);
        assert!(s.get("embeddings.ln.gamma").unwrap().data().iter().all(|&v| v == 1.0));
        assert!(s.get("encoder.0.attn.q.bias").unwrap().data().iter().all(|&v| v == 0.0));
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = BertConfig {
            vocab_size: 30,
            hidden: 8,
            layers: 1,
            heads: 2,
            ffn: 16,
            max_len: 10,
            ..Default::default()
        };
        let mut rng = Rng::new(1);
        let s = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let path = std::env::temp_dir().join("splitquant_test_ckpt.bin");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.len(), s.len());
        for (name, t) in s.iter() {
            let l = loaded.get(name).unwrap();
            assert_eq!(l.shape(), t.shape());
            assert_eq!(l.data(), t.data());
        }
        loaded.check_order(&cfg.param_order()).unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("splitquant_test_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn replace_flat_validates() {
        let order = vec![("a".to_string(), vec![2usize]), ("b".to_string(), vec![3usize])];
        let mut s = ParamStore::zeros(&order);
        assert!(s.replace_flat(vec![Tensor::zeros(&[2])]).is_err());
        assert!(s
            .replace_flat(vec![Tensor::zeros(&[2]), Tensor::zeros(&[4])])
            .is_err());
        assert!(s
            .replace_flat(vec![Tensor::ones(&[2]), Tensor::ones(&[3])])
            .is_ok());
        assert_eq!(s.get("a").unwrap().data(), &[1.0, 1.0]);
    }

    #[test]
    fn set_checks_shape() {
        let order = vec![("w".to_string(), vec![2usize, 2])];
        let mut s = ParamStore::zeros(&order);
        assert!(s.set("w", Tensor::zeros(&[4])).is_err());
        assert!(s.set("w", Tensor::ones(&[2, 2])).is_ok());
    }

    #[test]
    fn share_is_zero_copy_until_written() {
        let order = vec![
            ("w".to_string(), vec![4usize, 4]),
            ("b".to_string(), vec![4usize]),
        ];
        let base = ParamStore::zeros(&order);
        let mut replica = base.share();
        assert!(replica.shares_tensor(&base, "w"));
        assert!(replica.shares_tensor(&base, "b"));
        assert!(Arc::ptr_eq(&base.handle("w").unwrap(), &replica.handle("w").unwrap()));
        assert_eq!(ParamStore::resident_bytes([&base, &replica]), base.byte_size());

        // writing through the replica breaks sharing for that tensor only
        replica.get_mut("w").unwrap().data_mut()[0] = 7.0;
        assert!(!replica.shares_tensor(&base, "w"));
        assert!(replica.shares_tensor(&base, "b"));
        assert_eq!(base.get("w").unwrap().data()[0], 0.0);
        assert_eq!(replica.get("w").unwrap().data()[0], 7.0);
        assert_eq!(
            ParamStore::resident_bytes([&base, &replica]),
            base.byte_size() + base.get("w").unwrap().byte_size()
        );
    }

    #[test]
    fn shared_handles_alias_one_allocation() {
        let order = vec![("w".to_string(), vec![2usize])];
        let mut s = ParamStore::zeros(&order);
        let t = Arc::new(Tensor::ones(&[2]));
        s.set_shared("w", Arc::clone(&t)).unwrap();
        assert!(Arc::ptr_eq(&s.handle("w").unwrap(), &t));
        // shape still validated
        assert!(s.set_shared("w", Arc::new(Tensor::zeros(&[3]))).is_err());

        let mut s2 = ParamStore::zeros(&[]);
        s2.push_shared("w".into(), Arc::clone(&t));
        assert!(Arc::ptr_eq(&s2.handle("w").unwrap(), &t));
        assert!(s2.shares_tensor(&s, "w"));
    }

    #[test]
    fn replace_flat_breaks_sharing_per_slot() {
        let order = vec![("a".to_string(), vec![2usize]), ("b".to_string(), vec![3usize])];
        let base = ParamStore::zeros(&order);
        let mut replica = base.share();
        replica
            .replace_flat(vec![Tensor::ones(&[2]), Tensor::ones(&[3])])
            .unwrap();
        assert!(!replica.shares_tensor(&base, "a"));
        assert!(base.get("a").unwrap().data().iter().all(|&v| v == 0.0));
        assert!(replica.get("a").unwrap().data().iter().all(|&v| v == 1.0));
    }
}
