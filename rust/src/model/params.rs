//! Ordered, named parameter storage + binary checkpoints.
//!
//! The store preserves the manifest's flat parameter order (the ABI with the
//! AOT executables) while offering name-based access for the quantization
//! passes. Checkpoints use a simple versioned little-endian binary format.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"SQCKPT1\n";

/// Ordered named tensors.
#[derive(Debug, Clone)]
pub struct ParamStore {
    names: Vec<String>,
    tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    /// Zero-initialized store following a (name, shape) order.
    pub fn zeros(order: &[(String, Vec<usize>)]) -> Self {
        let mut s = ParamStore { names: Vec::new(), tensors: Vec::new(), index: HashMap::new() };
        for (n, shape) in order {
            s.push(n.clone(), Tensor::zeros(shape));
        }
        s
    }

    /// BERT-style init: LayerNorm gamma = 1, biases/betas = 0, everything
    /// else N(0, 0.02) — matching common transformer initialization.
    pub fn init_bert(order: &[(String, Vec<usize>)], rng: &mut Rng) -> Self {
        let mut s = ParamStore { names: Vec::new(), tensors: Vec::new(), index: HashMap::new() };
        for (n, shape) in order {
            let t = if n.ends_with(".gamma") {
                Tensor::ones(shape)
            } else if n.ends_with(".beta") || n.ends_with(".bias") {
                Tensor::zeros(shape)
            } else {
                Tensor::randn(shape, 0.0, 0.02, rng)
            };
            s.push(n.clone(), t);
        }
        s
    }

    /// CNN init: BN gamma/var = 1, mean/beta/bias = 0, weights He-ish.
    pub fn init_cnn(order: &[(String, Vec<usize>)], rng: &mut Rng) -> Self {
        let mut s = ParamStore { names: Vec::new(), tensors: Vec::new(), index: HashMap::new() };
        for (n, shape) in order {
            let t = if n.ends_with(".gamma") || n.ends_with(".var") {
                Tensor::ones(shape)
            } else if n.ends_with(".beta") || n.ends_with(".bias") || n.ends_with(".mean") {
                Tensor::zeros(shape)
            } else {
                let fan_in: usize = shape[1..].iter().product::<usize>().max(1);
                let std = (2.0 / fan_in as f32).sqrt();
                Tensor::randn(shape, 0.0, std, rng)
            };
            s.push(n.clone(), t);
        }
        s
    }

    pub fn push(&mut self, name: String, t: Tensor) {
        assert!(!self.index.contains_key(&name), "duplicate param {name}");
        self.index.insert(name.clone(), self.tensors.len());
        self.names.push(name);
        self.tensors.push(t);
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| Error::Model(format!("no parameter named {name:?}")))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        match self.index.get(name) {
            Some(&i) => Ok(&mut self.tensors[i]),
            None => Err(Error::Model(format!("no parameter named {name:?}"))),
        }
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let cur = self.get(name)?;
        if cur.shape() != t.shape() {
            return Err(Error::Model(format!(
                "set {name:?}: shape {:?} != existing {:?}",
                t.shape(),
                cur.shape()
            )));
        }
        *self.get_mut(name)? = t;
        Ok(())
    }

    /// Tensors in flat (manifest) order.
    pub fn flat(&self) -> &[Tensor] {
        &self.tensors
    }

    /// Replace all tensors, keeping names (training-step output ingestion).
    pub fn replace_flat(&mut self, tensors: Vec<Tensor>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            return Err(Error::Model(format!(
                "replace_flat: {} tensors for {} slots",
                tensors.len(),
                self.tensors.len()
            )));
        }
        for (slot, t) in self.tensors.iter_mut().zip(tensors) {
            if slot.shape() != t.shape() {
                return Err(Error::Model(format!(
                    "replace_flat shape {:?} != {:?}",
                    t.shape(),
                    slot.shape()
                )));
            }
            *slot = t;
        }
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.names.iter().map(|s| s.as_str()).zip(self.tensors.iter())
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Total FP32 bytes (paper-§6 size accounting base).
    pub fn byte_size(&self) -> usize {
        self.tensors.iter().map(|t| t.byte_size()).sum()
    }

    /// Save to a binary checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.len() as u32).to_le_bytes())?;
        for (name, t) in self.iter() {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(t.shape().len() as u8).to_le_bytes())?;
            for &d in t.shape() {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            for &v in t.data() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load from a binary checkpoint.
    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Checkpoint(format!("{path:?}: bad magic {magic:?}")));
        }
        let count = read_u32(&mut f)? as usize;
        let mut s = ParamStore { names: Vec::new(), tensors: Vec::new(), index: HashMap::new() };
        for _ in 0..count {
            let nlen = read_u16(&mut f)? as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)
                .map_err(|e| Error::Checkpoint(format!("bad name: {e}")))?;
            let ndim = read_u8(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let mut buf = vec![0u8; numel * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            s.push(name, Tensor::new(&shape, data)?);
        }
        Ok(s)
    }

    /// Validate against an expected (name, shape) order.
    pub fn check_order(&self, order: &[(String, Vec<usize>)]) -> Result<()> {
        if self.len() != order.len() {
            return Err(Error::Model(format!(
                "store has {} params, expected {}",
                self.len(),
                order.len()
            )));
        }
        for ((name, shape), (n2, t)) in order.iter().zip(self.iter()) {
            if name != n2 || shape.as_slice() != t.shape() {
                return Err(Error::Model(format!(
                    "param mismatch: expected {name:?}{shape:?}, got {n2:?}{:?}",
                    t.shape()
                )));
            }
        }
        Ok(())
    }
}

fn read_u8(f: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    f.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::BertConfig;

    #[test]
    fn init_and_access() {
        let cfg = BertConfig { vocab_size: 50, hidden: 8, layers: 1, ..Default::default() };
        let mut rng = Rng::new(0);
        let s = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        assert_eq!(s.len(), 24);
        assert!(s.get("embeddings.ln.gamma").unwrap().data().iter().all(|&v| v == 1.0));
        assert!(s.get("encoder.0.attn.q.bias").unwrap().data().iter().all(|&v| v == 0.0));
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = BertConfig {
            vocab_size: 30,
            hidden: 8,
            layers: 1,
            heads: 2,
            ffn: 16,
            max_len: 10,
            ..Default::default()
        };
        let mut rng = Rng::new(1);
        let s = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let path = std::env::temp_dir().join("splitquant_test_ckpt.bin");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.len(), s.len());
        for (name, t) in s.iter() {
            let l = loaded.get(name).unwrap();
            assert_eq!(l.shape(), t.shape());
            assert_eq!(l.data(), t.data());
        }
        loaded.check_order(&cfg.param_order()).unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("splitquant_test_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn replace_flat_validates() {
        let order = vec![("a".to_string(), vec![2usize]), ("b".to_string(), vec![3usize])];
        let mut s = ParamStore::zeros(&order);
        assert!(s.replace_flat(vec![Tensor::zeros(&[2])]).is_err());
        assert!(s
            .replace_flat(vec![Tensor::zeros(&[2]), Tensor::zeros(&[4])])
            .is_err());
        assert!(s
            .replace_flat(vec![Tensor::ones(&[2]), Tensor::ones(&[3])])
            .is_ok());
        assert_eq!(s.get("a").unwrap().data(), &[1.0, 1.0]);
    }

    #[test]
    fn set_checks_shape() {
        let order = vec![("w".to_string(), vec![2usize, 2])];
        let mut s = ParamStore::zeros(&order);
        assert!(s.set("w", Tensor::zeros(&[4])).is_err());
        assert!(s.set("w", Tensor::ones(&[2, 2])).is_ok());
    }
}
