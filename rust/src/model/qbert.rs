//! Quantized-weight BERT executor — the *deployment* path.
//!
//! [`super::bert::BertModel`] evaluates PTQ accuracy by dequantizing weights
//! back to an FP32 store (the paper's simulation protocol). This module
//! instead keeps the packed [`QTensor`]s resident and dequantizes **on the
//! fly inside the matmul**, mirroring the L1 `split_matmul` Pallas kernel:
//! per weight element the cluster id selects (scale, zp) and the fused loop
//! reconstructs `w = (q − zp)/scale` in registers before the FMA.
//!
//! Memory: INT2+cid ≈ 12.5 % of the FP32 weights (§6 accounting) — this
//! executor actually realizes that saving at inference time instead of
//! re-materializing FP32 copies.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::error::Result;
use crate::parallel::{kernels, KernelKind};
use crate::quant::{QParams, QTensor};
use crate::shardstore::{PagedModel, ShardData};
use crate::splitquant::{ActQuantParams, QuantizedModel};
use crate::tensor::ops;
use crate::tensor::{IntTensor, Tensor};

use super::bert::argmax_rows;
use super::config::BertConfig;
use super::params::ParamStore;

/// A linear weight in deployment form: packed codes + per-group params,
/// unpacked lazily row-by-row during the matmul.
#[derive(Debug, Clone)]
pub struct QLinear {
    q: QTensor,
    /// decoded i8 codes (kept unpacked for the hot loop; still 1 byte/elem
    /// = 25 % of FP32; the packed form stays the storage format)
    codes: Vec<i8>,
    /// cluster id per element (Split layout) — empty for per-tensor
    cid: Vec<u8>,
}

impl QLinear {
    pub fn new(q: QTensor) -> Result<Self> {
        let (codes, cid) = q.fused_planes()?;
        Ok(QLinear { q, codes, cid })
    }

    pub fn shape(&self) -> &[usize] {
        self.q.shape()
    }

    /// `y = x @ dq(W)` — the Rust twin of the L1 `split_matmul` kernel.
    ///
    /// Runs the tiled fused kernel
    /// ([`crate::parallel::kernels::split_matmul`]): per-cluster weight
    /// tiles are dequantized into a cache-resident scratch tile inside the
    /// blocked matmul, never materializing the full FP32 matrix. §Perf:
    /// the earlier full-scratch variant dequantized all of W per call
    /// (k·n·4 bytes of traffic before the first FMA); tile dequant keeps
    /// the reconstruction in L1/L2 and row-partitions across the worker
    /// pool for large batches, while resident weight memory stays ≤50 %
    /// of FP32 (unpacked codes + cid).
    pub fn matmul_fused(&self, x: &Tensor) -> Tensor {
        crate::parallel::kernels::split_matmul(
            x,
            self.q.shape(),
            &self.codes,
            &self.cid,
            self.q.params(),
        )
    }

    /// Resident bytes of this deployment form (unpacked codes + cid + meta).
    pub fn resident_bytes(&self) -> usize {
        self.codes.len() + self.cid.len() + self.q.params().len() * 12
    }

    /// Packed storage bytes (what goes on disk / over the wire).
    pub fn packed_bytes(&self) -> usize {
        self.q.byte_size()
    }
}

/// Whether a quantized tensor executes on the fused linear path (vs being
/// dequantized into the FP32 store once): a rank-2 weight outside the
/// embedding block. The single source of truth shared with
/// [`crate::shardstore::paged`]'s pagable classification, so the resident
/// and paged backends can never disagree about which tensors run fused —
/// the byte-identity contract between them depends on that.
pub(crate) fn is_fused_linear(name: &str, shape: &[usize]) -> bool {
    shape.len() == 2 && !name.starts_with("embeddings.")
}

/// Decoded code/cid planes of one paged shard — what the fused kernel
/// actually consumes.
struct Planes {
    codes: Vec<i8>,
    cid: Vec<u8>,
}

/// One cached decode. The entry holds a [`Weak`] to the shard allocation
/// it was decoded from: if the residency manager evicted (and a later
/// fault re-read) the shard, the pointer identity changes and the stale
/// planes are re-decoded — the cache can never serve planes for bytes that
/// left residency.
struct PlaneEntry {
    shard: Weak<ShardData>,
    planes: Arc<Planes>,
}

/// Fix for the paged hot path re-unpacking planes on every matmul: decoded
/// planes keyed by shard name + allocation identity, so repeated matmuls
/// (and repeated requests) against a still-resident shard reuse one decode.
///
/// The cache's lifetime policy **is** the residency manager's: entries
/// whose shard allocation has been dropped (dead `Weak`) are swept on
/// every miss, so decoded planes exist only for resident shards — no
/// second eviction policy to mis-tune, and no cyclic-LRU thrash when the
/// execution order is longer than a fixed cap. Memory therefore tracks the
/// residency budget scaled by the unpack ratio (≈ 2 bytes/element decoded
/// vs ~0.5 packed at INT2+cid), the same ratio the fully-resident backend
/// pays for *all* linears up front. Decode/reuse counts surface in serving
/// [`crate::coordinator::Metrics`] via `plane_stats`.
///
/// The cache is per-executor, not per-`PagedModel`: replicas share packed
/// shard bytes (one residency manager) but decode independently — decoded
/// planes are working state, not model state.
struct PlaneCache {
    map: Mutex<HashMap<String, PlaneEntry>>,
    decodes: AtomicUsize,
    reuses: AtomicUsize,
}

impl PlaneCache {
    fn new() -> PlaneCache {
        PlaneCache {
            map: Mutex::new(HashMap::new()),
            decodes: AtomicUsize::new(0),
            reuses: AtomicUsize::new(0),
        }
    }

    /// Planes for `name` as currently materialized in `shard`: reuse the
    /// cached decode when the shard allocation is unchanged, else decode
    /// (outside the lock — workers decoding different layers don't
    /// serialize) and cache. A racing decode of the same shard keeps the
    /// first inserted entry.
    fn get(&self, name: &str, shard: &Arc<ShardData>, q: &QTensor) -> Result<Arc<Planes>> {
        {
            let map = self.map.lock().unwrap();
            if let Some(e) = map.get(name) {
                if e.shard.upgrade().is_some_and(|s| Arc::ptr_eq(&s, shard)) {
                    self.reuses.fetch_add(1, Ordering::Relaxed);
                    crate::trace::instant(
                        crate::trace::Category::Shard,
                        "plane-reuse",
                        e.planes.codes.len() as u64,
                        0,
                    );
                    return Ok(Arc::clone(&e.planes));
                }
            }
        }
        let (codes, cid) = q.fused_planes()?;
        let planes = Arc::new(Planes { codes, cid });
        self.decodes.fetch_add(1, Ordering::Relaxed);
        crate::trace::instant(
            crate::trace::Category::Shard,
            "plane-decode",
            planes.codes.len() as u64,
            planes.cid.len() as u64,
        );
        let mut map = self.map.lock().unwrap();
        if let Some(e) = map.get(name) {
            // another worker decoded the same shard while we did — keep one
            if e.shard.upgrade().is_some_and(|s| Arc::ptr_eq(&s, shard)) {
                return Ok(Arc::clone(&e.planes));
            }
        }
        // drop planes of evicted shards (their Weak is dead) — the sweep
        // that keeps decoded bytes proportional to *resident* shards
        map.retain(|_, e| e.shard.strong_count() > 0);
        map.insert(
            name.to_string(),
            PlaneEntry { shard: Arc::downgrade(shard), planes: Arc::clone(&planes) },
        );
        Ok(planes)
    }

    /// `(decodes, reuses)` so far.
    fn stats(&self) -> (usize, usize) {
        (self.decodes.load(Ordering::Relaxed), self.reuses.load(Ordering::Relaxed))
    }
}

/// Where the quantized linear weights live during execution.
enum Linears {
    /// All fused linears resident in their unpacked deployment form.
    Resident(BTreeMap<String, QLinear>),
    /// Packed shards paged in on demand under a byte budget
    /// ([`crate::shardstore`]). The packed [`QTensor`] is the resident
    /// form; the code/cid planes decode through the [`PlaneCache`], so
    /// repeated matmuls against a still-resident shard pay one decode.
    Paged { model: PagedModel, planes: PlaneCache },
}

/// BERT-Tiny with quantized linear weights executed fused; embeddings and
/// the non-quantizable parameters (LayerNorm, position) stay FP32.
pub struct QuantizedBert {
    pub cfg: BertConfig,
    /// FP32 params: LN, position embedding, biases (biases are tiny; the
    /// dequantized form is used directly), token embedding (dequantized once
    /// — it is a *lookup*, not a matmul, so fused dequant buys nothing).
    fp32: ParamStore,
    /// quantized linears by parameter name — resident or paged
    linears: Linears,
    /// Per-executor kernel-engine override. `None` (the default) uses the
    /// process-wide [`crate::parallel::kernel_kind`], preserving the
    /// `ServeConfig.parallel` routing; `Some(KernelKind::Int8)` switches the
    /// fused linears to the integer datapath.
    kernel: Option<KernelKind>,
    /// Calibrated per-tensor activation params ([`ActQuantizePass`]
    /// artifact), deployed at layer boundaries on the Int8 engine. Without
    /// them the integer path quantizes each activation tensor dynamically.
    ///
    /// [`ActQuantizePass`]: crate::quant::ActQuantizePass
    act_params: Option<ActQuantParams>,
    /// Route Int8 matmuls through the scalar reference twin
    /// ([`kernels::split_matmul_int8_reference`]) — the end-to-end
    /// bit-equality oracle, settable only from in-module tests.
    int8_reference: bool,
    /// OCS-style duplicate-and-halve escape hatch on the activation path:
    /// columns whose max |activation| exceeds `ratio ×` the mean column max
    /// are split before integer quantization. `None` = off (the default).
    act_ocs_ratio: Option<f32>,
    /// Numeric-health recorder ([`crate::qhealth`]). `None` (the default)
    /// keeps the forward path untouched: every observation site guards on
    /// [`crate::qhealth::enabled`] (one relaxed atomic load) and then on
    /// this `Option` — no locks, no allocations, logits bit-identical.
    qhealth: Option<Arc<crate::qhealth::Recorder>>,
}

/// Per-forward execution mode: which kernel override drives the fused
/// linears, and whether numeric-health observation sites fire. The shadow
/// path re-runs a request with `observe: false` (so fidelity probes don't
/// double-count drift) and `kernel: None` (the f32 reference engine).
#[derive(Debug, Clone, Copy)]
struct ExecMode {
    kernel: Option<KernelKind>,
    observe: bool,
}

impl QuantizedBert {
    /// Build from the original store + a [`QuantizedModel`] (SplitQuant or
    /// baseline). Rank-2 quantized weights execute fused; everything else is
    /// dequantized into the FP32 store once.
    pub fn new(cfg: BertConfig, store: &ParamStore, qm: &QuantizedModel) -> Result<Self> {
        // O(1) share: only the slots rewritten below are copy-on-written
        let mut fp32 = store.share();
        let mut qlinears = BTreeMap::new();
        for (name, q) in &qm.tensors {
            if is_fused_linear(name, q.shape()) {
                qlinears.insert(name.clone(), QLinear::new(q.clone())?);
                // zero the fp32 copy so accidental use is loud in tests
                fp32.set(name, Tensor::zeros(q.shape()))?;
            } else {
                fp32.set(name, q.dequantize())?;
            }
        }
        Ok(QuantizedBert {
            cfg,
            fp32,
            linears: Linears::Resident(qlinears),
            kernel: None,
            act_params: None,
            int8_reference: false,
            act_ocs_ratio: None,
            qhealth: None,
        })
    }

    /// Build from a paged shard store ([`crate::shardstore::PagedModel`]):
    /// the pinned set (FP32 remainder + embeddings) materializes into the
    /// FP32 store via [`ParamStore::push_shared`] — every replica built
    /// from a `paged.clone()` aliases the same allocations (FP32 shards
    /// come from the residency cache; pinned quantized shards are
    /// dequantized once per `PagedModel`, not per replica) — while the
    /// fused linears stay on disk until [`QuantizedBert::forward`] faults
    /// them in. Pagable weights get **no** FP32 slot at all: the store
    /// never allocates the dense model this subsystem exists to avoid, and
    /// an accidental FP32 lookup of a pagable weight fails loudly as a
    /// missing parameter. Every parameter the config requires must exist
    /// in the shard file — a config/file mismatch is an error here, not
    /// silent zero logits later.
    pub fn from_paged(cfg: BertConfig, paged: PagedModel) -> Result<Self> {
        let mut fp32 = ParamStore::zeros(&[]);
        for (name, shape) in cfg.param_order() {
            if paged.is_pagable(&name) {
                continue;
            }
            // errors on shards missing from the file (fail fast on a
            // config/file mismatch)
            let t = paged.pinned_fp32(&name)?;
            if t.shape() != shape.as_slice() {
                return Err(crate::error::Error::Model(format!(
                    "shard {name:?}: shape {:?} does not match the model \
                     config's {shape:?}",
                    t.shape()
                )));
            }
            fp32.push_shared(name, t);
        }
        Ok(QuantizedBert {
            cfg,
            fp32,
            linears: Linears::Paged { model: paged, planes: PlaneCache::new() },
            kernel: None,
            act_params: None,
            int8_reference: false,
            act_ocs_ratio: None,
            qhealth: None,
        })
    }

    /// Override the kernel engine for this executor's fused linears (e.g.
    /// [`KernelKind::Int8`] for integer-only inference). Without the `simd`
    /// feature both `Simd` and `Int8` degrade to `Scalar` — logits stay
    /// valid, only the datapath changes.
    pub fn set_kernel(&mut self, kind: KernelKind) {
        self.kernel = Some(kind);
    }

    /// Deploy calibrated activation parameters (an
    /// [`crate::quant::ActQuantizePass`] artifact) at the layer boundaries:
    /// on the Int8 engine each fused linear whose input corresponds to an
    /// activation site quantizes with the calibrated scale/zero-point
    /// instead of a per-call min–max scan. Inputs without a site (the
    /// attention context) stay dynamically quantized.
    pub fn set_act_params(&mut self, params: ActQuantParams) {
        self.act_params = Some(params);
    }

    /// Enable the OCS-style duplicate-and-halve escape hatch on the
    /// activation path: before integer quantization, columns whose max
    /// |activation| exceeds `ratio ×` the mean column max are halved and
    /// duplicated (exact in f32), tightening the per-tensor activation
    /// scale. Expanded matmuls fall back to dynamic ranges — a range
    /// calibrated on unexpanded activations would give the win back.
    pub fn set_act_ocs_ratio(&mut self, ratio: f32) {
        self.act_ocs_ratio = Some(ratio);
    }

    /// Install (or fetch) this executor's numeric-health recorder
    /// ([`crate::qhealth::Recorder`]) and return a handle to it. Recording
    /// additionally requires the process-wide [`crate::qhealth::enabled`]
    /// switch — installing a recorder alone changes nothing on the forward
    /// path beyond the `Option` guard.
    pub fn enable_qhealth(&mut self) -> Arc<crate::qhealth::Recorder> {
        self.qhealth.get_or_insert_with(Arc::default).clone()
    }

    /// Snapshot of this executor's numeric-health state, when a recorder
    /// is installed.
    pub fn qhealth_snapshot(&self) -> Option<crate::qhealth::QHealthSnapshot> {
        self.qhealth.as_ref().map(|r| r.snapshot())
    }

    /// Calibrated per-tensor params for activation site `site`, when
    /// deployed. Chunk slot 0 carries the per-tensor value (the
    /// `ActQuantizePass` artifact stores `[p, p, p]`).
    fn act_for(&self, site: usize) -> Option<&QParams> {
        self.act_params.as_ref().and_then(|a| a.per_site.get(site)).map(|s| &s[0])
    }

    /// One fused quantized-weight matmul under `mode`'s engine selection —
    /// the single dispatch point both backends (resident and paged) route
    /// through, so engine behavior can never differ between them. `name`
    /// keys the dispatch-prologue health telemetry (cluster occupancy, OCS
    /// hatch activity) per layer; the micro-kernels themselves are never
    /// touched.
    #[allow(clippy::too_many_arguments)]
    fn fused_matmul(
        &self,
        name: &str,
        x: &Tensor,
        wshape: &[usize],
        codes: &[i8],
        cid: &[u8],
        params: &[QParams],
        act: Option<&QParams>,
        mode: ExecMode,
    ) -> Tensor {
        if mode.observe && crate::qhealth::enabled() {
            if let Some(rec) = &self.qhealth {
                // dispatch prologue: per-tensor layouts have no cid plane
                // and therefore no occupancy story to tell
                if !cid.is_empty() {
                    rec.record_dispatch(name, kernels::cluster_occupancy(cid));
                }
            }
        }
        let Some(kind) = mode.kernel else {
            // no override: the process-wide engine (`ServeConfig.parallel`)
            return kernels::split_matmul(x, wshape, codes, cid, params);
        };
        if kind.effective() != KernelKind::Int8 {
            return kernels::split_matmul_with(x, wshape, codes, cid, params, kind);
        }
        if let Some(ratio) = self.act_ocs_ratio {
            let outliers = kernels::act_outlier_columns(x, ratio);
            if mode.observe && crate::qhealth::enabled() {
                if let Some(rec) = &self.qhealth {
                    rec.record_ocs(name, x.shape()[1] as u64, outliers.len() as u64);
                }
            }
            if !outliers.is_empty() {
                let (xe, we, ce, ie) =
                    kernels::ocs_expand_acts(x, wshape, codes, cid, &outliers);
                return if self.int8_reference {
                    kernels::split_matmul_int8_reference(&xe, &we, &ce, &ie, params, None)
                } else {
                    kernels::split_matmul_int8(&xe, &we, &ce, &ie, params, None)
                };
            }
        }
        if self.int8_reference {
            kernels::split_matmul_int8_reference(x, wshape, codes, cid, params, act)
        } else {
            kernels::split_matmul_int8(x, wshape, codes, cid, params, act)
        }
    }

    /// Plain FP32 matmul under `mode`'s engine selection. `Int8` has no
    /// integer form for f32×f32 operands — it rides the f32 engines on
    /// this path ([`ops::matmul_with`] maps it to the f32x8 family).
    fn plain_matmul(&self, x: &Tensor, w: &Tensor, mode: ExecMode) -> Tensor {
        match mode.kernel {
            Some(kind) => ops::matmul_with(x, w, kind),
            None => ops::matmul(x, w),
        }
    }

    /// `Err` only on the paged backend: a shard fault can fail on IO or an
    /// unsupported layout — surfaced as a `classify` error, never a panic
    /// in a serving worker. `act` is the calibrated activation-range param
    /// for this linear's *input* site (Int8 engine only; `None` = dynamic).
    fn linear(
        &self,
        name: &str,
        x: &Tensor,
        act: Option<&QParams>,
        mode: ExecMode,
    ) -> Result<Tensor> {
        let mut y = match &self.linears {
            Linears::Resident(qlinears) => match qlinears.get(name) {
                Some(ql) => self.fused_matmul(
                    name,
                    x,
                    ql.q.shape(),
                    &ql.codes,
                    &ql.cid,
                    ql.q.params(),
                    act,
                    mode,
                ),
                None => self.plain_matmul(x, self.fp32.get(name)?, mode),
            },
            Linears::Paged { model, planes } => {
                if model.is_pagable(name) {
                    let shard = model.fetch_quant(name)?;
                    let q = shard.as_quant().expect("fetch_quant returned quantized");
                    // shard shapes come from disk: a stale/corrupt file must
                    // surface as the documented Err, not a kernel panic
                    if x.shape()[1] != q.shape()[0] {
                        return Err(crate::error::Error::Quant(format!(
                            "paged shard {name:?}: activations {:?} do not \
                             match weights {:?}",
                            x.shape(),
                            q.shape()
                        )));
                    }
                    // same planes, same dispatch as the resident arm —
                    // logits stay byte-identical to the resident path; the
                    // plane cache only skips re-decoding them
                    let p = planes.get(name, &shard, q)?;
                    self.fused_matmul(name, x, q.shape(), &p.codes, &p.cid, q.params(), act, mode)
                } else {
                    self.plain_matmul(x, self.fp32.get(name)?, mode)
                }
            }
        };
        let bias_name = name.strip_suffix(".weight").map(|p| format!("{p}.bias"));
        if let Some(bn) = bias_name {
            if let Ok(b) = self.fp32.get(&bn) {
                ops::add_bias(&mut y, b);
            }
        }
        Ok(y)
    }

    /// Activation-drift observation at a calibrated act site: observed
    /// min/max and clip count of `x` against the site's deployed dequant
    /// range, at layer-boundary granularity. Guarded by the relaxed
    /// [`crate::qhealth::enabled`] load and the recorder `Option` — with
    /// either off this is a no-op with zero allocations.
    fn observe_act(&self, mode: ExecMode, site: usize, x: &Tensor) {
        if !mode.observe || !crate::qhealth::enabled() {
            return;
        }
        let Some(rec) = &self.qhealth else { return };
        let calibrated = self.act_for(site).map(|p| p.dequant_range());
        rec.record_act(site, calibrated, x.data());
    }

    /// logits f32[B, C] — same math as `BertModel::forward`, quantized hot
    /// path. `Err` only on the paged backend (failed shard fault).
    pub fn forward(&self, ids: &IntTensor, mask: &Tensor) -> Result<Tensor> {
        self.forward_impl(ids, mask, ExecMode { kernel: self.kernel, observe: true })
    }

    /// The forward body, parameterized by [`ExecMode`] so the shadow path
    /// can re-run a request on the f32 reference engine without mutating
    /// the executor (and without re-observing drift).
    fn forward_impl(&self, ids: &IntTensor, mask: &Tensor, mode: ExecMode) -> Result<Tensor> {
        let cfg = &self.cfg;
        let p = &self.fp32;
        let (b, l) = (ids.shape()[0], ids.shape()[1]);
        let h = cfg.hidden;
        let a = cfg.heads;
        let hd = cfg.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();

        let mut x = ops::embedding(p.get("embeddings.token")?, ids);
        {
            let pos = p.get("embeddings.position")?;
            let xd = x.data_mut();
            for bi in 0..b {
                for li in 0..l {
                    let row = &mut xd[(bi * l + li) * h..(bi * l + li + 1) * h];
                    for (v, &pv) in row.iter_mut().zip(pos.row(li)) {
                        *v += pv;
                    }
                }
            }
        }
        let mut x = ops::layer_norm(
            &x.reshape(&[b * l, h]).unwrap(),
            p.get("embeddings.ln.gamma")?,
            p.get("embeddings.ln.beta")?,
            cfg.ln_eps,
        );

        // Calibrated activation sites (`BertConfig::act_sites` order):
        // site 0 = embeddings.out, then per layer i the triple
        // {3i+1: attn.out, 3i+2: ffn.gelu, 3i+3: ffn.out}, then
        // 3L+1 = pooler.out. Each fused linear's *input* maps to the site
        // recorded at that tensor: q/k/v of layer i read the previous
        // layer's output (site 3i; embeddings.out for i = 0), ffn.in reads
        // attn.out, ffn.out reads ffn.gelu, the pooler reads the final
        // layer output and the classifier reads pooler.out. The attention
        // context feeding attn.out.weight has no calibration site — it
        // quantizes dynamically on the Int8 engine.
        for i in 0..cfg.layers {
            let pre = format!("encoder.{i}");
            let xin = self.act_for(3 * i);
            // one drift observation per site per dispatch: q/k/v share the
            // same input tensor and site, so record it once
            self.observe_act(mode, 3 * i, &x);
            let q = self.linear(&format!("{pre}.attn.q.weight"), &x, xin, mode)?;
            let k = self.linear(&format!("{pre}.attn.k.weight"), &x, xin, mode)?;
            let v = self.linear(&format!("{pre}.attn.v.weight"), &x, xin, mode)?;

            let ctx = super::bert::attention_ctx(&q, &k, &v, mask, b, l, h, a, hd, scale);
            let attn = self.linear(&format!("{pre}.attn.out.weight"), &ctx, None, mode)?;
            let mut res = x.clone();
            res.add_assign(&attn);
            x = ops::layer_norm(
                &res,
                p.get(&format!("{pre}.attn.ln.gamma"))?,
                p.get(&format!("{pre}.attn.ln.beta"))?,
                cfg.ln_eps,
            );

            self.observe_act(mode, 3 * i + 1, &x);
            let mid = ops::gelu(&self.linear(
                &format!("{pre}.ffn.in.weight"),
                &x,
                self.act_for(3 * i + 1),
                mode,
            )?);
            self.observe_act(mode, 3 * i + 2, &mid);
            let mut ff = self.linear(
                &format!("{pre}.ffn.out.weight"),
                &mid,
                self.act_for(3 * i + 2),
                mode,
            )?;
            ff.add_assign(&x);
            x = ops::layer_norm(
                &ff,
                p.get(&format!("{pre}.ffn.ln.gamma"))?,
                p.get(&format!("{pre}.ffn.ln.beta"))?,
                cfg.ln_eps,
            );
        }

        let mut cls = Tensor::zeros(&[b, h]);
        for bi in 0..b {
            cls.data_mut()[bi * h..(bi + 1) * h]
                .copy_from_slice(&x.data()[bi * l * h..bi * l * h + h]);
        }
        self.observe_act(mode, 3 * cfg.layers, &cls);
        let pooled = ops::tanh(&self.linear(
            "pooler.weight",
            &cls,
            self.act_for(3 * cfg.layers),
            mode,
        )?);
        self.observe_act(mode, 3 * cfg.layers + 1, &pooled);
        self.linear("classifier.weight", &pooled, self.act_for(3 * cfg.layers + 1), mode)
    }

    pub fn predict(&self, ids: &IntTensor, mask: &Tensor) -> Result<Vec<i32>> {
        Ok(argmax_rows(&self.forward(ids, mask)?))
    }

    /// Shadow-fidelity probe ([`crate::qhealth`]): re-run `ids`/`mask`
    /// through this executor's configured engine *and* through the f32
    /// reference engine (no kernel override — the same fused-dequant math
    /// the accuracy protocol trusts), then record per-row logit-KL and
    /// top-1 agreement. Neither pass fires drift observations, so shadow
    /// probes never double-count the health signals of the request they
    /// mirror. A no-op unless a recorder is installed and
    /// [`crate::qhealth::enabled`] is on; the server calls this *after*
    /// responding to the hot batch.
    pub fn shadow_sample(&self, ids: &IntTensor, mask: &Tensor) -> Result<()> {
        if !crate::qhealth::enabled() {
            return Ok(());
        }
        let Some(rec) = &self.qhealth else { return Ok(()) };
        let served =
            self.forward_impl(ids, mask, ExecMode { kernel: self.kernel, observe: false })?;
        let reference = self.forward_impl(ids, mask, ExecMode { kernel: None, observe: false })?;
        let (rows, classes) = served.as_2d();
        let s_top = argmax_rows(&served);
        let r_top = argmax_rows(&reference);
        for r in 0..rows {
            let s = &served.data()[r * classes..(r + 1) * classes];
            let f = &reference.data()[r * classes..(r + 1) * classes];
            rec.record_shadow(crate::qhealth::logit_kl(f, s), s_top[r] == r_top[r]);
        }
        Ok(())
    }

    /// Resident weight bytes of the quantized linears (deployment memory).
    /// For the paged backend this is the *current* pagable working set —
    /// bounded by the residency budget, not the model size.
    pub fn quantized_resident_bytes(&self) -> usize {
        match &self.linears {
            Linears::Resident(qlinears) => {
                qlinears.values().map(|q| q.resident_bytes()).sum()
            }
            Linears::Paged { model, .. } => model.counters().resident_bytes,
        }
    }

    /// The FP32 bytes those linears would occupy.
    pub fn fp32_equivalent_bytes(&self) -> usize {
        match &self.linears {
            Linears::Resident(qlinears) => {
                qlinears.values().map(|q| q.shape().iter().product::<usize>() * 4).sum()
            }
            Linears::Paged { model, .. } => model.fp32_equivalent_bytes(),
        }
    }

    pub fn num_quantized_linears(&self) -> usize {
        match &self.linears {
            Linears::Resident(qlinears) => qlinears.len(),
            Linears::Paged { model, .. } => model.pagable().len(),
        }
    }

    /// The FP32 parameter view (sharing checks / introspection — the
    /// quantized-executor analogue of `RustExecutor::params`).
    pub fn fp32_params(&self) -> &ParamStore {
        &self.fp32
    }

    /// The paged backend, when this executor serves from shards.
    pub fn paged(&self) -> Option<&PagedModel> {
        match &self.linears {
            Linears::Resident(_) => None,
            Linears::Paged { model, .. } => Some(model),
        }
    }

    /// `(plane_decodes, plane_reuses)` of the paged plane cache — how often
    /// a matmul had to unpack the code/cid planes vs reusing a cached
    /// decode. `(0, 0)` on the resident backend (planes are decoded once at
    /// construction there). Folded into serving
    /// [`crate::coordinator::Metrics`].
    pub fn plane_stats(&self) -> (usize, usize) {
        match &self.linears {
            Linears::Resident(_) => (0, 0),
            Linears::Paged { planes, .. } => planes.stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::observer;
    use crate::splitquant::{default_quantizable, quantize_store, SplitQuantConfig};
    use crate::util::rng::Rng;

    fn setup(bits: u8) -> (BertConfig, ParamStore, QuantizedModel) {
        let cfg = BertConfig {
            vocab_size: 128,
            hidden: 16,
            layers: 2,
            heads: 2,
            ffn: 32,
            max_len: 10,
            num_classes: 4,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, qm) = quantize_store(&store, &q, &SplitQuantConfig::new(bits)).unwrap();
        (cfg, store, qm)
    }

    fn batch(cfg: &BertConfig, b: usize, seed: u64) -> (IntTensor, Tensor) {
        let mut rng = Rng::new(seed);
        let l = cfg.max_len;
        let ids: Vec<i32> = (0..b * l).map(|_| rng.below(cfg.vocab_size) as i32).collect();
        (IntTensor::new(&[b, l], ids).unwrap(), Tensor::full(&[b, l], 1.0))
    }

    #[test]
    fn fused_matches_dequantized_execution() {
        // QuantizedBert (fused dequant) == BertModel on the dequantized store
        for bits in [2u8, 4, 8] {
            let (cfg, store, qm) = setup(bits);
            let quantizable = default_quantizable(&store);
            let (eval_store, _) =
                quantize_store(&store, &quantizable, &SplitQuantConfig::new(bits)).unwrap();
            let reference =
                super::super::bert::BertModel::new(cfg.clone(), eval_store).unwrap();
            let fused = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
            let (ids, mask) = batch(&cfg, 3, 1);
            let a = reference.forward(&ids, &mask);
            let b = fused.forward(&ids, &mask).unwrap();
            let gap = a.max_abs_diff(&b);
            assert!(gap < 1e-3, "bits {bits}: fused gap {gap}");
        }
    }

    #[test]
    fn memory_accounting() {
        let (cfg, store, qm) = setup(2);
        let q = QuantizedBert::new(cfg, &store, &qm).unwrap();
        assert!(q.num_quantized_linears() >= 10);
        let resident = q.quantized_resident_bytes();
        let fp32 = q.fp32_equivalent_bytes();
        // unpacked codes (1B) + cid (1B) + meta ≈ half of FP32 (4B); the
        // packed on-disk form is 4x smaller still
        assert!(
            (resident as f64) < fp32 as f64 * 0.6,
            "resident {resident} vs fp32 {fp32}"
        );
        let Linears::Resident(qlinears) = &q.linears else {
            panic!("QuantizedBert::new builds the resident backend")
        };
        for ql in qlinears.values() {
            assert!(ql.packed_bytes() < ql.resident_bytes());
        }
    }

    #[test]
    fn paged_backend_is_byte_identical_to_resident() {
        use crate::shardstore::{PagedConfig, PagedModel};
        let (cfg, store, qm) = setup(2);
        let resident = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        let pm = crate::quant::PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_qbert_paged.sqsh");
        pm.save_sharded(&path).unwrap();

        let probe = PagedModel::open(&path, PagedConfig::default()).unwrap();
        let budget = probe.pagable_bytes() / 2;
        assert!(budget >= probe.max_shard_bytes());
        drop(probe);
        let paged = PagedModel::open(
            &path,
            PagedConfig { residency_budget_bytes: budget, prefetch_depth: 1, ..Default::default() },
        )
        .unwrap();
        let qbert = QuantizedBert::from_paged(cfg.clone(), paged.clone()).unwrap();
        std::fs::remove_file(&path).ok();

        let (ids, mask) = batch(&cfg, 3, 1);
        let a = resident.forward(&ids, &mask).unwrap();
        let b = qbert.forward(&ids, &mask).unwrap();
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "paged logits diverged");
        }
        let c = paged.counters();
        assert!(c.shard_faults > 0, "paged forward never faulted");
        assert!(c.shard_evictions > 0, "half-budget forward never evicted");
        assert!(c.resident_bytes <= budget);
        assert!(c.peak_resident_bytes <= budget);
    }

    #[test]
    fn paged_plane_cache_reuses_decodes_within_residency() {
        use crate::shardstore::{PagedConfig, PagedModel};
        // 1 layer ⇒ 8 pagable linears (attn q/k/v/out, ffn in/out, pooler,
        // classifier); with an unbounded budget every shard stays resident,
        // so the second forward must reuse every decode instead of
        // re-unpacking the planes per matmul
        let cfg = BertConfig {
            vocab_size: 128,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 10,
            num_classes: 4,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(12);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let q = default_quantizable(&store);
        let (_, qm) = quantize_store(&store, &q, &SplitQuantConfig::new(2)).unwrap();
        let pm = crate::quant::PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_qbert_planes.sqsh");
        pm.save_sharded(&path).unwrap();
        let paged = PagedModel::open(&path, PagedConfig::default()).unwrap();
        let qbert = QuantizedBert::from_paged(cfg.clone(), paged).unwrap();
        std::fs::remove_file(&path).ok();
        let nlin = qbert.num_quantized_linears();
        assert_eq!(nlin, 8);

        let (ids, mask) = batch(&cfg, 2, 4);
        let a = qbert.forward(&ids, &mask).unwrap();
        let (d1, r1) = qbert.plane_stats();
        assert_eq!(d1, nlin, "first forward decodes each linear once");
        assert_eq!(r1, 0);
        let b = qbert.forward(&ids, &mask).unwrap();
        let (d2, r2) = qbert.plane_stats();
        assert_eq!(d2, nlin, "still-resident shards must not re-decode");
        assert_eq!(r2, nlin, "second forward reuses every decode");
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "cached planes changed the logits");
        }
    }

    #[test]
    fn int8_engine_matches_scalar_reference_bit_for_bit_end_to_end() {
        // acceptance: KernelKind::Int8 end-to-end logits bit-identical to
        // the scalar i8 reference path (exact i32 accumulation, one shared
        // float epilogue). Without the `simd` feature both executors
        // degrade to the same f32 engine and equality holds trivially.
        let (cfg, store, qm) = setup(4);
        let (ids, mask) = batch(&cfg, 3, 2);

        let mut main = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        main.set_kernel(KernelKind::Int8);
        let mut oracle = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        oracle.set_kernel(KernelKind::Int8);
        oracle.int8_reference = true; // in-module: route the scalar twin

        let a = main.forward(&ids, &mask).unwrap();
        let b = oracle.forward(&ids, &mask).unwrap();
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "int8 logits diverged from reference");
        }

        // different datapath, same model: the gap to the f32 engines is
        // activation-quantization error only
        let f32e = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        let c = f32e.forward(&ids, &mask).unwrap();
        let gap = a.max_abs_diff(&c);
        assert!(gap < 1.0, "int8 vs f32 gap {gap}");
        if cfg!(feature = "simd") {
            assert!(gap > 0.0, "int8 engine never engaged");
        }
    }

    #[test]
    fn paged_int8_is_bit_identical_to_resident_int8() {
        use crate::shardstore::{PagedConfig, PagedModel};
        let (cfg, store, qm) = setup(2);
        let mut resident = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        resident.set_kernel(KernelKind::Int8);
        let pm = crate::quant::PackedModel::assemble(&store, &qm);
        let path = std::env::temp_dir().join("sq_qbert_paged_int8.sqsh");
        pm.save_sharded(&path).unwrap();
        let paged = PagedModel::open(&path, PagedConfig::default()).unwrap();
        let mut qbert = QuantizedBert::from_paged(cfg.clone(), paged).unwrap();
        qbert.set_kernel(KernelKind::Int8);
        std::fs::remove_file(&path).ok();
        let (ids, mask) = batch(&cfg, 3, 1);
        let a = resident.forward(&ids, &mask).unwrap();
        let b = qbert.forward(&ids, &mask).unwrap();
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "paged int8 logits diverged");
        }
    }

    #[test]
    fn calibrated_act_params_are_consulted_and_stay_bit_exact() {
        let (cfg, store, qm) = setup(8);
        let (ids, mask) = batch(&cfg, 2, 3);
        let n_sites = cfg.act_sites().len();
        let p = crate::quant::QParams::from_range(-4.0, 4.0, 8);
        let act = ActQuantParams { per_site: vec![[p, p, p]; n_sites], bits: 8 };

        let mut dynamic = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        dynamic.set_kernel(KernelKind::Int8);
        let mut calibrated = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        calibrated.set_kernel(KernelKind::Int8);
        calibrated.set_act_params(act.clone());
        let mut oracle = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        oracle.set_kernel(KernelKind::Int8);
        oracle.set_act_params(act);
        oracle.int8_reference = true;

        let d = dynamic.forward(&ids, &mask).unwrap();
        let c = calibrated.forward(&ids, &mask).unwrap();
        let o = oracle.forward(&ids, &mask).unwrap();
        for (x, y) in c.data().iter().zip(o.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "calibrated int8 diverged from reference");
        }
        if cfg!(feature = "simd") {
            // calibrated scale ≠ per-call min–max scale ⇒ different logits:
            // proof the deployed params are actually consulted
            assert_ne!(c.data(), d.data(), "calibrated ranges never engaged");
        }
    }

    #[test]
    fn act_ocs_hatch_keeps_the_int8_oracle_contract() {
        let (cfg, store, qm) = setup(4);
        let (ids, mask) = batch(&cfg, 2, 6);
        let mut main = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        main.set_kernel(KernelKind::Int8);
        main.set_act_ocs_ratio(3.0);
        let mut oracle = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        oracle.set_kernel(KernelKind::Int8);
        oracle.set_act_ocs_ratio(3.0);
        oracle.int8_reference = true;
        let a = main.forward(&ids, &mask).unwrap();
        let b = oracle.forward(&ids, &mask).unwrap();
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "ocs int8 diverged from reference");
        }
    }

    #[test]
    fn per_tensor_layout_also_supported() {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 1,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 3,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(3);
        let store = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let quantizable = default_quantizable(&store);
        let (eval, tensors) = crate::baselines::quantize_store_baseline(
            &store,
            &quantizable,
            &crate::quant::QConfig::baseline(4),
        )
        .unwrap();
        let qm = QuantizedModel { tensors, fp32_names: vec![], bits: 4 };
        let fused = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        let reference = super::super::bert::BertModel::new(cfg.clone(), eval).unwrap();
        let (ids, mask) = batch(&cfg, 2, 5);
        let gap =
            reference.forward(&ids, &mask).max_abs_diff(&fused.forward(&ids, &mask).unwrap());
        assert!(gap < 1e-3, "{gap}");
    }

    /// An Int8 executor with calibrated act params and the OCS hatch — the
    /// configuration that exercises every qhealth recording site.
    fn int8_setup(
        cfg: &BertConfig,
        store: &ParamStore,
        qm: &QuantizedModel,
        range: (f32, f32),
    ) -> QuantizedBert {
        let p = crate::quant::QParams::from_range(range.0, range.1, 8);
        let act = ActQuantParams { per_site: vec![[p, p, p]; cfg.act_sites().len()], bits: 8 };
        let mut m = QuantizedBert::new(cfg.clone(), store, qm).unwrap();
        m.set_kernel(KernelKind::Int8);
        m.set_act_params(act);
        m.set_act_ocs_ratio(3.0);
        m
    }

    #[test]
    fn qhealth_observation_keeps_logits_bit_identical() {
        // acceptance: with monitoring fully on, served logits are
        // bit-identical to the unmonitored executor; with the master
        // switch back off, an installed recorder stays silent
        let _g = crate::qhealth::test_guard();
        let (cfg, store, qm) = setup(4);
        let (ids, mask) = batch(&cfg, 3, 4);
        let plain = int8_setup(&cfg, &store, &qm, (-2.0, 2.0));
        let mut observed = int8_setup(&cfg, &store, &qm, (-2.0, 2.0));
        observed.enable_qhealth();

        crate::qhealth::set_enabled(true);
        let b = observed.forward(&ids, &mask).unwrap();
        observed.shadow_sample(&ids, &mask).unwrap();
        crate::qhealth::set_enabled(false);
        let a = plain.forward(&ids, &mask).unwrap();
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "qhealth observation changed logits");
        }

        let snap = observed.qhealth_snapshot().unwrap();
        assert!(!snap.sites.is_empty(), "no drift sites recorded");
        assert!(!snap.layers.is_empty(), "no dispatch telemetry recorded");
        assert_eq!(snap.shadow.samples, 3, "one shadow row per batch row");
        assert!(plain.qhealth_snapshot().is_none());

        // switch off again: the same executor records nothing further
        let before = observed.qhealth_snapshot().unwrap();
        observed.forward(&ids, &mask).unwrap();
        observed.shadow_sample(&ids, &mask).unwrap();
        let after = observed.qhealth_snapshot().unwrap();
        assert_eq!(before, after, "disabled switch must silence recording");
    }

    #[test]
    fn qhealth_reconciles_exactly_with_offline_recomputation() {
        let _g = crate::qhealth::test_guard();
        let (cfg, store, qm) = setup(4);
        // deliberately tight range: real clipping traffic to reconcile
        let range = (-1.5, 1.5);
        let mut m = int8_setup(&cfg, &store, &qm, range);
        let rec = m.enable_qhealth();
        const RUNS: u64 = 3;
        const B: usize = 2;
        crate::qhealth::set_enabled(true);
        for r in 0..RUNS {
            let (ids, mask) = batch(&cfg, B, 10 + r);
            m.forward(&ids, &mask).unwrap();
            m.shadow_sample(&ids, &mask).unwrap();
        }
        crate::qhealth::set_enabled(false);
        let snap = rec.snapshot();

        // (a) cluster occupancy: ground truth recomputed from the resident
        // cid planes — each fused linear dispatches once per forward
        let Linears::Resident(qlinears) = &m.linears else {
            panic!("QuantizedBert::new builds the resident backend")
        };
        let split: Vec<&String> =
            qlinears.iter().filter(|(_, ql)| !ql.cid.is_empty()).map(|(n, _)| n).collect();
        assert_eq!(
            snap.layers.iter().map(|l| &l.layer).collect::<Vec<_>>(),
            split,
            "every split-layout linear appears exactly once, sorted"
        );
        for ls in &snap.layers {
            let one = kernels::cluster_occupancy(&qlinears[&ls.layer].cid);
            assert_eq!(ls.dispatches, RUNS, "{}", ls.layer);
            for c in 0..3 {
                assert_eq!(ls.occupancy[c], one[c] * RUNS, "{} cluster {c}", ls.layer);
            }
            assert_eq!(ls.ocs_calls, RUNS, "{}: one OCS evaluation per dispatch", ls.layer);
        }

        // (b) site-0 drift: offline recompute of embeddings.out (token +
        // position embedding, LayerNorm) and its clip stats vs the range
        let p32 = m.fp32_params();
        let (mut want_clipped, mut want_lo, mut want_hi) = (0u64, f32::INFINITY, f32::NEG_INFINITY);
        let deployed = crate::quant::QParams::from_range(range.0, range.1, 8).dequant_range();
        for r in 0..RUNS {
            let (ids, _) = batch(&cfg, B, 10 + r);
            let (h, l) = (cfg.hidden, cfg.max_len);
            let mut x = ops::embedding(p32.get("embeddings.token").unwrap(), &ids);
            let pos = p32.get("embeddings.position").unwrap();
            let xd = x.data_mut();
            for bi in 0..B {
                for li in 0..l {
                    let row = &mut xd[(bi * l + li) * h..(bi * l + li + 1) * h];
                    for (v, &pv) in row.iter_mut().zip(pos.row(li)) {
                        *v += pv;
                    }
                }
            }
            let x0 = ops::layer_norm(
                &x.reshape(&[B * l, h]).unwrap(),
                p32.get("embeddings.ln.gamma").unwrap(),
                p32.get("embeddings.ln.beta").unwrap(),
                cfg.ln_eps,
            );
            let (c, lo, hi) = observer::clip_stats(x0.data(), deployed.0, deployed.1);
            want_clipped += c;
            want_lo = want_lo.min(lo);
            want_hi = want_hi.max(hi);
        }
        let site0 = &snap.sites[0];
        assert_eq!(site0.site, 0);
        assert_eq!(site0.batches, RUNS);
        assert_eq!(site0.values, (RUNS as usize * B * cfg.max_len * cfg.hidden) as u64);
        assert_eq!(site0.clipped, want_clipped, "clip count must reconcile exactly");
        assert!(want_clipped > 0, "range too loose to exercise clipping");
        let (got_lo, got_hi) = site0.observed.unwrap();
        assert_eq!(got_lo.to_bits(), want_lo.to_bits());
        assert_eq!(got_hi.to_bits(), want_hi.to_bits());
        assert_eq!(site0.calibrated, Some(deployed));

        // (c) shadow fidelity: offline recompute of served-vs-reference
        // logit KL and top-1 agreement over the same seeded batches
        let served_m = int8_setup(&cfg, &store, &qm, range);
        let reference_m = QuantizedBert::new(cfg.clone(), &store, &qm).unwrap();
        let (mut want_samples, mut want_agree, mut want_max_un) = (0u64, 0u64, 0u64);
        for r in 0..RUNS {
            let (ids, mask) = batch(&cfg, B, 10 + r);
            let s = served_m.forward(&ids, &mask).unwrap();
            let f = reference_m.forward(&ids, &mask).unwrap();
            let (st, ft) = (argmax_rows(&s), argmax_rows(&f));
            let classes = cfg.num_classes;
            for row in 0..B {
                let kl = crate::qhealth::logit_kl(
                    &f.data()[row * classes..(row + 1) * classes],
                    &s.data()[row * classes..(row + 1) * classes],
                );
                want_samples += 1;
                want_agree += u64::from(st[row] == ft[row]);
                want_max_un = want_max_un.max((kl.max(0.0) * 1e6).round() as u64);
            }
        }
        assert_eq!(snap.shadow.samples, want_samples);
        assert_eq!(snap.shadow.top1_agree, want_agree);
        assert_eq!(snap.shadow.kl_max_micro_nats, want_max_un);

        // (d) replay determinism: a fresh executor over the same seeded
        // run renders a byte-identical health report
        let mut replay = int8_setup(&cfg, &store, &qm, range);
        let rec2 = replay.enable_qhealth();
        crate::qhealth::set_enabled(true);
        for r in 0..RUNS {
            let (ids, mask) = batch(&cfg, B, 10 + r);
            replay.forward(&ids, &mask).unwrap();
            replay.shadow_sample(&ids, &mask).unwrap();
        }
        crate::qhealth::set_enabled(false);
        assert_eq!(
            crate::qhealth::render(&snap),
            crate::qhealth::render(&rec2.snapshot()),
            "replay must render byte-identically"
        );
    }
}
