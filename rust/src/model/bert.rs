//! Pure-Rust BERT-Tiny executor.
//!
//! Runs the exact computation of the L2 JAX graph (`python/compile/model.py`)
//! on a [`ParamStore`] — used for the quantization accuracy sweeps (Table 1)
//! where thousands of forward passes over perturbed weights are needed and
//! round-tripping through PJRT per configuration would dominate.
//!
//! Activation hooks fire at the same sites as the AOT act-quant graph
//! (`BertConfig::act_sites`), enabling calibration (range recording) and
//! activation fake-quant (per-tensor or SplitQuant chunked) without new
//! graphs.

use crate::error::Result;
use crate::tensor::ops;
use crate::tensor::{IntTensor, Tensor};

use super::config::BertConfig;
use super::params::ParamStore;

/// Observer/mutator invoked at each activation site: `(site_index, tensor)`.
/// The tensor is `(B·L, width)` or `(B, width)` 2-D; the hook may mutate it
/// in place (fake-quant) or just record statistics (calibration).
pub type ActHook<'a> = &'a mut dyn FnMut(usize, &mut Tensor);

/// BERT-Tiny with owned parameters.
#[derive(Debug, Clone)]
pub struct BertModel {
    pub cfg: BertConfig,
    pub params: ParamStore,
}

impl BertModel {
    pub fn new(cfg: BertConfig, params: ParamStore) -> Result<Self> {
        params.check_order(&cfg.param_order())?;
        Ok(BertModel { cfg, params })
    }

    /// logits f32[B, C].
    pub fn forward(&self, ids: &IntTensor, mask: &Tensor) -> Tensor {
        self.forward_hooked(ids, mask, None)
    }

    /// Forward with an optional activation hook.
    pub fn forward_hooked(
        &self,
        ids: &IntTensor,
        mask: &Tensor,
        mut hook: Option<ActHook<'_>>,
    ) -> Tensor {
        let cfg = &self.cfg;
        let p = &self.params;
        let (b, l) = (ids.shape()[0], ids.shape()[1]);
        let h = cfg.hidden;

        // embeddings + position + LN
        let mut x = ops::embedding(p.get("embeddings.token").unwrap(), ids);
        {
            let pos = p.get("embeddings.position").unwrap();
            let xd = x.data_mut();
            for bi in 0..b {
                for li in 0..l {
                    let row = &mut xd[(bi * l + li) * h..(bi * l + li + 1) * h];
                    for (v, &pv) in row.iter_mut().zip(pos.row(li)) {
                        *v += pv;
                    }
                }
            }
        }
        let mut x = ops::layer_norm(
            &x.reshape(&[b * l, h]).unwrap(),
            p.get("embeddings.ln.gamma").unwrap(),
            p.get("embeddings.ln.beta").unwrap(),
            cfg.ln_eps,
        );
        let mut site = 0usize;
        fire(&mut hook, &mut site, &mut x);

        for i in 0..cfg.layers {
            let pre = format!("encoder.{i}");
            // ---- attention
            let attn = self.attention(&pre, &x, mask, b, l);
            let mut res = x.clone();
            res.add_assign(&attn);
            x = ops::layer_norm(
                &res,
                p.get(&format!("{pre}.attn.ln.gamma")).unwrap(),
                p.get(&format!("{pre}.attn.ln.beta")).unwrap(),
                cfg.ln_eps,
            );
            fire(&mut hook, &mut site, &mut x);

            // ---- FFN
            let mut mid = ops::matmul(&x, p.get(&format!("{pre}.ffn.in.weight")).unwrap());
            ops::add_bias(&mut mid, p.get(&format!("{pre}.ffn.in.bias")).unwrap());
            let mut mid = ops::gelu(&mid);
            fire(&mut hook, &mut site, &mut mid);
            let mut ff = ops::matmul(&mid, p.get(&format!("{pre}.ffn.out.weight")).unwrap());
            ops::add_bias(&mut ff, p.get(&format!("{pre}.ffn.out.bias")).unwrap());
            ff.add_assign(&x);
            x = ops::layer_norm(
                &ff,
                p.get(&format!("{pre}.ffn.ln.gamma")).unwrap(),
                p.get(&format!("{pre}.ffn.ln.beta")).unwrap(),
                cfg.ln_eps,
            );
            fire(&mut hook, &mut site, &mut x);
        }

        // ---- pooler on the [CLS] token (sequence position 0)
        let mut cls = Tensor::zeros(&[b, h]);
        for bi in 0..b {
            cls.data_mut()[bi * h..(bi + 1) * h]
                .copy_from_slice(&x.data()[bi * l * h..bi * l * h + h]);
        }
        let mut pooled = ops::matmul(&cls, p.get("pooler.weight").unwrap());
        ops::add_bias(&mut pooled, p.get("pooler.bias").unwrap());
        let mut pooled = ops::tanh(&pooled);
        fire(&mut hook, &mut site, &mut pooled);

        let mut logits = ops::matmul(&pooled, p.get("classifier.weight").unwrap());
        ops::add_bias(&mut logits, p.get("classifier.bias").unwrap());
        logits
    }

    /// Multi-head self-attention block (pre-LN residual handled by caller).
    /// `x` is (B·L, H); returns (B·L, H).
    fn attention(&self, pre: &str, x: &Tensor, mask: &Tensor, b: usize, l: usize) -> Tensor {
        let cfg = &self.cfg;
        let p = &self.params;
        let h = cfg.hidden;
        let a = cfg.heads;
        let hd = cfg.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();

        let proj = |name: &str| -> Tensor {
            let mut y = ops::matmul(x, p.get(&format!("{pre}.attn.{name}.weight")).unwrap());
            ops::add_bias(&mut y, p.get(&format!("{pre}.attn.{name}.bias")).unwrap());
            y // (B·L, H)
        };
        let q = proj("q");
        let k = proj("k");
        let v = proj("v");

        let ctx = attention_ctx(&q, &k, &v, mask, b, l, h, a, hd, scale);

        let mut out = ops::matmul(&ctx, p.get(&format!("{pre}.attn.out.weight")).unwrap());
        ops::add_bias(&mut out, p.get(&format!("{pre}.attn.out.bias")).unwrap());
        out
    }

    /// Predicted class per example.
    pub fn predict(&self, ids: &IntTensor, mask: &Tensor) -> Vec<i32> {
        argmax_rows(&self.forward(ids, mask))
    }
}

/// Multi-head attention context `softmax(q·kᵀ·scale + mask)·v`, gathered
/// back into `(B·L, H)`. Shared by [`BertModel`] and
/// [`super::qbert::QuantizedBert`]. Each batch writes a disjoint `l·h`
/// chunk of the output, so batches fan out over the
/// [`crate::parallel`] worker pool when the problem is large enough;
/// per-task gather scratch is worker-local, and the inner matmuls run
/// serially inside pool tasks (nested-dispatch guard).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_ctx(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &Tensor,
    b: usize,
    l: usize,
    h: usize,
    heads: usize,
    hd: usize,
    scale: f32,
) -> Tensor {
    let mut ctx = Tensor::zeros(&[b * l, h]);
    let flops = 4 * b * heads * l * l * hd;
    if b >= 2 && crate::parallel::should_parallelize(flops) {
        let pool = crate::parallel::global();
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (bi, chunk) in ctx.data_mut().chunks_mut(l * h).enumerate() {
            tasks.push(Box::new(move || {
                // worker-local gather scratch (tasks run concurrently)
                let mut scratch = AttnScratch::new(l, hd);
                attn_one_batch(q, k, v, mask, chunk, bi, h, heads, &mut scratch, scale);
            }));
        }
        pool.scope(tasks);
    } else {
        // one scratch reused across the whole batch (the b1 latency path
        // must not pay per-element allocations)
        let mut scratch = AttnScratch::new(l, hd);
        for (bi, chunk) in ctx.data_mut().chunks_mut(l * h).enumerate() {
            attn_one_batch(q, k, v, mask, chunk, bi, h, heads, &mut scratch, scale);
        }
    }
    ctx
}

/// Per-head gather buffers for [`attn_one_batch`]: the head slice of q/v
/// packed contiguously and k transposed, reused across heads and batches.
struct AttnScratch {
    qb: Tensor,
    kt: Tensor,
    vb: Tensor,
}

impl AttnScratch {
    fn new(l: usize, hd: usize) -> AttnScratch {
        AttnScratch {
            qb: Tensor::zeros(&[l, hd]),
            kt: Tensor::zeros(&[hd, l]),
            vb: Tensor::zeros(&[l, hd]),
        }
    }
}

/// Attention for a single batch element into its `(l × h)` context chunk.
/// Per head: gather the head slice contiguously and reuse the blocked
/// matmul for scores (q·kᵀ) and context (softmax·v) — ~2× faster than the
/// element-wise loops this replaced (§Perf).
#[allow(clippy::too_many_arguments)]
fn attn_one_batch(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &Tensor,
    ctx_chunk: &mut [f32],
    bi: usize,
    h: usize,
    heads: usize,
    scratch: &mut AttnScratch,
    scale: f32,
) {
    let l = scratch.qb.shape()[0];
    let hd = scratch.qb.shape()[1];
    let AttnScratch { qb, kt, vb } = scratch;
    let mrow = &mask.data()[bi * l..(bi + 1) * l];
    for ai in 0..heads {
        let off = ai * hd;
        for i in 0..l {
            let src = (bi * l + i) * h + off;
            qb.data_mut()[i * hd..(i + 1) * hd].copy_from_slice(&q.data()[src..src + hd]);
            vb.data_mut()[i * hd..(i + 1) * hd].copy_from_slice(&v.data()[src..src + hd]);
            for d in 0..hd {
                kt.data_mut()[d * l + i] = k.data()[src + d];
            }
        }
        let mut scores = ops::matmul(&qb, &kt); // (L, L)
        {
            let sd = scores.data_mut();
            for i in 0..l {
                for j in 0..l {
                    sd[i * l + j] = sd[i * l + j] * scale + (1.0 - mrow[j]) * ops::NEG_INF;
                }
            }
        }
        let sm = ops::softmax_last(&scores);
        let ctx_head = ops::matmul(&sm, &vb); // (L, hd)
        for i in 0..l {
            let dst = i * h + off;
            ctx_chunk[dst..dst + hd].copy_from_slice(&ctx_head.data()[i * hd..(i + 1) * hd]);
        }
    }
}

/// Row-wise argmax of a logits matrix.
pub fn argmax_rows(logits: &Tensor) -> Vec<i32> {
    let (r, c) = logits.as_2d();
    (0..r)
        .map(|i| {
            let row = &logits.data()[i * c..(i + 1) * c];
            let mut best = 0usize;
            for j in 1..c {
                if row[j] > row[best] {
                    best = j;
                }
            }
            best as i32
        })
        .collect()
}

fn fire(hook: &mut Option<ActHook<'_>>, site: &mut usize, x: &mut Tensor) {
    if let Some(h) = hook.as_mut() {
        h(*site, x);
    }
    *site += 1;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny() -> (BertConfig, BertModel) {
        let cfg = BertConfig {
            vocab_size: 64,
            hidden: 16,
            layers: 2,
            heads: 2,
            ffn: 32,
            max_len: 12,
            num_classes: 4,
            ln_eps: 1e-12,
        };
        let mut rng = Rng::new(0);
        let params = ParamStore::init_bert(&cfg.param_order(), &mut rng);
        let m = BertModel::new(cfg.clone(), params).unwrap();
        (cfg, m)
    }

    fn batch(cfg: &BertConfig, b: usize, seed: u64) -> (IntTensor, Tensor) {
        let mut rng = Rng::new(seed);
        let l = cfg.max_len;
        let mut ids = vec![0i32; b * l];
        let mut mask = vec![0.0f32; b * l];
        for bi in 0..b {
            let len = rng.range(3, l + 1);
            for li in 0..l {
                ids[bi * l + li] =
                    if li < len { rng.below(cfg.vocab_size) as i32 } else { 0 };
                mask[bi * l + li] = if li < len { 1.0 } else { 0.0 };
            }
        }
        (
            IntTensor::new(&[b, l], ids).unwrap(),
            Tensor::new(&[b, l], mask).unwrap(),
        )
    }

    #[test]
    fn forward_shape_and_finite() {
        let (cfg, m) = tiny();
        let (ids, mask) = batch(&cfg, 5, 1);
        let logits = m.forward(&ids, &mask);
        assert_eq!(logits.shape(), &[5, 4]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn padding_tokens_do_not_change_logits() {
        let (cfg, m) = tiny();
        let (ids, mask) = batch(&cfg, 4, 2);
        let l1 = m.forward(&ids, &mask);
        let mut noisy = ids.clone();
        for i in 0..noisy.numel() {
            if mask.data()[i] == 0.0 {
                noisy.data_mut()[i] = (noisy.data()[i] + 17) % cfg.vocab_size as i32;
            }
        }
        let l2 = m.forward(&noisy, &mask);
        assert!(l1.max_abs_diff(&l2) < 1e-4, "diff {}", l1.max_abs_diff(&l2));
    }

    #[test]
    fn batch_invariance() {
        // example 0 evaluated alone == evaluated inside a batch
        let (cfg, m) = tiny();
        let (ids, mask) = batch(&cfg, 3, 3);
        let all = m.forward(&ids, &mask);
        let one_ids = IntTensor::new(&[1, cfg.max_len], ids.data()[..cfg.max_len].to_vec()).unwrap();
        let one_mask = Tensor::new(&[1, cfg.max_len], mask.data()[..cfg.max_len].to_vec()).unwrap();
        let single = m.forward(&one_ids, &one_mask);
        for j in 0..cfg.num_classes {
            assert!((all.at2(0, j) - single.at2(0, j)).abs() < 1e-4);
        }
    }

    #[test]
    fn hooks_fire_at_all_sites_in_order() {
        let (cfg, m) = tiny();
        let (ids, mask) = batch(&cfg, 2, 4);
        let mut seen = Vec::new();
        let mut widths = Vec::new();
        let mut hook = |site: usize, t: &mut Tensor| {
            seen.push(site);
            widths.push(*t.shape().last().unwrap());
        };
        m.forward_hooked(&ids, &mask, Some(&mut hook));
        let sites = cfg.act_sites();
        assert_eq!(seen, (0..sites.len()).collect::<Vec<_>>());
        let expect: Vec<usize> = sites.iter().map(|(_, w)| *w).collect();
        assert_eq!(widths, expect);
    }

    #[test]
    fn hook_mutation_changes_output() {
        let (cfg, m) = tiny();
        let (ids, mask) = batch(&cfg, 2, 5);
        let base = m.forward(&ids, &mask);
        let mut hook = |_site: usize, t: &mut Tensor| {
            for v in t.data_mut() {
                *v = 0.0;
            }
        };
        let zeroed = m.forward_hooked(&ids, &mask, Some(&mut hook));
        assert!(base.max_abs_diff(&zeroed) > 1e-3);
        let _ = cfg;
    }

    #[test]
    fn argmax() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }
}
