//! CSR sparse linear algebra for split layers.
//!
//! Paper §6: SplitQuant triples the layer count but every new layer is ~⅔
//! structural zeros, so "model size, memory usage and inference speed may be
//! optimized if SplitQuant is used together with sparse DNN inference engines
//! such as SparseDNN". This module is that engine for our stack: CSR storage
//! + row-major sparse·dense matmul. Bench `sparse_hotpath` measures how much
//! of the 3× dense overhead it recovers.

use crate::tensor::Tensor;

/// Compressed-sparse-row matrix (CSR over the weight's `in` dimension).
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// row_ptr[r]..row_ptr[r+1] indexes into col_idx / values.
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from a dense matrix, dropping exact zeros.
    pub fn from_dense(t: &Tensor) -> CsrMatrix {
        assert_eq!(t.shape().len(), 2);
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for c in 0..cols {
                let v = t.at2(r, c);
                if v != 0.0 {
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        CsrMatrix { rows, cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Density in [0, 1].
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Storage bytes (values + column indices + row pointers).
    pub fn byte_size(&self) -> usize {
        self.values.len() * 4 + self.col_idx.len() * 4 + self.row_ptr.len() * 4
    }

    /// `y = x (m×rows) @ self (rows×cols)`: dense·sparse with the sparse
    /// matrix acting on the right — the split-linear hot path. Accumulates
    /// into `out` (must be m×cols, zero-initialized by the caller), so three
    /// split branches can share one output buffer.
    pub fn matmul_acc(&self, x: &Tensor, out: &mut Tensor) {
        let (m, k) = (x.shape()[0], x.shape()[1]);
        assert_eq!(k, self.rows, "x width {k} vs csr rows {}", self.rows);
        assert_eq!(out.shape(), &[m, self.cols]);
        let n = self.cols;
        let xd = x.data();
        let od = out.data_mut();
        for i in 0..m {
            let xrow = &xd[i * k..(i + 1) * k];
            let orow = &mut od[i * n..(i + 1) * n];
            for r in 0..self.rows {
                let xv = xrow[r];
                if xv == 0.0 {
                    continue;
                }
                let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
                for idx in lo..hi {
                    orow[self.col_idx[idx] as usize] += xv * self.values[idx];
                }
            }
        }
    }

    /// Convenience: `x @ self` into a fresh tensor.
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        let m = x.shape()[0];
        let mut out = Tensor::zeros(&[m, self.cols]);
        self.matmul_acc(x, &mut out);
        out
    }
}

/// A split linear layer executed sparsely: k CSR branches + dense bias.
#[derive(Debug, Clone)]
pub struct SparseSplitLinear {
    pub branches: Vec<CsrMatrix>,
    pub bias: Option<Tensor>,
}

impl SparseSplitLinear {
    /// Build from zero-padded dense branches (as produced by the SplitQuant
    /// materialization).
    pub fn from_dense_branches(branches: &[Tensor], bias: Option<Tensor>) -> Self {
        SparseSplitLinear {
            branches: branches.iter().map(CsrMatrix::from_dense).collect(),
            bias,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let m = x.shape()[0];
        let n = self.branches[0].cols;
        let mut out = Tensor::zeros(&[m, n]);
        for b in &self.branches {
            b.matmul_acc(x, &mut out);
        }
        if let Some(bias) = &self.bias {
            crate::tensor::ops::add_bias(&mut out, bias);
        }
        out
    }

    /// Total nonzeros across branches (== original weight nnz).
    pub fn nnz(&self) -> usize {
        self.branches.iter().map(|b| b.nnz()).sum()
    }

    pub fn byte_size(&self) -> usize {
        self.branches.iter().map(|b| b.byte_size()).sum::<usize>()
            + self.bias.as_ref().map_or(0, |b| b.byte_size())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn csr_roundtrip_matmul() {
        let mut rng = Rng::new(0);
        let mut w = Tensor::randn(&[16, 12], 0.0, 1.0, &mut rng);
        // sparsify ~2/3
        for v in w.data_mut() {
            if rng.chance(0.66) {
                *v = 0.0;
            }
        }
        let x = Tensor::randn(&[5, 16], 0.0, 1.0, &mut rng);
        let dense = ops::matmul(&x, &w);
        let sparse = CsrMatrix::from_dense(&w).matmul(&x);
        assert!(dense.max_abs_diff(&sparse) < 1e-5);
    }

    #[test]
    fn density_and_bytes() {
        let mut w = Tensor::zeros(&[10, 10]);
        w.data_mut()[3] = 1.0;
        w.data_mut()[57] = -2.0;
        let c = CsrMatrix::from_dense(&w);
        assert_eq!(c.nnz(), 2);
        assert!((c.density() - 0.02).abs() < 1e-12);
        assert_eq!(c.byte_size(), 2 * 4 + 2 * 4 + 11 * 4);
    }

    #[test]
    fn split_branches_equal_dense_sum() {
        check("sparse split == dense linear", 20, |rng| {
            let (kin, kout, m) = (rng.range(2, 24), rng.range(1, 20), rng.range(1, 8));
            let w = Tensor::randn(&[kin, kout], 0.0, 1.0, rng);
            // random 3-way element partition
            let mut branches = vec![Tensor::zeros(&[kin, kout]); 3];
            for i in 0..kin * kout {
                let c = rng.below(3);
                branches[c].data_mut()[i] = w.data()[i];
            }
            let bias = Tensor::randn(&[kout], 0.0, 1.0, rng);
            let sp = SparseSplitLinear::from_dense_branches(&branches, Some(bias.clone()));
            let x = Tensor::randn(&[m, kin], 0.0, 1.0, rng);
            let mut dense = ops::matmul(&x, &w);
            ops::add_bias(&mut dense, &bias);
            let got = sp.forward(&x);
            assert!(dense.max_abs_diff(&got) < 1e-4);
            assert_eq!(sp.nnz(), w.data().iter().filter(|&&v| v != 0.0).count());
        });
    }

    #[test]
    fn sparse_storage_smaller_than_three_dense() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[128, 128], 0.0, 1.0, &mut rng);
        let mut branches = vec![Tensor::zeros(&[128, 128]); 3];
        for i in 0..128 * 128 {
            branches[rng.below(3)].data_mut()[i] = w.data()[i];
        }
        let sp = SparseSplitLinear::from_dense_branches(&branches, None);
        // u32 col indices double the per-nnz cost vs pure values, so CSR is
        // ~1.5× smaller than 3× dense here (u16 indices would reach ~2×; see
        // DESIGN.md §Perf)
        let three_dense = 3 * w.byte_size();
        assert!(
            sp.byte_size() < three_dense * 3 / 4,
            "sparse {} vs 3x dense {three_dense}",
            sp.byte_size()
        );
    }
}
