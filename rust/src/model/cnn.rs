//! Pure-Rust CNN executor (eval mode) — the conv-splitting / BN-folding
//! substrate for Figure 3 and §4.1.

use crate::error::Result;
use crate::tensor::ops;
use crate::tensor::{IntTensor, Tensor};

use super::config::CnnConfig;
use super::params::ParamStore;

/// conv1→BN→ReLU→pool→conv2→BN→ReLU→pool→FC, matching `python/compile/cnn.py`.
#[derive(Debug, Clone)]
pub struct CnnModel {
    pub cfg: CnnConfig,
    pub params: ParamStore,
}

impl CnnModel {
    pub fn new(cfg: CnnConfig, params: ParamStore) -> Result<Self> {
        params.check_order(&cfg.param_order())?;
        Ok(CnnModel { cfg, params })
    }

    /// logits f32[B, C] from images f32[B, 1, 16, 16] (eval-mode BN).
    pub fn forward(&self, images: &Tensor) -> Tensor {
        let p = &self.params;
        let eps = self.cfg.bn_eps;
        let g = |n: &str| p.get(n).unwrap();

        let x = ops::conv2d_same(images, g("conv1.weight"), g("conv1.bias"));
        let x = ops::batch_norm_eval(&x, g("bn1.gamma"), g("bn1.beta"), g("bn1.mean"), g("bn1.var"), eps);
        let x = ops::relu(&x);
        let x = ops::maxpool2(&x);
        let x = ops::conv2d_same(&x, g("conv2.weight"), g("conv2.bias"));
        let x = ops::batch_norm_eval(&x, g("bn2.gamma"), g("bn2.beta"), g("bn2.mean"), g("bn2.var"), eps);
        let x = ops::relu(&x);
        let x = ops::maxpool2(&x);
        let b = x.shape()[0];
        let flat = x.reshape(&[b, self.cfg.flat()]).unwrap();
        let mut logits = ops::matmul(&flat, g("fc.weight"));
        ops::add_bias(&mut logits, g("fc.bias"));
        logits
    }

    pub fn predict(&self, images: &Tensor) -> Vec<i32> {
        super::bert::argmax_rows(&self.forward(images))
    }

    /// Accuracy over a labelled set.
    pub fn accuracy(&self, images: &Tensor, labels: &IntTensor) -> f64 {
        let preds = self.predict(images);
        let hits = preds.iter().zip(labels.data()).filter(|(p, l)| p == l).count();
        hits as f64 / labels.numel() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn forward_shape() {
        let cfg = CnnConfig::default();
        let mut rng = Rng::new(0);
        let m = CnnModel::new(cfg.clone(), ParamStore::init_cnn(&cfg.param_order(), &mut rng))
            .unwrap();
        let imgs = Tensor::randn(&[3, 1, 16, 16], 0.0, 1.0, &mut rng);
        let logits = m.forward(&imgs);
        assert_eq!(logits.shape(), &[3, 4]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn accuracy_on_random_params_is_chancey() {
        let cfg = CnnConfig::default();
        let mut rng = Rng::new(1);
        let m = CnnModel::new(cfg.clone(), ParamStore::init_cnn(&cfg.param_order(), &mut rng))
            .unwrap();
        let ds = crate::data::images::generate(200, &mut rng);
        let acc = m.accuracy(&ds.images, &ds.labels);
        assert!(acc < 0.6, "untrained model too good: {acc}");
    }
}
