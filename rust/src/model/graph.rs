//! Generic sequential layer IR — the structural form SplitQuant operates on
//! (Figure 1): linear/conv layers can be *split* into three parallel branches
//! whose outputs are added; activation layers into three chunks whose outputs
//! are concatenated.
//!
//! The BERT executor ([`super::bert`]) uses fused quantized parameters for
//! speed; this IR exists to demonstrate and test the paper's *literal* layer
//! structure (zero-padded branches, add/concat recombination) and to measure
//! its overhead (bench `equivalence`, bench `model_size`).

use crate::tensor::ops;
use crate::tensor::Tensor;

/// Elementwise activation kinds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ActKind {
    Relu,
    Gelu,
    Tanh,
}

impl ActKind {
    pub fn apply(&self, x: &Tensor) -> Tensor {
        match self {
            ActKind::Relu => ops::relu(x),
            ActKind::Gelu => ops::gelu(x),
            ActKind::Tanh => ops::tanh(x),
        }
    }
}

/// One branch of a split linear layer (zero-injected weight/bias).
#[derive(Debug, Clone)]
pub struct LinearPart {
    pub weight: Tensor,
    pub bias: Option<Tensor>,
}

/// A layer node.
#[derive(Debug, Clone)]
pub enum Layer {
    /// Dense affine: `y = x·W + b`, W is (in, out).
    Linear { weight: Tensor, bias: Option<Tensor> },
    /// SplitQuant linear (Figure 2): parallel branches, outputs **added**.
    SplitLinear { parts: Vec<LinearPart> },
    /// Elementwise activation.
    Activation(ActKind),
    /// SplitQuant activation (Figure 1 D): input chunked on the last dim,
    /// activation applied per chunk, results **concatenated**.
    SplitActivation { kind: ActKind, spans: Vec<(usize, usize)> },
}

impl Layer {
    pub fn forward(&self, x: &Tensor) -> Tensor {
        match self {
            Layer::Linear { weight, bias } => {
                let mut y = ops::matmul(x, weight);
                if let Some(b) = bias {
                    ops::add_bias(&mut y, b);
                }
                y
            }
            Layer::SplitLinear { parts } => {
                assert!(!parts.is_empty());
                let mut acc: Option<Tensor> = None;
                for part in parts {
                    let mut y = ops::matmul(x, &part.weight);
                    if let Some(b) = &part.bias {
                        ops::add_bias(&mut y, b);
                    }
                    match &mut acc {
                        None => acc = Some(y),
                        Some(a) => a.add_assign(&y),
                    }
                }
                acc.unwrap()
            }
            Layer::Activation(k) => k.apply(x),
            Layer::SplitActivation { kind, spans } => {
                let (r, c) = x.as_2d();
                assert_eq!(spans.last().map(|s| s.1), Some(c), "spans must cover width");
                let mut out = vec![0.0f32; r * c];
                for &(lo, hi) in spans {
                    // gather chunk, activate, scatter back (the concat)
                    let w = hi - lo;
                    let mut chunk = vec![0.0f32; r * w];
                    for i in 0..r {
                        chunk[i * w..(i + 1) * w]
                            .copy_from_slice(&x.data()[i * c + lo..i * c + hi]);
                    }
                    let act = kind.apply(&Tensor::new(&[r, w], chunk).unwrap());
                    for i in 0..r {
                        out[i * c + lo..i * c + hi]
                            .copy_from_slice(&act.data()[i * w..(i + 1) * w]);
                    }
                }
                Tensor::new(x.shape(), out).unwrap()
            }
        }
    }

    /// Parameter count (for overhead accounting).
    pub fn numel(&self) -> usize {
        match self {
            Layer::Linear { weight, bias } => {
                weight.numel() + bias.as_ref().map_or(0, |b| b.numel())
            }
            Layer::SplitLinear { parts } => parts
                .iter()
                .map(|p| p.weight.numel() + p.bias.as_ref().map_or(0, |b| b.numel()))
                .sum(),
            _ => 0,
        }
    }
}

/// A simple feed-forward stack.
#[derive(Debug, Clone, Default)]
pub struct Sequential {
    pub layers: Vec<Layer>,
}

impl Sequential {
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut cur = x.clone();
        for l in &self.layers {
            cur = l.forward(&cur);
        }
        cur
    }

    pub fn numel(&self) -> usize {
        self.layers.iter().map(|l| l.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::chunk_spans;
    use crate::util::rng::Rng;

    #[test]
    fn linear_forward() {
        let w = Tensor::new(&[2, 2], vec![1., 0., 0., 2.]).unwrap();
        let b = Tensor::new(&[2], vec![10., 20.]).unwrap();
        let l = Layer::Linear { weight: w, bias: Some(b) };
        let y = l.forward(&Tensor::new(&[1, 2], vec![3., 4.]).unwrap());
        assert_eq!(y.data(), &[13., 28.]);
    }

    #[test]
    fn split_linear_sums_branches() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(&[4, 3], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[3], 0.0, 1.0, &mut rng);
        // split by even/odd element parity into two zero-padded branches
        let mut w0 = w.clone();
        let mut w1 = w.clone();
        for (i, (a, c)) in w0.data_mut().iter_mut().zip(w1.data_mut()).enumerate() {
            if i % 2 == 0 {
                *c = 0.0;
            } else {
                *a = 0.0;
            }
        }
        let mut b0 = b.clone();
        let mut b1 = b.clone();
        b0.data_mut()[1] = 0.0;
        b1.data_mut()[0] = 0.0;
        b1.data_mut()[2] = 0.0;
        let orig = Layer::Linear { weight: w, bias: Some(b) };
        let split = Layer::SplitLinear {
            parts: vec![
                LinearPart { weight: w0, bias: Some(b0) },
                LinearPart { weight: w1, bias: Some(b1) },
            ],
        };
        let x = Tensor::randn(&[5, 4], 0.0, 1.0, &mut rng);
        let diff = orig.forward(&x).max_abs_diff(&split.forward(&x));
        assert!(diff < 1e-5, "{diff}");
    }

    #[test]
    fn split_activation_equals_plain_activation() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[6, 10], 0.0, 2.0, &mut rng);
        for kind in [ActKind::Relu, ActKind::Gelu, ActKind::Tanh] {
            let plain = Layer::Activation(kind).forward(&x);
            let split =
                Layer::SplitActivation { kind, spans: chunk_spans(10, 3) }.forward(&x);
            assert!(plain.max_abs_diff(&split) < 1e-6);
        }
    }

    #[test]
    fn sequential_chains() {
        let mut rng = Rng::new(2);
        let net = Sequential {
            layers: vec![
                Layer::Linear {
                    weight: Tensor::randn(&[4, 8], 0.0, 1.0, &mut rng),
                    bias: None,
                },
                Layer::Activation(ActKind::Relu),
                Layer::Linear {
                    weight: Tensor::randn(&[8, 2], 0.0, 1.0, &mut rng),
                    bias: None,
                },
            ],
        };
        let y = net.forward(&Tensor::randn(&[3, 4], 0.0, 1.0, &mut rng));
        assert_eq!(y.shape(), &[3, 2]);
        assert_eq!(net.numel(), 4 * 8 + 8 * 2);
    }
}
