//! Model hyper-parameters and the flat parameter ABI.
//!
//! These mirror `python/compile/config.py` exactly; the integration tests
//! cross-check `param_order()` against `artifacts/manifest.json` so the two
//! sides can never silently drift.

use crate::error::Result;
use crate::util::json::Json;

/// BERT-Tiny configuration (Turc et al. 2019 scale: L=2, H=128, A=2).
#[derive(Debug, Clone, PartialEq)]
pub struct BertConfig {
    pub vocab_size: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub max_len: usize,
    pub num_classes: usize,
    pub ln_eps: f32,
}

impl Default for BertConfig {
    fn default() -> Self {
        BertConfig {
            vocab_size: 8192,
            hidden: 128,
            layers: 2,
            heads: 2,
            ffn: 512,
            max_len: 64,
            num_classes: 6,
            ln_eps: 1e-12,
        }
    }
}

impl BertConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Parse from the manifest's `bert_config` object.
    pub fn from_manifest(j: &Json) -> Result<Self> {
        let c = j.get("bert_config")?;
        Ok(BertConfig {
            vocab_size: c.get("vocab_size")?.as_usize()?,
            hidden: c.get("hidden")?.as_usize()?,
            layers: c.get("layers")?.as_usize()?,
            heads: c.get("heads")?.as_usize()?,
            ffn: c.get("ffn")?.as_usize()?,
            max_len: c.get("max_len")?.as_usize()?,
            num_classes: c.get("num_classes")?.as_usize()?,
            ln_eps: c.get("ln_eps")?.as_f64()? as f32,
        })
    }

    /// Deterministic flat (name, shape) parameter order — the L2⇄L3 ABI.
    pub fn param_order(&self) -> Vec<(String, Vec<usize>)> {
        let (h, f, v, l, c) =
            (self.hidden, self.ffn, self.vocab_size, self.max_len, self.num_classes);
        let mut out: Vec<(String, Vec<usize>)> = vec![
            ("embeddings.token".into(), vec![v, h]),
            ("embeddings.position".into(), vec![l, h]),
            ("embeddings.ln.gamma".into(), vec![h]),
            ("embeddings.ln.beta".into(), vec![h]),
        ];
        for i in 0..self.layers {
            let p = format!("encoder.{i}");
            for (n, s) in [
                ("attn.q.weight", vec![h, h]),
                ("attn.q.bias", vec![h]),
                ("attn.k.weight", vec![h, h]),
                ("attn.k.bias", vec![h]),
                ("attn.v.weight", vec![h, h]),
                ("attn.v.bias", vec![h]),
                ("attn.out.weight", vec![h, h]),
                ("attn.out.bias", vec![h]),
                ("attn.ln.gamma", vec![h]),
                ("attn.ln.beta", vec![h]),
                ("ffn.in.weight", vec![h, f]),
                ("ffn.in.bias", vec![f]),
                ("ffn.out.weight", vec![f, h]),
                ("ffn.out.bias", vec![h]),
                ("ffn.ln.gamma", vec![h]),
                ("ffn.ln.beta", vec![h]),
            ] {
                out.push((format!("{p}.{n}"), s));
            }
        }
        out.push(("pooler.weight".into(), vec![h, h]));
        out.push(("pooler.bias".into(), vec![h]));
        out.push(("classifier.weight".into(), vec![h, c]));
        out.push(("classifier.bias".into(), vec![c]));
        out
    }

    /// Activation fake-quant sites, mirroring `config.act_sites`:
    /// (name, channel width), in execution order.
    pub fn act_sites(&self) -> Vec<(String, usize)> {
        let mut sites = vec![("embeddings.out".to_string(), self.hidden)];
        for i in 0..self.layers {
            sites.push((format!("encoder.{i}.attn.out"), self.hidden));
            sites.push((format!("encoder.{i}.ffn.gelu"), self.ffn));
            sites.push((format!("encoder.{i}.ffn.out"), self.hidden));
        }
        sites.push(("pooler.out".to_string(), self.hidden));
        sites
    }
}

/// Interior split points for positional activation splitting (paper §4.2);
/// mirrors `config.chunk_bounds`.
pub fn chunk_bounds(n: usize, parts: usize) -> Vec<usize> {
    let base = n / parts;
    let rem = n % parts;
    let mut bounds = Vec::with_capacity(parts - 1);
    let mut acc = 0;
    for i in 0..parts - 1 {
        acc += base + usize::from(i < rem);
        bounds.push(acc);
    }
    bounds
}

/// Chunk (start, end) pairs for a width-`n` activation split 3 ways.
pub fn chunk_spans(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let b = chunk_bounds(n, parts);
    let mut lo = 0;
    let mut out = Vec::with_capacity(parts);
    for &hi in &b {
        out.push((lo, hi));
        lo = hi;
    }
    out.push((lo, n));
    out
}

/// Tiny CNN configuration (conv-splitting / BN-folding path).
#[derive(Debug, Clone, PartialEq)]
pub struct CnnConfig {
    pub image: usize,
    pub in_ch: usize,
    pub ch1: usize,
    pub ch2: usize,
    pub kernel: usize,
    pub num_classes: usize,
    pub bn_eps: f32,
}

impl Default for CnnConfig {
    fn default() -> Self {
        CnnConfig { image: 16, in_ch: 1, ch1: 8, ch2: 16, kernel: 3, num_classes: 4, bn_eps: 1e-5 }
    }
}

impl CnnConfig {
    pub fn flat(&self) -> usize {
        self.ch2 * (self.image / 4) * (self.image / 4)
    }

    pub fn from_manifest(j: &Json) -> Result<Self> {
        let c = j.get("cnn_config")?;
        Ok(CnnConfig {
            image: c.get("image")?.as_usize()?,
            in_ch: c.get("in_ch")?.as_usize()?,
            ch1: c.get("ch1")?.as_usize()?,
            ch2: c.get("ch2")?.as_usize()?,
            kernel: c.get("kernel")?.as_usize()?,
            num_classes: c.get("num_classes")?.as_usize()?,
            bn_eps: c.get("bn_eps")?.as_f64()? as f32,
        })
    }

    pub fn param_order(&self) -> Vec<(String, Vec<usize>)> {
        let k = self.kernel;
        vec![
            ("conv1.weight".into(), vec![self.ch1, self.in_ch, k, k]),
            ("conv1.bias".into(), vec![self.ch1]),
            ("bn1.gamma".into(), vec![self.ch1]),
            ("bn1.beta".into(), vec![self.ch1]),
            ("bn1.mean".into(), vec![self.ch1]),
            ("bn1.var".into(), vec![self.ch1]),
            ("conv2.weight".into(), vec![self.ch2, self.ch1, k, k]),
            ("conv2.bias".into(), vec![self.ch2]),
            ("bn2.gamma".into(), vec![self.ch2]),
            ("bn2.beta".into(), vec![self.ch2]),
            ("bn2.mean".into(), vec![self.ch2]),
            ("bn2.var".into(), vec![self.ch2]),
            ("fc.weight".into(), vec![self.flat(), self.num_classes]),
            ("fc.bias".into(), vec![self.num_classes]),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_param_order_size() {
        let cfg = BertConfig::default();
        let order = cfg.param_order();
        assert_eq!(order.len(), 40);
        let total: usize = order.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(total, 1_470_854); // asserted in python tests too
    }

    #[test]
    fn act_sites_count() {
        let cfg = BertConfig::default();
        assert_eq!(cfg.act_sites().len(), 3 * cfg.layers + 2);
        assert_eq!(cfg.act_sites()[0], ("embeddings.out".to_string(), 128));
    }

    #[test]
    fn chunk_bounds_match_python() {
        assert_eq!(chunk_bounds(128, 3), vec![43, 86]);
        assert_eq!(chunk_bounds(512, 3), vec![171, 342]);
        assert_eq!(chunk_bounds(3, 3), vec![1, 2]);
        for n in [3usize, 7, 16, 43, 128, 512, 513] {
            let spans = chunk_spans(n, 3);
            assert_eq!(spans.len(), 3);
            assert_eq!(spans.last().unwrap().1, n);
            let sizes: Vec<usize> = spans.iter().map(|(a, b)| b - a).collect();
            assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn cnn_flat_dim() {
        assert_eq!(CnnConfig::default().flat(), 256);
        assert_eq!(CnnConfig::default().param_order().len(), 14);
    }

    #[test]
    fn config_from_json() {
        let j = Json::parse(
            r#"{"bert_config":{"vocab_size":100,"hidden":8,"layers":1,"heads":2,
                "ffn":16,"max_len":12,"num_classes":3,"ln_eps":1e-12}}"#,
        )
        .unwrap();
        let c = BertConfig::from_manifest(&j).unwrap();
        assert_eq!(c.vocab_size, 100);
        assert_eq!(c.head_dim(), 4);
    }
}
