//! Model layer: configurations, parameter storage, the pure-Rust executors
//! (BERT-Tiny and CNN) and the generic layer-graph IR used by the SplitQuant
//! structural transforms.
//!
//! Two execution paths exist for every model:
//! * the **pure-Rust executor** here (quantization sweeps, Table 1 — no
//!   artifacts needed, fast on CPU), and
//! * the **PJRT executables** in [`crate::runtime`] (training, serving,
//!   activation-quant graphs — the AOT-compiled L2 graphs).
//!
//! Both implement the same math; `tests/integration_runtime.rs` asserts they
//! agree to float tolerance on identical parameters.

pub mod bert;
pub mod cnn;
pub mod config;
pub mod graph;
pub mod params;
pub mod qbert;
pub mod sparse;

pub use bert::BertModel;
pub use cnn::CnnModel;
pub use config::{BertConfig, CnnConfig};
pub use params::ParamStore;
pub use qbert::QuantizedBert;
