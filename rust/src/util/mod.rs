//! Small self-contained utilities (the offline sandbox has no serde_json /
//! rand / proptest, so these substrates are built in-crate).

pub mod crc32;
pub mod fastmath;
pub mod io;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;

pub use json::Json;
pub use rng::Rng;
