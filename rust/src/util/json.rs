//! Minimal JSON parser/serializer (substrate: no serde_json offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and the
//! benchmark reports: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are kept as `f64`; integer accessors validate
//! integrality.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json { at: 0, msg: format!("expected object, got {self:?}") }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json { at: 0, msg: format!("expected array, got {self:?}") }),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json { at: 0, msg: format!("expected string, got {self:?}") }),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json { at: 0, msg: format!("expected number, got {self:?}") }),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Json { at: 0, msg: format!("expected non-negative integer, got {n}") });
        }
        Ok(n as usize)
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json { at: 0, msg: format!("missing key {key:?}") })
    }

    /// `true` when the object has the key.
    pub fn has(&self, key: &str) -> bool {
        matches!(self, Json::Obj(m) if m.contains_key(key))
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from pairs (helper for report writers).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("utf8"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs unsupported (not emitted by aot.py)
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c => {
                    // collect UTF-8 continuation bytes verbatim
                    let mut buf = vec![c];
                    let extra = match c {
                        0x00..=0x7f => 0,
                        0xc0..=0xdf => 1,
                        0xe0..=0xef => 2,
                        _ => 3,
                    };
                    for _ in 0..extra {
                        let b = self.peek().ok_or_else(|| self.err("utf8"))?;
                        buf.push(b);
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&buf).map_err(|_| self.err("utf8"))?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"exe":{"f":[1,2.5,-3],"n":"x y","ok":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ☕");
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors_carry_position() {
        match Json::parse("[1, ") {
            Err(Error::Json { at, .. }) => assert!(at >= 3),
            other => panic!("expected Json error, got {other:?}"),
        }
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn integer_accessor_rejects_fractions() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
    }
}
