//! Small statistics helpers shared by observers, metrics and benches.

/// Exact percentile of a sample via sorting (linear interpolation, like
/// numpy's default). `p` in [0, 100].
pub fn percentile(values: &[f32], p: f64) -> f32 {
    assert!(!values.is_empty(), "percentile of empty slice");
    let mut v: Vec<f32> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f32], p: f64) -> f32 {
    assert!(!sorted.is_empty());
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = (rank - lo as f64) as f32;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Mean of a slice.
pub fn mean(values: &[f32]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().map(|&x| x as f64).sum::<f64>() / values.len() as f64
}

/// Population standard deviation.
pub fn std_dev(values: &[f32]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    let var = values.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / values.len() as f64;
    var.sqrt()
}

/// (min, max) of a non-empty slice, ignoring NaNs.
pub fn min_max(values: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in values {
        if v.is_nan() {
            continue;
        }
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

/// Latency histogram with microsecond resolution for the serving metrics.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: std::time::Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let rank = (q.clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
        v[rank]
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={}us p95={}us p99={}us max={}us",
            self.len(),
            self.mean_us(),
            self.quantile_us(0.50),
            self.quantile_us(0.95),
            self.quantile_us(0.99),
            self.quantile_us(1.0),
        )
    }
}

/// Sub-bucket resolution of [`LogHistogram`]: each power-of-two octave is
/// split into `2^SUB_BITS` linear sub-buckets.
const SUB_BITS: u32 = 4;
const SUB: usize = 1 << SUB_BITS;
/// Fixed bucket count: values below `SUB` get exact unit buckets; every
/// octave `[2^m, 2^(m+1))` for `m` in `SUB_BITS..64` gets `SUB` buckets.
const LOG_BUCKETS: usize = SUB + (64 - SUB_BITS as usize) * SUB;

/// Bounded-memory log-bucketed latency histogram (HDR-style).
///
/// The serving path previously pushed every sample into a growing
/// `Vec<u64>` ([`LatencyStats`]) for the life of the server; this records
/// into a *fixed* array of [`LOG_BUCKETS`] counters instead — O(buckets)
/// memory no matter how many samples arrive — while keeping the exact sum,
/// min and max so `mean_us` and the extreme quantiles stay exact.
///
/// Quantiles are answered with the midpoint of the owning bucket, whose
/// width is at most `2^-SUB_BITS` of its lower bound, so the relative
/// error is bounded by `2^-(SUB_BITS + 1)` (≤ 1/32 at the default
/// resolution). Values below `SUB` are exact. Histograms with the same
/// resolution merge losslessly ([`LogHistogram::merge`]), which the exact
/// sort-based [`LatencyStats`] cannot do without concatenating samples.
///
/// The method surface mirrors [`LatencyStats`] (`record`, `len`,
/// `quantile_us`, `mean_us`, `summary`) so the two are drop-in swappable;
/// benches and observers that want exact percentiles keep using
/// [`LatencyStats`] / [`percentile`].
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Box<[u64; LOG_BUCKETS]>,
    total: u64,
    sum_us: u64,
    min_us: u64,
    max_us: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: Box::new([0; LOG_BUCKETS]),
            total: 0,
            sum_us: 0,
            min_us: u64::MAX,
            max_us: 0,
        }
    }
}

impl LogHistogram {
    fn bucket(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let m = 63 - v.leading_zeros(); // v in [2^m, 2^(m+1)), m >= SUB_BITS
        let sub = (v >> (m - SUB_BITS)) as usize & (SUB - 1);
        SUB + (m - SUB_BITS) as usize * SUB + sub
    }

    /// Midpoint of bucket `idx` (its maximum absolute error is half the
    /// bucket width).
    fn representative(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let m = (idx - SUB) as u32 / SUB as u32 + SUB_BITS;
        let sub = ((idx - SUB) % SUB) as u64;
        let width = 1u64 << (m - SUB_BITS);
        (1u64 << m) + sub * width + width / 2
    }

    /// Record one latency sample at microsecond resolution.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    /// Record one pre-quantized microsecond value.
    pub fn record_us(&mut self, us: u64) {
        self.counts[Self::bucket(us)] += 1;
        self.total += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    /// Number of recorded samples.
    pub fn len(&self) -> usize {
        self.total as usize
    }

    /// True if nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Number of buckets backing this histogram — constant by construction,
    /// which is what the O(buckets) memory regression test pins.
    pub fn bucket_count(&self) -> usize {
        self.counts.len()
    }

    /// Quantile in microseconds: nearest-rank lookup answered with the
    /// owning bucket's midpoint (relative error ≤ `2^-(SUB_BITS+1)`);
    /// `q <= 0` and `q >= 1` return the exact min/max.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        if q <= 0.0 {
            return self.min_us;
        }
        if q >= 1.0 {
            return self.max_us;
        }
        let rank = (q * (self.total - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                return Self::representative(idx).clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    /// Exact mean in microseconds (the sum is kept exactly).
    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.total as f64
    }

    /// Fold `other` into `self` (losslessly — same fixed bucketing).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// One-line digest in the same shape as [`LatencyStats::summary`].
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={}us p95={}us p99={}us max={}us",
            self.len(),
            self.mean_us(),
            self.quantile_us(0.50),
            self.quantile_us(0.95),
            self.quantile_us(0.99),
            self.quantile_us(1.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert!((percentile(&v, 25.0) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 75.0) - 7.5).abs() < 1e-6);
    }

    #[test]
    fn minmax_skips_nan() {
        let (lo, hi) = min_max(&[3.0, f32::NAN, -1.0]);
        assert_eq!((lo, hi), (-1.0, 3.0));
    }

    #[test]
    fn latency_quantiles() {
        let mut l = LatencyStats::default();
        for ms in 1..=100u64 {
            l.record(std::time::Duration::from_millis(ms));
        }
        assert_eq!(l.quantile_us(0.0), 1_000);
        assert_eq!(l.quantile_us(1.0), 100_000);
        let p50 = l.quantile_us(0.5);
        assert!((49_000..=52_000).contains(&p50), "{p50}");
    }

    #[test]
    fn stats_mean_std() {
        let v = [2.0f32, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&v) - 5.0).abs() < 1e-9);
        assert!((std_dev(&v) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn log_histogram_matches_latency_stats_within_bounded_error() {
        let mut exact = LatencyStats::default();
        let mut hist = LogHistogram::default();
        let mut x = 12345u64; // xorshift — spread samples over 5 decades
        for _ in 0..10_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let us = x % 1_000_000;
            exact.record(std::time::Duration::from_micros(us));
            hist.record(std::time::Duration::from_micros(us));
        }
        assert_eq!(hist.len(), exact.len());
        assert!((hist.mean_us() - exact.mean_us()).abs() < 1e-9, "sum is exact");
        assert_eq!(hist.quantile_us(1.0), exact.quantile_us(1.0), "max is exact");
        for q in [0.5, 0.95, 0.99, 0.999] {
            let e = exact.quantile_us(q) as f64;
            let h = hist.quantile_us(q) as f64;
            // bucket midpoint: relative error bounded by 2^-(SUB_BITS+1),
            // plus slack for nearest-rank landing one bucket over
            assert!((h - e).abs() <= e / 16.0 + 1.0, "q={q}: exact {e} vs hist {h}");
        }
    }

    #[test]
    fn log_histogram_small_values_are_exact() {
        let mut h = LogHistogram::default();
        for us in [0u64, 1, 2, 3, 15, 16, 17] {
            h.record_us(us);
        }
        assert_eq!(h.quantile_us(0.0), 0);
        assert_eq!(h.quantile_us(1.0), 17);
        assert_eq!(h.len(), 7);
    }

    #[test]
    fn log_histogram_merge_equals_single_recording() {
        let mut all = LogHistogram::default();
        let mut left = LogHistogram::default();
        let mut right = LogHistogram::default();
        for i in 0..2_000u64 {
            let us = i * i % 777_777;
            all.record_us(us);
            if i % 2 == 0 {
                left.record_us(us);
            } else {
                right.record_us(us);
            }
        }
        left.merge(&right);
        assert_eq!(left.len(), all.len());
        assert!((left.mean_us() - all.mean_us()).abs() < 1e-9);
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(left.quantile_us(q), all.quantile_us(q), "q={q}");
        }
    }

    #[test]
    fn log_histogram_memory_is_o_buckets_under_1m_records() {
        // the regression LatencyStats has: a million samples must not grow
        // the backing storage — the bucket array is fixed at construction
        let mut h = LogHistogram::default();
        let buckets_before = h.bucket_count();
        let bytes = std::mem::size_of::<LogHistogram>()
            + buckets_before * std::mem::size_of::<u64>();
        for i in 0..1_000_000u64 {
            h.record_us(i % 250_000);
        }
        assert_eq!(h.len(), 1_000_000);
        assert_eq!(h.bucket_count(), buckets_before, "no growth under load");
        assert!(bytes < 16 * 1024, "fixed footprint stays under 16KiB: {bytes}B");
        let p50 = h.quantile_us(0.5);
        assert!((120_000..=130_000).contains(&p50), "{p50}");
    }
}
