//! Small statistics helpers shared by observers, metrics and benches.

/// Exact percentile of a sample via sorting (linear interpolation, like
/// numpy's default). `p` in [0, 100].
pub fn percentile(values: &[f32], p: f64) -> f32 {
    assert!(!values.is_empty(), "percentile of empty slice");
    let mut v: Vec<f32> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f32], p: f64) -> f32 {
    assert!(!sorted.is_empty());
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = (rank - lo as f64) as f32;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Mean of a slice.
pub fn mean(values: &[f32]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().map(|&x| x as f64).sum::<f64>() / values.len() as f64
}

/// Population standard deviation.
pub fn std_dev(values: &[f32]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    let var = values.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / values.len() as f64;
    var.sqrt()
}

/// (min, max) of a non-empty slice, ignoring NaNs.
pub fn min_max(values: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in values {
        if v.is_nan() {
            continue;
        }
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

/// Latency histogram with microsecond resolution for the serving metrics.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: std::time::Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let rank = (q.clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
        v[rank]
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={}us p95={}us p99={}us max={}us",
            self.len(),
            self.mean_us(),
            self.quantile_us(0.50),
            self.quantile_us(0.95),
            self.quantile_us(0.99),
            self.quantile_us(1.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert!((percentile(&v, 25.0) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 75.0) - 7.5).abs() < 1e-6);
    }

    #[test]
    fn minmax_skips_nan() {
        let (lo, hi) = min_max(&[3.0, f32::NAN, -1.0]);
        assert_eq!((lo, hi), (-1.0, 3.0));
    }

    #[test]
    fn latency_quantiles() {
        let mut l = LatencyStats::default();
        for ms in 1..=100u64 {
            l.record(std::time::Duration::from_millis(ms));
        }
        assert_eq!(l.quantile_us(0.0), 1_000);
        assert_eq!(l.quantile_us(1.0), 100_000);
        let p50 = l.quantile_us(0.5);
        assert!((49_000..=52_000).contains(&p50), "{p50}");
    }

    #[test]
    fn stats_mean_std() {
        let v = [2.0f32, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&v) - 5.0).abs() < 1e-9);
        assert!((std_dev(&v) - 2.0).abs() < 1e-9);
    }
}
