//! Poison-recovering lock helpers for the serving path.
//!
//! `Mutex::lock().unwrap()` turns one panicked thread into a process-wide
//! cascade: every later locker panics on the poison flag, which is exactly
//! the failure mode a serving coordinator must not have (`sq-lint`'s
//! `no-panic-in-serving` rule bans the pattern). These helpers recover the
//! guard from a poisoned lock instead.
//!
//! Why recovery is sound *here*: every critical section in this crate is a
//! small state update (queue push/pop, residency table edit, counter bump)
//! whose invariants hold at every await-free point — a panic mid-section
//! cannot leave half-updated state that a later reader would misparse.
//! Subsystems with multi-step invariants must not adopt these helpers
//! without re-checking that property; the doc comment on each call site's
//! mutex is the contract.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `Condvar::wait` with the same poison recovery as [`lock_recover`].
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `Condvar::wait_timeout` with the same poison recovery.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Consume a `Mutex`, recovering the value if the lock was poisoned.
pub fn into_inner_recover<T>(m: Mutex<T>) -> T {
    m.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn into_inner_recover_survives_poison() {
        let m = Arc::new(Mutex::new(3u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let m = Arc::try_unwrap(m).unwrap();
        assert_eq!(into_inner_recover(m), 3);
    }

    #[test]
    fn wait_timeout_recover_times_out_cleanly() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_recover(&m);
        let (_g, res) = wait_timeout_recover(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
