//! Lightweight property-based testing harness (substrate: no `proptest`
//! crate offline).
//!
//! A property is a closure over a seeded [`Rng`]; the harness runs it for
//! many derived seeds and, on failure, reports the failing case seed so it
//! can be replayed deterministically:
//!
//! ```ignore
//! use splitquant::util::proptest::check;
//! check("addition commutes", 100, |rng| {
//!     let a = rng.f32();
//!     let b = rng.f32();
//!     assert!((a + b - (b + a)).abs() < 1e-9);
//! });
//! ```
//! (doctests cannot link against libxla in this sandbox, hence `ignore`;
//! the same property runs as a unit test below.)

use super::rng::Rng;

/// Base seed; change via `SPLITQUANT_PROPTEST_SEED` to explore new cases.
fn base_seed() -> u64 {
    std::env::var("SPLITQUANT_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE)
}

/// Run `prop` for `cases` independent seeded RNGs. Panics (with the failing
/// case index and seed) if any case panics.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u32, prop: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed on case {case}/{cases} (seed {seed:#x}): {msg}\n\
                 replay with SPLITQUANT_PROPTEST_SEED={base} and this case index"
            );
        }
    }
}

/// Generate a random tensor-ish Vec<f32> with occasional outliers — the value
/// distribution SplitQuant targets (heavy tails, paper §1).
pub fn gen_values_with_outliers(rng: &mut Rng, n: usize, outlier_rate: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.chance(outlier_rate) {
                rng.normal_f32(0.0, 1.0) * rng.range_f64(5.0, 50.0) as f32
            } else {
                rng.normal_f32(0.0, 1.0)
            }
        })
        .collect()
}

/// Random shape with bounded rank / dimension (non-empty).
pub fn gen_shape(rng: &mut Rng, max_rank: usize, max_dim: usize) -> Vec<usize> {
    let rank = rng.range(1, max_rank + 1);
    (0..rank).map(|_| rng.range(1, max_dim + 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always fails eventually", 10, |rng| {
            assert!(rng.f64() < 0.00001, "boom");
        });
    }

    #[test]
    fn outlier_generator_has_tails() {
        let mut rng = Rng::new(1);
        let v = gen_values_with_outliers(&mut rng, 10_000, 0.01);
        let big = v.iter().filter(|x| x.abs() > 4.0).count();
        assert!(big > 10, "expected outliers, got {big}");
    }
}
