//! Fast scalar transcendentals for the executor hot loops.
//!
//! `libm`'s `expf`/`tanhf` cost ~15–20 ns each and do not vectorize; the
//! BERT executor evaluates ~1M GELUs and ~0.5M softmax exps per batch-32
//! forward, which made transcendentals ~30 % of forward time (§Perf log).
//! These Cephes-style polynomial versions are accurate to ~2 ulp over the
//! ranges the model uses and are branch-light so LLVM can vectorize the
//! surrounding loops.

/// Fast `exp(x)` for f32, max relative error ≈ 1e-6 on [-87, 87].
///
/// Range reduction: `x = n·ln2 + r`, `e^x = 2^n · e^r` with a degree-5
/// polynomial for `e^r` on [-ln2/2, ln2/2]; `2^n` applied via exponent bits.
#[inline]
pub fn fast_exp(x: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2_HI: f32 = 0.693_359_375;
    const LN2_LO: f32 = -2.121_944_4e-4;
    // clamp to the finite range of f32 exp
    let x = x.clamp(-87.0, 88.0);
    let n = (x * LOG2E).round_ties_even();
    let r = x - n * LN2_HI - n * LN2_LO;
    // e^r via Horner, coefficients 1/k!
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (0.166_666_67 + r * (0.041_666_67 + r * (0.008_333_4 + r * 0.001_388_9)))));
    // scale by 2^n: add n to the exponent field
    let bits = p.to_bits();
    let scaled = (bits as i64 + ((n as i64) << 23)) as u32;
    f32::from_bits(scaled)
}

/// Fast `tanh(x)` via `fast_exp`, max abs error ≈ 2e-7.
#[inline]
pub fn fast_tanh(x: f32) -> f32 {
    // tanh saturates to ±1 beyond ~9.02 in f32
    if x > 9.0 {
        return 1.0;
    }
    if x < -9.0 {
        return -1.0;
    }
    let e2x = fast_exp(2.0 * x);
    (e2x - 1.0) / (e2x + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_accuracy() {
        let mut worst = 0.0f32;
        for i in -8000..=8000 {
            let x = i as f32 * 0.01; // [-80, 80]
            let got = fast_exp(x);
            let want = x.exp();
            let rel = if want > 0.0 { (got - want).abs() / want } else { 0.0 };
            worst = worst.max(rel);
        }
        assert!(worst < 3e-6, "worst rel err {worst}");
    }

    #[test]
    fn exp_edge_cases() {
        assert_eq!(fast_exp(0.0), 1.0);
        assert!(fast_exp(-100.0) >= 0.0);
        assert!(fast_exp(-100.0) < 1e-37);
        assert!(fast_exp(88.0).is_finite());
    }

    #[test]
    fn tanh_accuracy() {
        let mut worst = 0.0f32;
        for i in -2000..=2000 {
            let x = i as f32 * 0.01; // [-20, 20]
            let got = fast_tanh(x);
            let want = x.tanh();
            worst = worst.max((got - want).abs());
        }
        assert!(worst < 5e-7, "worst abs err {worst}");
    }

    #[test]
    fn tanh_saturates() {
        assert_eq!(fast_tanh(50.0), 1.0);
        assert_eq!(fast_tanh(-50.0), -1.0);
        assert_eq!(fast_tanh(0.0), 0.0);
    }
}
