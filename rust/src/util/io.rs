//! Batched binary IO helpers shared by every on-disk format in the crate
//! (`SQCKPT1` checkpoints, `SQQM0001` packed models, `SQSH0001` shards).
//!
//! The original writers emitted FP32 payloads one `f32::to_le_bytes` at a
//! time — four-byte `write_all` calls that dominate save time on large FP32
//! remainders even through a `BufWriter`. These helpers stage each tensor's
//! payload through a single byte buffer so the OS sees one read/write per
//! tensor.

use std::io::{Read, Write};

use crate::error::Result;

/// Write `data` as little-endian FP32 in one `write_all`.
pub fn write_f32_slice(f: &mut impl Write, data: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(data.len() * 4);
    for &v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Read `n` little-endian FP32 values in one `read_exact`.
pub fn read_f32_vec(f: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

pub fn read_u8(f: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    f.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

pub fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn read_f32(f: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_slice_roundtrip() {
        let vals = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE, -0.0];
        let mut buf = Vec::new();
        write_f32_slice(&mut buf, &vals).unwrap();
        assert_eq!(buf.len(), vals.len() * 4);
        let back = read_f32_vec(&mut &buf[..], vals.len()).unwrap();
        // bit-exact, including the sign of -0.0
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn short_reads_error() {
        let buf = [0u8; 7];
        assert!(read_f32_vec(&mut &buf[..], 2).is_err());
        assert!(read_u64(&mut &buf[..]).is_err());
    }
}
