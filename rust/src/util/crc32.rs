//! Hand-rolled CRC-32/ISO-HDLC (the ubiquitous "crc32" of zlib, PNG and
//! Ethernet): reflected polynomial `0xEDB88320`, init `0xFFFF_FFFF`, final
//! XOR `0xFFFF_FFFF`. Zero external crates — the offline sandbox rule —
//! and table-driven, so integrity checks on the shard fault-in path cost a
//! table lookup per byte, not a branch per bit.
//!
//! Used by [`crate::shardstore::format`] for the `SQSH0002` shard format:
//! a header checksum plus one CRC per tensor record, verified on every
//! fault-in and prefetch. The canonical check vector
//! `crc32(b"123456789") == 0xCBF43926` is pinned in the tests below.

/// 256-entry lookup table for the reflected polynomial `0xEDB88320`,
/// generated at compile time so the table itself is part of the binary and
/// cannot drift from the algorithm.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// Streaming CRC-32 state, for checksumming data that arrives in pieces
/// (e.g. a shard header serialized field by field). `Hasher::new()` →
/// repeated [`update`](Hasher::update) → [`finish`](Hasher::finish) yields
/// exactly [`crc32`] of the concatenation.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    /// Fresh state (equivalent to having hashed zero bytes).
    pub fn new() -> Self {
        Hasher { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            let idx = ((crc ^ b as u32) & 0xFF) as usize;
            // sq-lint exempts ranges, and TABLE has 256 entries so the
            // masked index is always in bounds
            crc = (crc >> 8) ^ TABLE[idx & 0xFF];
        }
        self.state = crc;
    }

    /// The CRC-32 of everything fed to [`update`](Hasher::update) so far.
    /// Does not consume the state; more updates may follow.
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Hasher {
    fn default() -> Self {
        Hasher::new()
    }
}

/// One-shot CRC-32/ISO-HDLC of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_check_vector() {
        // the CRC-32/ISO-HDLC "check" value from the CRC catalogue
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_input_is_zero() {
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn known_vectors() {
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"abc"), 0x3524_41C2);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> = (0u32..1024).map(|i| (i * 7 + 13) as u8).collect();
        let whole = crc32(&data);
        for split in [0, 1, 7, 512, 1023, 1024] {
            let mut h = Hasher::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish(), whole, "split at {split}");
        }
        // byte-at-a-time
        let mut h = Hasher::new();
        for b in &data {
            h.update(std::slice::from_ref(b));
        }
        assert_eq!(h.finish(), whole);
    }

    #[test]
    fn finish_is_non_destructive() {
        let mut h = Hasher::new();
        h.update(b"1234");
        let _ = h.finish();
        h.update(b"56789");
        assert_eq!(h.finish(), 0xCBF4_3926);
    }

    #[test]
    fn single_bit_flips_change_the_checksum() {
        let data: Vec<u8> = (0u32..256).map(|i| i as u8).collect();
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut m = data.clone();
                m[byte] ^= 1 << bit;
                assert_ne!(crc32(&m), base, "flip byte {byte} bit {bit} undetected");
            }
        }
    }
}
