//! Deterministic seeded RNG (substrate: no `rand` crate offline).
//!
//! splitmix64 core with Box–Muller for normals. Every stochastic component in
//! the crate (data generation, parameter init, k-means++ seeding, workload
//! generators) takes an explicit `Rng`, so whole experiments replay
//! bit-for-bit from a seed — a requirement for the EXPERIMENTS.md protocol.

/// splitmix64-based pseudorandom generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second Box–Muller sample
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (for parallel workers / sub-generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Uses rejection sampling to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }

    #[test]
    fn forks_diverge() {
        let mut r = Rng::new(2);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
