//! Row-major dense tensors (`f32` and `i32`).
//!
//! Deliberately minimal: owned storage, explicit shapes, no stride tricks —
//! the executor works on contiguous buffers and the hot loops live in
//! [`super::ops`].

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Dense row-major `f32` tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                numel,
                data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor::full(shape, 1.0)
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// i.i.d. normal entries (parameter initialization).
    pub fn randn(shape: &[usize], mean: f32, std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal_f32(mean, std)).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![1], data: vec![v] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} ({} elems) to {:?}",
                self.shape,
                self.data.len(),
                shape
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// Number of rows / row width when viewed as 2-D (collapses leading dims).
    pub fn as_2d(&self) -> (usize, usize) {
        match self.shape.len() {
            0 => (1, 1),
            1 => (1, self.shape[0]),
            _ => {
                let cols = *self.shape.last().unwrap();
                (self.data.len() / cols, cols)
            }
        }
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Row `i` of a 2-D tensor.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    pub fn min_max(&self) -> (f32, f32) {
        crate::util::stats::min_max(&self.data)
    }

    /// Max |x| (symmetric quantization range).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Elementwise maximum absolute difference to another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in max_abs_diff");
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// In-place elementwise add.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Bytes of FP32 storage (model-size accounting, paper §6).
    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }
}

/// Dense row-major `i32` tensor (token ids, labels, cluster ids).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                numel,
                data.len()
            )));
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        IntTensor { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn data(&self) -> &[i32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    #[inline]
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> i32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.as_2d(), (2, 3));
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(&[2, 2], vec![1.0]).is_err());
        assert!(IntTensor::new(&[3], vec![1, 2]).is_err());
    }

    #[test]
    fn reshape_checks_numel() {
        let t = Tensor::zeros(&[4, 2]);
        assert!(t.reshape(&[2, 4]).is_ok());
        assert!(t.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn randn_moments() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[100, 100], 0.5, 2.0, &mut rng);
        let m = crate::util::stats::mean(t.data());
        let s = crate::util::stats::std_dev(t.data());
        assert!((m - 0.5).abs() < 0.05, "mean {m}");
        assert!((s - 2.0).abs() < 0.05, "std {s}");
    }

    #[test]
    fn minmax_and_absmax() {
        let t = Tensor::new(&[4], vec![-3.0, 1.0, 2.0, -0.5]).unwrap();
        assert_eq!(t.min_max(), (-3.0, 2.0));
        assert_eq!(t.abs_max(), 3.0);
    }

    #[test]
    fn as_2d_collapses_leading() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.as_2d(), (6, 4));
        let v = Tensor::zeros(&[7]);
        assert_eq!(v.as_2d(), (1, 7));
    }
}
