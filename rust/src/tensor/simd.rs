//! Explicit 8-lane f32 micro-kernels for the matmul hot path (§Perf).
//!
//! [`F32x8`] is a portable `std::simd`-style lane type: a fixed `[f32; 8]`
//! whose lane-wise ops compile to a single AVX instruction (or an SSE pair)
//! on x86-64 — no nightly features, no external crates, no intrinsics. The
//! win over the auto-vectorized scalar kernels comes from the *kernel
//! structure* built on top of it, not from the type itself:
//!
//! * [`PackedB`] — B repacked once per dispatch into 8-wide column panels
//!   (panel-major, rows contiguous), so the inner loop streams aligned
//!   8-lane slices instead of striding across B rows;
//! * [`matmul_rows_simd`] — register accumulation: each output 8-lane strip
//!   is loaded once, accumulated across the whole k extent, stored once.
//!   The scalar quad kernel re-reads and re-writes the C row every 4 k
//!   steps, so its C traffic is `k/4 × m×n×8` bytes; here it is `m×n×8`.
//!   Panels are swept in the outer loop, so one k×8 panel stays L1-resident
//!   across every row of the chunk.
//!
//! ## Bit-identity contract
//!
//! Every lane op mirrors the scalar kernels' exact f32 expression — same
//! k-quad boundaries, same zero-skip, same association order, and **no**
//! `mul_add` (a fused multiply-add would round differently than the scalar
//! `a*b + c`). Per output element the sequence of IEEE operations is
//! identical to the scalar `ops::matmul_rows`, so the SIMD engine is
//! bit-exact against the scalar and serial engines — asserted by the
//! remainder-torture and property tests in `parallel::kernels`.
//!
//! ## The i8×i8→i32 family
//!
//! [`matmul_rows_i8`] (and its scalar twin [`matmul_rows_i8_ref`]) are the
//! integer micro-kernels behind `KernelKind::Int8`: activations arrive as
//! zero-point-corrected i8 codes widened to i16, weights stay as their
//! packed i8 codes, and products accumulate **exactly** in one i32
//! accumulator per cluster group (per-cluster zero-point correction is
//! folded into the epilogue via the running code sum, so the inner loop
//! never touches the weight zero-points, let alone f32). Because integer
//! accumulation is associative, the SIMD strips and the scalar reference
//! produce identical accumulators in any order; the only float math is the
//! shared [`i8_epilogue`] (or its i8-requantizing twin), evaluated with one
//! fixed expression per output element — so the two twins are bit-identical
//! by construction, and stay so across serial/pooled row partitions.
//! Accumulator headroom: `|xc| ≤ 255`, `|w| ≤ 128` ⇒ safe for `k < 65_000`
//! (far above any transformer hidden size; debug builds catch overflow).

use std::ops::Range;

use crate::quant::QParams;

/// Lane width of the micro-kernels (one AVX ymm register of f32).
pub const LANES: usize = 8;

/// Portable 8-lane f32 vector. Lane-wise ops are written as fixed-width
/// array zips, which LLVM reliably lowers to vector instructions at
/// `opt-level=3` without any target-feature gating.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct F32x8([f32; LANES]);

impl F32x8 {
    /// All lanes = `v`.
    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; LANES])
    }

    /// Wrap an explicit lane array (per-lane gathers, e.g. the per-cluster
    /// scale/zero-point lookup in the fused dequant tile).
    #[inline(always)]
    pub fn from_array(lanes: [f32; LANES]) -> F32x8 {
        F32x8(lanes)
    }

    /// Load 8 lanes from the head of `s` (`s.len() >= 8`).
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32x8 {
        let mut a = [0.0f32; LANES];
        a.copy_from_slice(&s[..LANES]);
        F32x8(a)
    }

    /// Load `s.len() <= 8` lanes, zero-padding the tail — ragged-N panel
    /// edges. Zero lanes stay exactly 0.0 through the kernels (they only
    /// ever accumulate products against zero-padded B lanes) and are never
    /// stored back.
    #[inline(always)]
    pub fn load_partial(s: &[f32]) -> F32x8 {
        debug_assert!(s.len() <= LANES);
        let mut a = [0.0f32; LANES];
        a[..s.len()].copy_from_slice(s);
        F32x8(a)
    }

    /// Widen 8 `i8` codes to f32 lanes (`s.len() >= 8`) — the in-register
    /// half of the fused dequant tile.
    #[inline(always)]
    pub fn from_i8(s: &[i8]) -> F32x8 {
        let mut a = [0.0f32; LANES];
        for (l, &q) in a.iter_mut().zip(&s[..LANES]) {
            *l = q as f32;
        }
        F32x8(a)
    }

    /// Store all 8 lanes to the head of `out` (`out.len() >= 8`).
    #[inline(always)]
    pub fn store(self, out: &mut [f32]) {
        out[..LANES].copy_from_slice(&self.0);
    }

    /// Store the first `out.len() <= 8` lanes (ragged-N tail strips).
    #[inline(always)]
    pub fn store_partial(self, out: &mut [f32]) {
        let w = out.len();
        debug_assert!(w <= LANES);
        out.copy_from_slice(&self.0[..w]);
    }

    /// Lane-wise `self + o`. Plain IEEE add — matches the scalar kernels.
    #[inline(always)]
    pub fn add(self, o: F32x8) -> F32x8 {
        let mut r = [0.0f32; LANES];
        for (r, (a, b)) in r.iter_mut().zip(self.0.iter().zip(&o.0)) {
            *r = a + b;
        }
        F32x8(r)
    }

    /// Lane-wise `self - o`.
    #[inline(always)]
    pub fn sub(self, o: F32x8) -> F32x8 {
        let mut r = [0.0f32; LANES];
        for (r, (a, b)) in r.iter_mut().zip(self.0.iter().zip(&o.0)) {
            *r = a - b;
        }
        F32x8(r)
    }

    /// Lane-wise `self * o`. Deliberately NOT fused with a following add:
    /// the bit-identity contract requires the scalar `a*b + c` rounding.
    #[inline(always)]
    pub fn mul(self, o: F32x8) -> F32x8 {
        let mut r = [0.0f32; LANES];
        for (r, (a, b)) in r.iter_mut().zip(self.0.iter().zip(&o.0)) {
            *r = a * b;
        }
        F32x8(r)
    }
}

/// B(k×n) repacked into 8-wide column panels: panel `p` holds columns
/// `[8p, 8p+8)` with the k rows contiguous (`k × 8` floats per panel), the
/// tail panel zero-padded to full width. Packed **once per dispatch** —
/// the pooled engine shares one `PackedB` across every row-chunk task —
/// then the inner loop is pure 8-lane FMA over contiguous slices.
pub struct PackedB {
    k: usize,
    n: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Repack row-major `bd` (`k*n` floats). One streaming pass over B.
    pub fn pack(bd: &[f32], k: usize, n: usize) -> PackedB {
        debug_assert_eq!(bd.len(), k * n);
        let panels = n.div_ceil(LANES);
        let mut data = vec![0.0f32; panels * k * LANES];
        for p in 0..panels {
            let c0 = p * LANES;
            let w = LANES.min(n - c0);
            let base = p * k * LANES;
            for kk in 0..k {
                let dst = base + kk * LANES;
                data[dst..dst + w].copy_from_slice(&bd[kk * n + c0..kk * n + c0 + w]);
            }
        }
        PackedB { k, n, data }
    }

    #[inline(always)]
    fn panel(&self, p: usize) -> &[f32] {
        &self.data[p * self.k * LANES..(p + 1) * self.k * LANES]
    }
}

/// Compute output rows `rows` of `A @ B` into `out_chunk` (`rows.len() × n`,
/// pre-zeroed or carrying prior partial sums) — the SIMD twin of the
/// scalar `ops::matmul_rows`, bit-identical to it (see module docs).
///
/// Loop order is panel → row → k: one k×8 panel stays cache-resident
/// across every row, each 8-lane C strip is loaded/stored exactly once.
pub fn matmul_rows_simd(ad: &[f32], b: &PackedB, out_chunk: &mut [f32], rows: Range<usize>) {
    let (k, n) = (b.k, b.n);
    let k4 = k - k % 4;
    let panels = n.div_ceil(LANES);
    for p in 0..panels {
        let c0 = p * LANES;
        let w = LANES.min(n - c0);
        let pan = b.panel(p);
        for (ri, i) in rows.clone().enumerate() {
            let arow = &ad[i * k..(i + 1) * k];
            let ostrip = &mut out_chunk[ri * n + c0..ri * n + c0 + w];
            let mut acc =
                if w == LANES { F32x8::load(ostrip) } else { F32x8::load_partial(ostrip) };
            let mut kk = 0;
            while kk < k4 {
                let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                    kk += 4;
                    continue; // padded/sparse rows — same skip as the scalar quad
                }
                let b0 = F32x8::load(&pan[kk * LANES..(kk + 1) * LANES]);
                let b1 = F32x8::load(&pan[(kk + 1) * LANES..(kk + 2) * LANES]);
                let b2 = F32x8::load(&pan[(kk + 2) * LANES..(kk + 3) * LANES]);
                let b3 = F32x8::load(&pan[(kk + 3) * LANES..(kk + 4) * LANES]);
                // association order of the scalar kernel:
                // ((a0*b0 + a1*b1) + a2*b2) + a3*b3, then += into C
                let t = F32x8::splat(a0)
                    .mul(b0)
                    .add(F32x8::splat(a1).mul(b1))
                    .add(F32x8::splat(a2).mul(b2))
                    .add(F32x8::splat(a3).mul(b3));
                acc = acc.add(t);
                kk += 4;
            }
            for kk in k4..k {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = F32x8::load(&pan[kk * LANES..(kk + 1) * LANES]);
                acc = acc.add(F32x8::splat(av).mul(brow));
            }
            if w == LANES {
                acc.store(ostrip);
            } else {
                acc.store_partial(ostrip);
            }
        }
    }
}

/// Borrowed view of one quantized weight plane for the i8 kernels: packed
/// codes, optional per-element cluster ids, and the per-cluster constants
/// the epilogue needs. Built once per fused dispatch (the planes are the
/// same buffers the f32 fused kernel and the paged plane cache hold — the
/// integer engine adds no weight-side memory).
pub struct I8Plane<'a> {
    /// Weight codes, row-major `k × n`.
    pub codes: &'a [i8],
    /// Cluster id per element (`k × n`), or empty for a single group.
    pub cid: &'a [u8],
    /// Per-cluster zero-points (integral, as stored in `QParams.zp`).
    pub zps: &'a [f32],
    /// Per-cluster reciprocal scales `1 / s_g`.
    pub inv: &'a [f32],
    /// Inner dimension (rows of W).
    pub k: usize,
    /// Output width (columns of W).
    pub n: usize,
}

/// Quantize an activation slice for the integer engine: each value becomes
/// its i8 code with the activation zero-point already subtracted, widened
/// to i16 (`x_q − Z_x ∈ [−255, 254]`). `p` must come from a zero-inclusive
/// range (the fused dispatch widens ranges to include 0), which pins
/// `Z_x` inside the i8 range so the subtraction is exact.
pub fn quantize_acts_i8(xd: &[f32], p: &QParams) -> Vec<i16> {
    let zp = p.zp as i16;
    xd.iter().map(|&v| p.quantize(v) as i16 - zp).collect()
}

/// The integer engine's dequantize epilogue — the **only** float math in
/// the i8 datapath, shared verbatim by the SIMD strips and the scalar
/// reference so the twins stay bit-identical:
///
/// ```text
/// out = inv_x · Σ_g (acc_g − zp_g · cnt_g) · inv_g
/// ```
///
/// `acc_g = Σ xc·w_q` and `cnt_g = Σ xc` over the k-elements of cluster
/// `g` are exact i32 sums; subtracting `zp_g · cnt_g` here is the
/// per-cluster zero-point correction folded out of the inner loop.
#[inline(always)]
pub fn i8_epilogue(acc: &[i32], cnt: &[i32], zps: &[f32], inv: &[f32], inv_x: f32) -> f32 {
    let mut s = 0.0f32;
    for ((&a, &c), (&z, &v)) in acc.iter().zip(cnt).zip(zps.iter().zip(inv)) {
        s += (a as f32 - z * c as f32) * v;
    }
    s * inv_x
}

/// Scalar reference twin of [`matmul_rows_i8`]: one output element at a
/// time, per-cluster i32 accumulators, the shared [`i8_epilogue`]. This is
/// the ground truth the SIMD strips (and the end-to-end qbert int8 path)
/// are torture-tested against.
pub fn matmul_rows_i8_ref(
    xc: &[i16],
    w: &I8Plane,
    inv_x: f32,
    out_chunk: &mut [f32],
    rows: Range<usize>,
) {
    i8_rows_ref_core(xc, w, out_chunk, rows, |acc, cnt| {
        i8_epilogue(acc, cnt, w.zps, w.inv, inv_x)
    });
}

/// Integer micro-kernel for one output row chunk: 8-wide column strips
/// with per-cluster `[i32; 8]` lane accumulators (per-tensor planes take a
/// vector fast path whose code sum hoists out of the lanes), then the
/// shared [`i8_epilogue`] per lane. Bit-identical to
/// [`matmul_rows_i8_ref`] — integer accumulation is exact in any order and
/// the epilogue expression is the same.
pub fn matmul_rows_i8(
    xc: &[i16],
    w: &I8Plane,
    inv_x: f32,
    out_chunk: &mut [f32],
    rows: Range<usize>,
) {
    i8_rows_simd_core(xc, w, out_chunk, rows, |acc, cnt| {
        i8_epilogue(acc, cnt, w.zps, w.inv, inv_x)
    });
}

/// [`matmul_rows_i8_ref`] with the i32→i8 re-quantizing epilogue: the
/// dequantized value is immediately re-quantized under `out_p`
/// (`QParams::quantize`), producing the next layer's activation codes
/// without a f32 round trip through memory.
pub fn matmul_rows_i8_requant_ref(
    xc: &[i16],
    w: &I8Plane,
    inv_x: f32,
    out_p: &QParams,
    out_chunk: &mut [i8],
    rows: Range<usize>,
) {
    i8_rows_ref_core(xc, w, out_chunk, rows, |acc, cnt| {
        out_p.quantize(i8_epilogue(acc, cnt, w.zps, w.inv, inv_x))
    });
}

/// [`matmul_rows_i8`] with the i32→i8 re-quantizing epilogue — SIMD twin
/// of [`matmul_rows_i8_requant_ref`], bit-identical to it (same
/// accumulators, same epilogue expression, same `QParams::quantize`
/// rounding).
pub fn matmul_rows_i8_requant(
    xc: &[i16],
    w: &I8Plane,
    inv_x: f32,
    out_p: &QParams,
    out_chunk: &mut [i8],
    rows: Range<usize>,
) {
    i8_rows_simd_core(xc, w, out_chunk, rows, |acc, cnt| {
        out_p.quantize(i8_epilogue(acc, cnt, w.zps, w.inv, inv_x))
    });
}

/// Debug-build guard for the documented accumulator headroom bound (module
/// doc: `|xc| ≤ 255`, `|w| ≤ 128` ⇒ safe for `k < 65_000`). Beyond it a
/// per-cluster i32 accumulator can wrap and the exactness contract — SIMD
/// == scalar reference, bit for bit — silently breaks instead of erroring.
#[inline]
fn debug_check_i8_headroom(k: usize) {
    debug_assert!(
        k < 65_000,
        "i8 kernel accumulator headroom exceeded: k = {k} ≥ 65_000 \
         (each step adds up to 255·128 = 32640, overflowing i32)"
    );
}

/// Scalar accumulation core, generic over the epilogue (f32 dequant or i8
/// re-quant) so both public twins share one loop body.
fn i8_rows_ref_core<T: Copy>(
    xc: &[i16],
    w: &I8Plane,
    out_chunk: &mut [T],
    rows: Range<usize>,
    epi: impl Fn(&[i32], &[i32]) -> T,
) {
    debug_check_i8_headroom(w.k);
    let (k, n) = (w.k, w.n);
    let groups = w.inv.len();
    let mut acc = vec![0i32; groups];
    let mut cnt = vec![0i32; groups];
    for (ri, i) in rows.enumerate() {
        let xrow = &xc[i * k..(i + 1) * k];
        for j in 0..n {
            acc.fill(0);
            cnt.fill(0);
            if w.cid.is_empty() {
                let (a, c) = (&mut acc[0], &mut cnt[0]);
                for (kk, &xq) in xrow.iter().enumerate() {
                    let xv = xq as i32;
                    *a += xv * w.codes[kk * n + j] as i32;
                    *c += xv;
                }
            } else {
                for (kk, &xq) in xrow.iter().enumerate() {
                    let xv = xq as i32;
                    let g = w.cid[kk * n + j] as usize;
                    acc[g] += xv * w.codes[kk * n + j] as i32;
                    cnt[g] += xv;
                }
            }
            out_chunk[ri * n + j] = epi(&acc, &cnt);
        }
    }
}

/// Strip accumulation core: panels of 8 output columns, per-cluster
/// `[i32; 8]` accumulators held in registers across the whole k extent.
/// The per-tensor fast path accumulates one vector lane set and hoists the
/// activation code sum (column-independent without cluster ids); the split
/// path gathers the cluster id per lane. Zero activation codes are skipped
/// — exact for integers, `acc += 0` and `cnt += 0` change nothing.
fn i8_rows_simd_core<T: Copy>(
    xc: &[i16],
    w: &I8Plane,
    out_chunk: &mut [T],
    rows: Range<usize>,
    epi: impl Fn(&[i32], &[i32]) -> T,
) {
    debug_check_i8_headroom(w.k);
    let (k, n) = (w.k, w.n);
    let groups = w.inv.len();
    let panels = n.div_ceil(LANES);
    let mut acc = vec![[0i32; LANES]; groups];
    let mut cnt = vec![[0i32; LANES]; groups];
    let mut acc_l = vec![0i32; groups];
    let mut cnt_l = vec![0i32; groups];
    for p in 0..panels {
        let c0 = p * LANES;
        let width = LANES.min(n - c0);
        for (ri, i) in rows.clone().enumerate() {
            let xrow = &xc[i * k..(i + 1) * k];
            for a in acc.iter_mut() {
                *a = [0; LANES];
            }
            for c in cnt.iter_mut() {
                *c = [0; LANES];
            }
            if w.cid.is_empty() {
                let a = &mut acc[0];
                let mut rowsum = 0i32;
                for (kk, &xq) in xrow.iter().enumerate() {
                    let xv = xq as i32;
                    if xv == 0 {
                        continue;
                    }
                    rowsum += xv;
                    let crow = &w.codes[kk * n + c0..kk * n + c0 + width];
                    for (al, &q) in a[..width].iter_mut().zip(crow) {
                        *al += xv * q as i32;
                    }
                }
                cnt[0] = [rowsum; LANES];
            } else {
                for (kk, &xq) in xrow.iter().enumerate() {
                    let xv = xq as i32;
                    if xv == 0 {
                        continue;
                    }
                    let base = kk * n + c0;
                    for l in 0..width {
                        let g = w.cid[base + l] as usize;
                        acc[g][l] += xv * w.codes[base + l] as i32;
                        cnt[g][l] += xv;
                    }
                }
            }
            for l in 0..width {
                for g in 0..groups {
                    acc_l[g] = acc[g][l];
                    cnt_l[g] = cnt[g][l];
                }
                out_chunk[ri * n + c0 + l] = epi(&acc_l, &cnt_l);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_ops_are_elementwise() {
        let a = F32x8([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = F32x8::splat(2.0);
        assert_eq!(a.add(b).0, [3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]);
        assert_eq!(a.mul(b).0, [2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0]);
        assert_eq!(a.sub(b).0, [-1.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn partial_load_zero_pads_and_partial_store_truncates() {
        let v = F32x8::load_partial(&[1.0, 2.0, 3.0]);
        assert_eq!(v.0, [1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let mut out = [9.0f32; 3];
        v.store_partial(&mut out);
        assert_eq!(out, [1.0, 2.0, 3.0]);
    }

    #[test]
    fn from_i8_widens() {
        let v = F32x8::from_i8(&[-2, -1, 0, 1, 2, 3, -8, 7]);
        assert_eq!(v.0, [-2.0, -1.0, 0.0, 1.0, 2.0, 3.0, -8.0, 7.0]);
    }

    #[test]
    fn packed_b_panels_cover_ragged_widths() {
        // 3×11: two panels, tail width 3, zero-padded
        let (k, n) = (3usize, 11usize);
        let bd: Vec<f32> = (0..k * n).map(|v| v as f32 + 1.0).collect();
        let pb = PackedB::pack(&bd, k, n);
        for kk in 0..k {
            assert_eq!(pb.panel(0)[kk * LANES..kk * LANES + LANES], bd[kk * n..kk * n + 8]);
            assert_eq!(pb.panel(1)[kk * LANES..kk * LANES + 3], bd[kk * n + 8..kk * n + 11]);
            assert_eq!(pb.panel(1)[kk * LANES + 3..(kk + 1) * LANES], [0.0; 5]);
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "accumulator headroom exceeded")]
    fn i8_headroom_guard_fires_past_the_documented_bound() {
        // the module doc promises exact i32 accumulation only for
        // k < 65_000; the debug guard must trip right at the bound instead
        // of letting the accumulator wrap silently
        let k = 65_000usize;
        let codes = vec![0i8; k];
        let xc = vec![0i16; k];
        let (zps, inv) = ([0.0f32], [1.0f32]);
        let plane = I8Plane { codes: &codes, cid: &[], zps: &zps, inv: &inv, k, n: 1 };
        let mut out = [0.0f32; 1];
        matmul_rows_i8_ref(&xc, &plane, 1.0, &mut out, 0..1);
    }

    fn i8_fixture(
        m: usize,
        k: usize,
        n: usize,
    ) -> (Vec<i16>, Vec<i8>, Vec<u8>, Vec<f32>, Vec<f32>, QParams) {
        let xp = QParams::from_range(-1.0, 1.0, 8);
        let x: Vec<f32> = (0..m * k).map(|v| (v as f32 * 0.7).sin()).collect();
        let xc = quantize_acts_i8(&x, &xp);
        let wp = [QParams::from_range(-0.5, 0.5, 4), QParams::from_range(-2.0, 2.0, 4)];
        let codes: Vec<i8> = (0..k * n).map(|v| ((v % 15) as i8) - 8).collect();
        let cid: Vec<u8> = (0..k * n).map(|v| (v % 2) as u8).collect();
        let zps: Vec<f32> = wp.iter().map(|p| p.zp).collect();
        let inv: Vec<f32> = wp.iter().map(|p| 1.0 / p.scale).collect();
        (xc, codes, cid, zps, inv, xp)
    }

    #[test]
    fn i8_twins_are_bit_identical_and_match_float_reference() {
        let (m, k, n) = (3usize, 7usize, 11usize);
        let (xc, codes, cid, zps, inv, xp) = i8_fixture(m, k, n);
        let plane = I8Plane { codes: &codes, cid: &cid, zps: &zps, inv: &inv, k, n };
        let inv_x = 1.0 / xp.scale;
        let mut simd = vec![0.0f32; m * n];
        let mut refr = vec![0.0f32; m * n];
        matmul_rows_i8(&xc, &plane, inv_x, &mut simd, 0..m);
        matmul_rows_i8_ref(&xc, &plane, inv_x, &mut refr, 0..m);
        for (a, b) in simd.iter().zip(&refr) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // against a plain float x_dq @ dq(W) reference
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0f64;
                for kk in 0..k {
                    let xf = xc[i * k + kk] as f64 / xp.scale as f64;
                    let g = cid[kk * n + j] as usize;
                    let wf = (codes[kk * n + j] as f64 - zps[g] as f64) * inv[g] as f64;
                    want += xf * wf;
                }
                assert!((simd[i * n + j] as f64 - want).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn i8_requant_twins_are_bit_identical() {
        let (m, k, n) = (2usize, 9usize, 13usize);
        let (xc, codes, cid, zps, inv, xp) = i8_fixture(m, k, n);
        let plane = I8Plane { codes: &codes, cid: &cid, zps: &zps, inv: &inv, k, n };
        let inv_x = 1.0 / xp.scale;
        let out_p = QParams::from_range(-4.0, 4.0, 8);
        let mut simd = vec![0i8; m * n];
        let mut refr = vec![0i8; m * n];
        matmul_rows_i8_requant(&xc, &plane, inv_x, &out_p, &mut simd, 0..m);
        matmul_rows_i8_requant_ref(&xc, &plane, inv_x, &out_p, &mut refr, 0..m);
        assert_eq!(simd, refr);
    }

    #[test]
    fn simd_rows_match_naive() {
        let (m, k, n) = (4usize, 10usize, 13usize);
        let ad: Vec<f32> = (0..m * k).map(|v| (v as f32 * 0.37).sin()).collect();
        let bd: Vec<f32> = (0..k * n).map(|v| (v as f32 * 0.11).cos()).collect();
        let pb = PackedB::pack(&bd, k, n);
        let mut got = vec![0.0f32; m * n];
        matmul_rows_simd(&ad, &pb, &mut got, 0..m);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| ad[i * k + kk] * bd[kk * n + j]).sum();
                assert!((got[i * n + j] - want).abs() < 1e-4, "({i},{j})");
            }
        }
    }
}
