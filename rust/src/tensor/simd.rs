//! Explicit 8-lane f32 micro-kernels for the matmul hot path (§Perf).
//!
//! [`F32x8`] is a portable `std::simd`-style lane type: a fixed `[f32; 8]`
//! whose lane-wise ops compile to a single AVX instruction (or an SSE pair)
//! on x86-64 — no nightly features, no external crates, no intrinsics. The
//! win over the auto-vectorized scalar kernels comes from the *kernel
//! structure* built on top of it, not from the type itself:
//!
//! * [`PackedB`] — B repacked once per dispatch into 8-wide column panels
//!   (panel-major, rows contiguous), so the inner loop streams aligned
//!   8-lane slices instead of striding across B rows;
//! * [`matmul_rows_simd`] — register accumulation: each output 8-lane strip
//!   is loaded once, accumulated across the whole k extent, stored once.
//!   The scalar quad kernel re-reads and re-writes the C row every 4 k
//!   steps, so its C traffic is `k/4 × m×n×8` bytes; here it is `m×n×8`.
//!   Panels are swept in the outer loop, so one k×8 panel stays L1-resident
//!   across every row of the chunk.
//!
//! ## Bit-identity contract
//!
//! Every lane op mirrors the scalar kernels' exact f32 expression — same
//! k-quad boundaries, same zero-skip, same association order, and **no**
//! `mul_add` (a fused multiply-add would round differently than the scalar
//! `a*b + c`). Per output element the sequence of IEEE operations is
//! identical to the scalar `ops::matmul_rows`, so the SIMD engine is
//! bit-exact against the scalar and serial engines — asserted by the
//! remainder-torture and property tests in `parallel::kernels`.

use std::ops::Range;

/// Lane width of the micro-kernels (one AVX ymm register of f32).
pub const LANES: usize = 8;

/// Portable 8-lane f32 vector. Lane-wise ops are written as fixed-width
/// array zips, which LLVM reliably lowers to vector instructions at
/// `opt-level=3` without any target-feature gating.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct F32x8([f32; LANES]);

impl F32x8 {
    /// All lanes = `v`.
    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; LANES])
    }

    /// Wrap an explicit lane array (per-lane gathers, e.g. the per-cluster
    /// scale/zero-point lookup in the fused dequant tile).
    #[inline(always)]
    pub fn from_array(lanes: [f32; LANES]) -> F32x8 {
        F32x8(lanes)
    }

    /// Load 8 lanes from the head of `s` (`s.len() >= 8`).
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32x8 {
        let mut a = [0.0f32; LANES];
        a.copy_from_slice(&s[..LANES]);
        F32x8(a)
    }

    /// Load `s.len() <= 8` lanes, zero-padding the tail — ragged-N panel
    /// edges. Zero lanes stay exactly 0.0 through the kernels (they only
    /// ever accumulate products against zero-padded B lanes) and are never
    /// stored back.
    #[inline(always)]
    pub fn load_partial(s: &[f32]) -> F32x8 {
        debug_assert!(s.len() <= LANES);
        let mut a = [0.0f32; LANES];
        a[..s.len()].copy_from_slice(s);
        F32x8(a)
    }

    /// Widen 8 `i8` codes to f32 lanes (`s.len() >= 8`) — the in-register
    /// half of the fused dequant tile.
    #[inline(always)]
    pub fn from_i8(s: &[i8]) -> F32x8 {
        let mut a = [0.0f32; LANES];
        for (l, &q) in a.iter_mut().zip(&s[..LANES]) {
            *l = q as f32;
        }
        F32x8(a)
    }

    /// Store all 8 lanes to the head of `out` (`out.len() >= 8`).
    #[inline(always)]
    pub fn store(self, out: &mut [f32]) {
        out[..LANES].copy_from_slice(&self.0);
    }

    /// Store the first `out.len() <= 8` lanes (ragged-N tail strips).
    #[inline(always)]
    pub fn store_partial(self, out: &mut [f32]) {
        let w = out.len();
        debug_assert!(w <= LANES);
        out.copy_from_slice(&self.0[..w]);
    }

    /// Lane-wise `self + o`. Plain IEEE add — matches the scalar kernels.
    #[inline(always)]
    pub fn add(self, o: F32x8) -> F32x8 {
        let mut r = [0.0f32; LANES];
        for (r, (a, b)) in r.iter_mut().zip(self.0.iter().zip(&o.0)) {
            *r = a + b;
        }
        F32x8(r)
    }

    /// Lane-wise `self - o`.
    #[inline(always)]
    pub fn sub(self, o: F32x8) -> F32x8 {
        let mut r = [0.0f32; LANES];
        for (r, (a, b)) in r.iter_mut().zip(self.0.iter().zip(&o.0)) {
            *r = a - b;
        }
        F32x8(r)
    }

    /// Lane-wise `self * o`. Deliberately NOT fused with a following add:
    /// the bit-identity contract requires the scalar `a*b + c` rounding.
    #[inline(always)]
    pub fn mul(self, o: F32x8) -> F32x8 {
        let mut r = [0.0f32; LANES];
        for (r, (a, b)) in r.iter_mut().zip(self.0.iter().zip(&o.0)) {
            *r = a * b;
        }
        F32x8(r)
    }
}

/// B(k×n) repacked into 8-wide column panels: panel `p` holds columns
/// `[8p, 8p+8)` with the k rows contiguous (`k × 8` floats per panel), the
/// tail panel zero-padded to full width. Packed **once per dispatch** —
/// the pooled engine shares one `PackedB` across every row-chunk task —
/// then the inner loop is pure 8-lane FMA over contiguous slices.
pub struct PackedB {
    k: usize,
    n: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Repack row-major `bd` (`k*n` floats). One streaming pass over B.
    pub fn pack(bd: &[f32], k: usize, n: usize) -> PackedB {
        debug_assert_eq!(bd.len(), k * n);
        let panels = n.div_ceil(LANES);
        let mut data = vec![0.0f32; panels * k * LANES];
        for p in 0..panels {
            let c0 = p * LANES;
            let w = LANES.min(n - c0);
            let base = p * k * LANES;
            for kk in 0..k {
                let dst = base + kk * LANES;
                data[dst..dst + w].copy_from_slice(&bd[kk * n + c0..kk * n + c0 + w]);
            }
        }
        PackedB { k, n, data }
    }

    #[inline(always)]
    fn panel(&self, p: usize) -> &[f32] {
        &self.data[p * self.k * LANES..(p + 1) * self.k * LANES]
    }
}

/// Compute output rows `rows` of `A @ B` into `out_chunk` (`rows.len() × n`,
/// pre-zeroed or carrying prior partial sums) — the SIMD twin of the
/// scalar `ops::matmul_rows`, bit-identical to it (see module docs).
///
/// Loop order is panel → row → k: one k×8 panel stays cache-resident
/// across every row, each 8-lane C strip is loaded/stored exactly once.
pub fn matmul_rows_simd(ad: &[f32], b: &PackedB, out_chunk: &mut [f32], rows: Range<usize>) {
    let (k, n) = (b.k, b.n);
    let k4 = k - k % 4;
    let panels = n.div_ceil(LANES);
    for p in 0..panels {
        let c0 = p * LANES;
        let w = LANES.min(n - c0);
        let pan = b.panel(p);
        for (ri, i) in rows.clone().enumerate() {
            let arow = &ad[i * k..(i + 1) * k];
            let ostrip = &mut out_chunk[ri * n + c0..ri * n + c0 + w];
            let mut acc =
                if w == LANES { F32x8::load(ostrip) } else { F32x8::load_partial(ostrip) };
            let mut kk = 0;
            while kk < k4 {
                let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                    kk += 4;
                    continue; // padded/sparse rows — same skip as the scalar quad
                }
                let b0 = F32x8::load(&pan[kk * LANES..(kk + 1) * LANES]);
                let b1 = F32x8::load(&pan[(kk + 1) * LANES..(kk + 2) * LANES]);
                let b2 = F32x8::load(&pan[(kk + 2) * LANES..(kk + 3) * LANES]);
                let b3 = F32x8::load(&pan[(kk + 3) * LANES..(kk + 4) * LANES]);
                // association order of the scalar kernel:
                // ((a0*b0 + a1*b1) + a2*b2) + a3*b3, then += into C
                let t = F32x8::splat(a0)
                    .mul(b0)
                    .add(F32x8::splat(a1).mul(b1))
                    .add(F32x8::splat(a2).mul(b2))
                    .add(F32x8::splat(a3).mul(b3));
                acc = acc.add(t);
                kk += 4;
            }
            for kk in k4..k {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = F32x8::load(&pan[kk * LANES..(kk + 1) * LANES]);
                acc = acc.add(F32x8::splat(av).mul(brow));
            }
            if w == LANES {
                acc.store(ostrip);
            } else {
                acc.store_partial(ostrip);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_ops_are_elementwise() {
        let a = F32x8([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = F32x8::splat(2.0);
        assert_eq!(a.add(b).0, [3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]);
        assert_eq!(a.mul(b).0, [2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0]);
        assert_eq!(a.sub(b).0, [-1.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn partial_load_zero_pads_and_partial_store_truncates() {
        let v = F32x8::load_partial(&[1.0, 2.0, 3.0]);
        assert_eq!(v.0, [1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let mut out = [9.0f32; 3];
        v.store_partial(&mut out);
        assert_eq!(out, [1.0, 2.0, 3.0]);
    }

    #[test]
    fn from_i8_widens() {
        let v = F32x8::from_i8(&[-2, -1, 0, 1, 2, 3, -8, 7]);
        assert_eq!(v.0, [-2.0, -1.0, 0.0, 1.0, 2.0, 3.0, -8.0, 7.0]);
    }

    #[test]
    fn packed_b_panels_cover_ragged_widths() {
        // 3×11: two panels, tail width 3, zero-padded
        let (k, n) = (3usize, 11usize);
        let bd: Vec<f32> = (0..k * n).map(|v| v as f32 + 1.0).collect();
        let pb = PackedB::pack(&bd, k, n);
        for kk in 0..k {
            assert_eq!(pb.panel(0)[kk * LANES..kk * LANES + LANES], bd[kk * n..kk * n + 8]);
            assert_eq!(pb.panel(1)[kk * LANES..kk * LANES + 3], bd[kk * n + 8..kk * n + 11]);
            assert_eq!(pb.panel(1)[kk * LANES + 3..(kk + 1) * LANES], [0.0; 5]);
        }
    }

    #[test]
    fn simd_rows_match_naive() {
        let (m, k, n) = (4usize, 10usize, 13usize);
        let ad: Vec<f32> = (0..m * k).map(|v| (v as f32 * 0.37).sin()).collect();
        let bd: Vec<f32> = (0..k * n).map(|v| (v as f32 * 0.11).cos()).collect();
        let pb = PackedB::pack(&bd, k, n);
        let mut got = vec![0.0f32; m * n];
        matmul_rows_simd(&ad, &pb, &mut got, 0..m);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| ad[i * k + kk] * bd[kk * n + j]).sum();
                assert!((got[i * n + j] - want).abs() < 1e-4, "({i},{j})");
            }
        }
    }
}
