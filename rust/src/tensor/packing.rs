//! Bit-packed storage for low-bit integer codes.
//!
//! The paper's model-size arithmetic (§6: INT2 = 6.25 % of FP32, SplitQuant
//! up to 18.75 %) assumes *real* sub-byte storage; this module provides it.
//! Signed codes in `[-2^(b-1), 2^(b-1)-1]` are biased to unsigned and packed
//! little-endian within each byte (first code in the lowest bits).

use crate::error::{Error, Result};

/// Bit-packed buffer of signed `bits`-wide integer codes.
#[derive(Debug, Clone, PartialEq)]
pub struct Packed {
    bits: u8,
    len: usize,
    bytes: Vec<u8>,
}

impl Packed {
    /// Pack signed codes. `bits` must be 1..=8 and each code must fit.
    pub fn pack(codes: &[i8], bits: u8) -> Result<Packed> {
        if !(1..=8).contains(&bits) {
            return Err(Error::Quant(format!("unsupported bit width {bits}")));
        }
        let qmin = -(1i16 << (bits - 1));
        let qmax = (1i16 << (bits - 1)) - 1;
        let per_byte = 8 / bits as usize;
        let nbytes = codes.len().div_ceil(per_byte);
        let mut bytes = vec![0u8; nbytes];
        let mask = ((1u16 << bits) - 1) as u8;
        for (i, &c) in codes.iter().enumerate() {
            let c16 = c as i16;
            if c16 < qmin || c16 > qmax {
                return Err(Error::Quant(format!("code {c} out of INT{bits} range")));
            }
            let biased = ((c16 - qmin) as u8) & mask;
            let byte = i / per_byte;
            let shift = (i % per_byte) as u8 * bits;
            bytes[byte] |= biased << shift;
        }
        Ok(Packed { bits, len: codes.len(), bytes })
    }

    /// Unpack back to signed codes.
    pub fn unpack(&self) -> Vec<i8> {
        let per_byte = 8 / self.bits as usize;
        let qmin = -(1i16 << (self.bits - 1));
        let mask = ((1u16 << self.bits) - 1) as u8;
        let mut out = Vec::with_capacity(self.len);
        for i in 0..self.len {
            let byte = self.bytes[i / per_byte];
            let shift = (i % per_byte) as u8 * self.bits;
            let biased = (byte >> shift) & mask;
            out.push((biased as i16 + qmin) as i8);
        }
        out
    }

    /// Pack **unsigned** codes in `[0, 2^bits)` (cluster-id planes).
    pub fn pack_unsigned(codes: &[u8], bits: u8) -> Result<Packed> {
        if !(1..=8).contains(&bits) {
            return Err(Error::Quant(format!("unsupported bit width {bits}")));
        }
        let limit = if bits == 8 { 255u16 } else { (1u16 << bits) - 1 };
        let per_byte = 8 / bits as usize;
        let nbytes = codes.len().div_ceil(per_byte);
        let mut bytes = vec![0u8; nbytes];
        let mask = ((1u16 << bits) - 1) as u8;
        for (i, &c) in codes.iter().enumerate() {
            if c as u16 > limit {
                return Err(Error::Quant(format!("code {c} out of UINT{bits} range")));
            }
            let byte = i / per_byte;
            let shift = (i % per_byte) as u8 * bits;
            bytes[byte] |= (c & mask) << shift;
        }
        Ok(Packed { bits, len: codes.len(), bytes })
    }

    /// Unpack as unsigned codes.
    pub fn unpack_unsigned(&self) -> Vec<u8> {
        let per_byte = 8 / self.bits as usize;
        let mask = ((1u16 << self.bits) - 1) as u8;
        (0..self.len)
            .map(|i| {
                let byte = self.bytes[i / per_byte];
                let shift = (i % per_byte) as u8 * self.bits;
                (byte >> shift) & mask
            })
            .collect()
    }

    /// Read one code without unpacking everything.
    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        debug_assert!(i < self.len);
        let per_byte = 8 / self.bits as usize;
        let qmin = -(1i16 << (self.bits - 1));
        let mask = ((1u16 << self.bits) - 1) as u8;
        let byte = self.bytes[i / per_byte];
        let shift = (i % per_byte) as u8 * self.bits;
        (((byte >> shift) & mask) as i16 + qmin) as i8
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Packed storage size in bytes.
    pub fn byte_size(&self) -> usize {
        self.bytes.len()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Reconstruct from raw parts (checkpoint loading).
    pub fn from_raw(bits: u8, len: usize, bytes: Vec<u8>) -> Result<Packed> {
        let per_byte = 8 / bits as usize;
        if bytes.len() != len.div_ceil(per_byte) {
            return Err(Error::Quant(format!(
                "packed buffer size {} does not match len {len} at {bits} bits",
                bytes.len()
            )));
        }
        Ok(Packed { bits, len, bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 1..=8u8 {
            let qmin = -(1i16 << (bits - 1));
            let qmax = (1i16 << (bits - 1)) - 1;
            let codes: Vec<i8> = (qmin..=qmax).map(|v| v as i8).collect();
            let p = Packed::pack(&codes, bits).unwrap();
            assert_eq!(p.unpack(), codes, "bits={bits}");
        }
    }

    #[test]
    fn sizes_match_paper_arithmetic() {
        // 1000 FP32 params = 4000 bytes; INT2 = 250 bytes = 6.25 %.
        let codes = vec![0i8; 1000];
        let p2 = Packed::pack(&codes, 2).unwrap();
        assert_eq!(p2.byte_size(), 250);
        let p4 = Packed::pack(&codes, 4).unwrap();
        assert_eq!(p4.byte_size(), 500);
        let p8 = Packed::pack(&codes, 8).unwrap();
        assert_eq!(p8.byte_size(), 1000);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(Packed::pack(&[2], 2).is_err()); // INT2 max is 1
        assert!(Packed::pack(&[-3], 2).is_err());
        assert!(Packed::pack(&[7], 4).is_ok());
        assert!(Packed::pack(&[8], 4).is_err());
    }

    #[test]
    fn random_get_matches_unpack() {
        check("packed get == unpack", 50, |rng| {
            let bits = [2u8, 4, 8][rng.below(3)];
            let qmin = -(1i16 << (bits - 1));
            let qmax = (1i16 << (bits - 1)) - 1;
            let n = rng.range(1, 300);
            let codes: Vec<i8> = (0..n)
                .map(|_| (qmin + rng.below((qmax - qmin + 1) as usize) as i16) as i8)
                .collect();
            let p = Packed::pack(&codes, bits).unwrap();
            let un = p.unpack();
            assert_eq!(un, codes);
            for i in 0..n {
                assert_eq!(p.get(i), codes[i]);
            }
        });
    }

    #[test]
    fn from_raw_validates_length() {
        let p = Packed::pack(&[0, 1, -1], 4).unwrap();
        let raw = p.bytes().to_vec();
        assert!(Packed::from_raw(4, 3, raw.clone()).is_ok());
        assert!(Packed::from_raw(4, 5, raw).is_err());
    }
}
