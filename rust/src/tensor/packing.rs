//! Bit-packed storage for low-bit integer codes.
//!
//! The paper's model-size arithmetic (§6: INT2 = 6.25 % of FP32, SplitQuant
//! up to 18.75 %) assumes *real* sub-byte storage; this module provides it.
//! Signed codes in `[-2^(b-1), 2^(b-1)-1]` are biased to unsigned and packed
//! little-endian within each byte (first code in the lowest bits).

use crate::error::{Error, Result};

/// 256-entry byte LUT for 2-bit signed codes: one packed byte expands to 4
/// codes in one indexed copy (two bytes per 8-lane SIMD dequant step).
static LUT2: [[i8; 4]; 256] = lut_signed2();
/// 256-entry byte LUT for 4-bit signed codes: one byte → 2 codes.
static LUT4: [[i8; 2]; 256] = lut_signed4();
/// Unsigned twins (cluster-id planes are 2-bit unsigned).
static ULUT2: [[u8; 4]; 256] = lut_unsigned2();
static ULUT4: [[u8; 2]; 256] = lut_unsigned4();

const fn lut_signed2() -> [[i8; 4]; 256] {
    let mut t = [[0i8; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut j = 0usize;
        while j < 4 {
            t[b][j] = (((b >> (2 * j)) & 0x3) as i16 - 2) as i8;
            j += 1;
        }
        b += 1;
    }
    t
}

const fn lut_signed4() -> [[i8; 2]; 256] {
    let mut t = [[0i8; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b][0] = ((b & 0xf) as i16 - 8) as i8;
        t[b][1] = (((b >> 4) & 0xf) as i16 - 8) as i8;
        b += 1;
    }
    t
}

const fn lut_unsigned2() -> [[u8; 4]; 256] {
    let mut t = [[0u8; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut j = 0usize;
        while j < 4 {
            t[b][j] = ((b >> (2 * j)) & 0x3) as u8;
            j += 1;
        }
        b += 1;
    }
    t
}

const fn lut_unsigned4() -> [[u8; 2]; 256] {
    let mut t = [[0u8; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b][0] = (b & 0xf) as u8;
        t[b][1] = ((b >> 4) & 0xf) as u8;
        b += 1;
    }
    t
}

/// Expand packed bytes through a per-byte LUT: whole bytes append `P`
/// codes at a time, the ragged tail takes a prefix of the last byte's
/// entry. Shared shape of the four plane-unpack fast paths.
fn unpack_via_lut<T: Copy, const P: usize>(
    bytes: &[u8],
    len: usize,
    lut: &[[T; P]; 256],
) -> Vec<T> {
    let mut out = Vec::with_capacity(len);
    let full = len / P;
    for &b in &bytes[..full] {
        out.extend_from_slice(&lut[b as usize]);
    }
    let tail = len - full * P;
    if tail > 0 {
        out.extend_from_slice(&lut[bytes[full] as usize][..tail]);
    }
    out
}

/// Bit-packed buffer of signed `bits`-wide integer codes.
#[derive(Debug, Clone, PartialEq)]
pub struct Packed {
    bits: u8,
    len: usize,
    bytes: Vec<u8>,
}

impl Packed {
    /// Pack signed codes. `bits` must be 1..=8 and each code must fit.
    pub fn pack(codes: &[i8], bits: u8) -> Result<Packed> {
        if !(1..=8).contains(&bits) {
            return Err(Error::Quant(format!("unsupported bit width {bits}")));
        }
        let qmin = -(1i16 << (bits - 1));
        let qmax = (1i16 << (bits - 1)) - 1;
        let per_byte = 8 / bits as usize;
        let nbytes = codes.len().div_ceil(per_byte);
        let mut bytes = vec![0u8; nbytes];
        let mask = ((1u16 << bits) - 1) as u8;
        for (i, &c) in codes.iter().enumerate() {
            let c16 = c as i16;
            if c16 < qmin || c16 > qmax {
                return Err(Error::Quant(format!("code {c} out of INT{bits} range")));
            }
            let biased = ((c16 - qmin) as u8) & mask;
            let byte = i / per_byte;
            let shift = (i % per_byte) as u8 * bits;
            bytes[byte] |= biased << shift;
        }
        Ok(Packed { bits, len: codes.len(), bytes })
    }

    /// Unpack back to signed codes.
    ///
    /// The 2/4/8-bit widths — the only ones on the inference hot path —
    /// take a byte-at-a-time LUT fast path (2-bit: 256→4 codes, 4-bit:
    /// 256→2), expanding 8 lanes every 2–4 byte lookups instead of one
    /// shift/mask/bias per element; this feeds the fused SIMD dequant tile
    /// ([`crate::parallel::kernels`]) and the paged executor's plane
    /// decode. Other widths use the generic loop; [`Packed::get`] is
    /// untouched. LUT == generic is property-tested below.
    pub fn unpack(&self) -> Vec<i8> {
        match self.bits {
            2 => unpack_via_lut(&self.bytes, self.len, &LUT2),
            4 => unpack_via_lut(&self.bytes, self.len, &LUT4),
            8 => self.bytes.iter().map(|&b| (b as i16 - 128) as i8).collect(),
            _ => self.unpack_generic(),
        }
    }

    /// The pre-LUT per-element unpack loop — kept as the reference the
    /// fast paths are property-tested against, and as the implementation
    /// for the off-hot-path widths.
    fn unpack_generic(&self) -> Vec<i8> {
        let per_byte = 8 / self.bits as usize;
        let qmin = -(1i16 << (self.bits - 1));
        let mask = ((1u16 << self.bits) - 1) as u8;
        let mut out = Vec::with_capacity(self.len);
        for i in 0..self.len {
            let byte = self.bytes[i / per_byte];
            let shift = (i % per_byte) as u8 * self.bits;
            let biased = (byte >> shift) & mask;
            out.push((biased as i16 + qmin) as i8);
        }
        out
    }

    /// Pack **unsigned** codes in `[0, 2^bits)` (cluster-id planes).
    pub fn pack_unsigned(codes: &[u8], bits: u8) -> Result<Packed> {
        if !(1..=8).contains(&bits) {
            return Err(Error::Quant(format!("unsupported bit width {bits}")));
        }
        let limit = if bits == 8 { 255u16 } else { (1u16 << bits) - 1 };
        let per_byte = 8 / bits as usize;
        let nbytes = codes.len().div_ceil(per_byte);
        let mut bytes = vec![0u8; nbytes];
        let mask = ((1u16 << bits) - 1) as u8;
        for (i, &c) in codes.iter().enumerate() {
            if c as u16 > limit {
                return Err(Error::Quant(format!("code {c} out of UINT{bits} range")));
            }
            let byte = i / per_byte;
            let shift = (i % per_byte) as u8 * bits;
            bytes[byte] |= (c & mask) << shift;
        }
        Ok(Packed { bits, len: codes.len(), bytes })
    }

    /// Unpack as unsigned codes (LUT fast path for the 2/4-bit cluster-id
    /// planes, byte copy for 8-bit — same contract as [`Packed::unpack`]).
    pub fn unpack_unsigned(&self) -> Vec<u8> {
        match self.bits {
            2 => unpack_via_lut(&self.bytes, self.len, &ULUT2),
            4 => unpack_via_lut(&self.bytes, self.len, &ULUT4),
            8 => self.bytes.clone(),
            _ => self.unpack_unsigned_generic(),
        }
    }

    /// Reference per-element unsigned unpack (see [`Packed::unpack_generic`]).
    fn unpack_unsigned_generic(&self) -> Vec<u8> {
        let per_byte = 8 / self.bits as usize;
        let mask = ((1u16 << self.bits) - 1) as u8;
        (0..self.len)
            .map(|i| {
                let byte = self.bytes[i / per_byte];
                let shift = (i % per_byte) as u8 * self.bits;
                (byte >> shift) & mask
            })
            .collect()
    }

    /// Read one code without unpacking everything.
    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        debug_assert!(i < self.len);
        let per_byte = 8 / self.bits as usize;
        let qmin = -(1i16 << (self.bits - 1));
        let mask = ((1u16 << self.bits) - 1) as u8;
        let byte = self.bytes[i / per_byte];
        let shift = (i % per_byte) as u8 * self.bits;
        (((byte >> shift) & mask) as i16 + qmin) as i8
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Packed storage size in bytes.
    pub fn byte_size(&self) -> usize {
        self.bytes.len()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Reconstruct from raw parts (checkpoint loading).
    pub fn from_raw(bits: u8, len: usize, bytes: Vec<u8>) -> Result<Packed> {
        let per_byte = 8 / bits as usize;
        if bytes.len() != len.div_ceil(per_byte) {
            return Err(Error::Quant(format!(
                "packed buffer size {} does not match len {len} at {bits} bits",
                bytes.len()
            )));
        }
        Ok(Packed { bits, len, bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 1..=8u8 {
            let qmin = -(1i16 << (bits - 1));
            let qmax = (1i16 << (bits - 1)) - 1;
            let codes: Vec<i8> = (qmin..=qmax).map(|v| v as i8).collect();
            let p = Packed::pack(&codes, bits).unwrap();
            assert_eq!(p.unpack(), codes, "bits={bits}");
        }
    }

    #[test]
    fn sizes_match_paper_arithmetic() {
        // 1000 FP32 params = 4000 bytes; INT2 = 250 bytes = 6.25 %.
        let codes = vec![0i8; 1000];
        let p2 = Packed::pack(&codes, 2).unwrap();
        assert_eq!(p2.byte_size(), 250);
        let p4 = Packed::pack(&codes, 4).unwrap();
        assert_eq!(p4.byte_size(), 500);
        let p8 = Packed::pack(&codes, 8).unwrap();
        assert_eq!(p8.byte_size(), 1000);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(Packed::pack(&[2], 2).is_err()); // INT2 max is 1
        assert!(Packed::pack(&[-3], 2).is_err());
        assert!(Packed::pack(&[7], 4).is_ok());
        assert!(Packed::pack(&[8], 4).is_err());
    }

    #[test]
    fn random_get_matches_unpack() {
        check("packed get == unpack", 50, |rng| {
            let bits = [2u8, 4, 8][rng.below(3)];
            let qmin = -(1i16 << (bits - 1));
            let qmax = (1i16 << (bits - 1)) - 1;
            let n = rng.range(1, 300);
            let codes: Vec<i8> = (0..n)
                .map(|_| (qmin + rng.below((qmax - qmin + 1) as usize) as i16) as i8)
                .collect();
            let p = Packed::pack(&codes, bits).unwrap();
            let un = p.unpack();
            assert_eq!(un, codes);
            for i in 0..n {
                assert_eq!(p.get(i), codes[i]);
            }
        });
    }

    #[test]
    fn property_lut_unpack_matches_generic_across_widths_and_tails() {
        // the LUT fast paths (2/4/8-bit) against the per-element reference
        // loop, over every width and ragged tail lengths
        check("LUT unpack == generic unpack", 60, |rng| {
            let bits = (rng.below(8) + 1) as u8; // 1..=8, incl. non-LUT widths
            let qmin = -(1i16 << (bits - 1));
            let qmax = (1i16 << (bits - 1)) - 1;
            let n = rng.range(1, 400); // ragged vs the 2/4-codes-per-byte expansion
            let codes: Vec<i8> = (0..n)
                .map(|_| (qmin + rng.below((qmax - qmin + 1) as usize) as i16) as i8)
                .collect();
            let p = Packed::pack(&codes, bits).unwrap();
            assert_eq!(p.unpack(), p.unpack_generic(), "bits={bits} n={n}");
            assert_eq!(p.unpack(), codes, "bits={bits} n={n}");
            let ucodes: Vec<u8> = codes.iter().map(|&c| (c as i16 - qmin) as u8).collect();
            let up = Packed::pack_unsigned(&ucodes, bits).unwrap();
            assert_eq!(up.unpack_unsigned(), up.unpack_unsigned_generic(), "u bits={bits}");
            assert_eq!(up.unpack_unsigned(), ucodes, "u bits={bits}");
        });
    }

    #[test]
    fn lut_unpack_handles_every_tail_length() {
        // deterministic sweep of all tail remainders for the LUT widths
        for bits in [2u8, 4, 8] {
            let qmin = -(1i16 << (bits - 1));
            let qmax = (1i16 << (bits - 1)) - 1;
            let span = (qmax - qmin + 1) as i16;
            for n in 0..=9usize {
                let codes: Vec<i8> =
                    (0..n).map(|i| (qmin + (i as i16 * 7) % span) as i8).collect();
                let p = Packed::pack(&codes, bits).unwrap();
                assert_eq!(p.unpack(), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn from_raw_validates_length() {
        let p = Packed::pack(&[0, 1, -1], 4).unwrap();
        let raw = p.bytes().to_vec();
        assert!(Packed::from_raw(4, 3, raw.clone()).is_ok());
        assert!(Packed::from_raw(4, 5, raw).is_err());
    }
}
