//! Numeric kernels for the pure-Rust executor.
//!
//! These mirror the JAX L2 graph op-for-op (same GELU approximation, same
//! LayerNorm epsilon placement, same additive −1e9 attention masking) so the
//! Rust executor and the PJRT executables agree to float tolerance — asserted
//! by `tests/integration_runtime.rs`.

use super::dense::{IntTensor, Tensor};

pub const NEG_INF: f32 = -1e9;

/// `C = A(m×k) @ B(k×n)`, row-major.
///
/// Size-aware dispatch: large products fan out row-partitioned over the
/// [`crate::parallel`] worker pool; everything else (and any call made from
/// inside a pool worker) runs [`matmul_serial`] on the calling thread. Both
/// engines share the same micro-kernels, so the result is identical either
/// way.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[1];
    if m >= 2 && crate::parallel::should_parallelize(2 * m * k * n) {
        return crate::parallel::kernels::matmul(a, b);
    }
    matmul_serial(a, b)
}

/// [`matmul`] with an explicit micro-kernel choice threaded through both
/// dispatch arms (pooled row partition vs serial).
pub fn matmul_with(a: &Tensor, b: &Tensor, kind: crate::parallel::KernelKind) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[1];
    if m >= 2 && crate::parallel::should_parallelize(2 * m * k * n) {
        return crate::parallel::kernels::matmul_with(a, b, kind);
    }
    matmul_serial_with(a, b, kind)
}

/// Serial `C = A(m×k) @ B(k×n)` under the process-wide kernel choice
/// ([`crate::parallel::kernel_kind`]). Both engines are bit-identical, so
/// dispatch never changes results.
pub fn matmul_serial(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_serial_with(a, b, crate::parallel::kernel_kind())
}

/// Serial matmul with an explicit micro-kernel choice (benches / engine
/// agreement tests).
///
/// Scalar engine: i–k–j loop with the k dimension unrolled 4-wide
/// (`matmul_rows`) — each pass over a C row performs 4 FMAs per element
/// against 4 consecutive B rows, amortizing the C-row load/store traffic
/// that bounds the naive i–k–j form (§Perf: 15 → ~28 GFLOP/s single-core
/// with `target-cpu=native`).
///
/// SIMD engine: B is repacked once into 8-wide panels and the rows run
/// through [`crate::tensor::simd::matmul_rows_simd`] (register
/// accumulation — C traffic drops from `k/4` passes to one). The packing
/// pass only pays for itself when it amortizes over several output rows,
/// so skinny dispatches (`m < 4` or `n < 8`, e.g. batch-1 serving
/// projections) stay on the scalar kernel — bit-identical anyway.
pub fn matmul_serial_with(a: &Tensor, b: &Tensor, kind: crate::parallel::KernelKind) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    #[cfg(feature = "simd")]
    if kind.effective() != crate::parallel::KernelKind::Scalar && m >= 4 && n >= 8 {
        // `Int8` rides the f32x8 family on plain f32×f32 matmuls — the
        // integer datapath only applies to fused quantized-weight matmuls
        let pb = super::simd::PackedB::pack(b.data(), k, n);
        super::simd::matmul_rows_simd(a.data(), &pb, &mut out, 0..m);
        return Tensor::new(&[m, n], out).unwrap();
    }
    let _ = kind; // scalar fallback (feature off, or shape below the packing payoff)
    matmul_rows(a.data(), b.data(), &mut out, 0..m, k, n);
    Tensor::new(&[m, n], out).unwrap()
}

/// Compute output rows `rows` of `A(m×k) @ B(k×n)` into `out_chunk`
/// (`rows.len() × n`, pre-zeroed). `ad` is indexed by absolute row, so
/// disjoint chunks can run concurrently — this is the **scalar** kernel
/// both the serial path and the pool tasks execute, keeping them
/// bit-identical. Its SIMD twin ([`crate::tensor::simd::matmul_rows_simd`])
/// replays the same per-element IEEE op sequence, so engine choice never
/// changes bits either.
pub(crate) fn matmul_rows(
    ad: &[f32],
    bd: &[f32],
    out_chunk: &mut [f32],
    rows: std::ops::Range<usize>,
    k: usize,
    n: usize,
) {
    let k4 = k - k % 4;
    for (ri, i) in rows.enumerate() {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut out_chunk[ri * n..(ri + 1) * n];
        let mut kk = 0;
        while kk < k4 {
            let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                kk += 4;
                continue; // padded/sparse rows (zero-mask batch slots)
            }
            let b0 = &bd[kk * n..kk * n + n];
            let b1 = &bd[(kk + 1) * n..(kk + 1) * n + n];
            let b2 = &bd[(kk + 2) * n..(kk + 2) * n + n];
            let b3 = &bd[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        for kk in k4..k {
            let av = arow[kk];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// 3-D batch of matmuls: `(B, m, k) @ (B, k, n) -> (B, m, n)`.
/// Large batches fan out over the worker pool, one task per batch slice.
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (bs, m, k) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let n = b.shape()[2];
    if bs >= 2 && crate::parallel::should_parallelize(2 * bs * m * k * n) {
        return crate::parallel::kernels::batch_matmul(a, b);
    }
    batch_matmul_serial(a, b)
}

/// Serial 3-D batch of matmuls.
pub fn batch_matmul_serial(a: &Tensor, b: &Tensor) -> Tensor {
    let (bs, m, k) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let (bs2, k2, n) = (b.shape()[0], b.shape()[1], b.shape()[2]);
    assert_eq!(bs, bs2);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; bs * m * n];
    for bi in 0..bs {
        let a2 = &a.data()[bi * m * k..(bi + 1) * m * k];
        let b2 = &b.data()[bi * k * n..(bi + 1) * k * n];
        let o2 = &mut out[bi * m * n..(bi + 1) * m * n];
        matmul_naive_into(a2, b2, o2, m, k, n);
    }
    Tensor::new(&[bs, m, n], out).unwrap()
}

/// One naive i–k–j matmul into a pre-zeroed output slice (the per-batch
/// inner loop of [`batch_matmul`], shared with the parallel engine).
pub(crate) fn matmul_naive_into(
    a2: &[f32],
    b2: &[f32],
    o2: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    for i in 0..m {
        let arow = &a2[i * k..(i + 1) * k];
        let orow = &mut o2[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b2[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `X(r×c) + bias(c)` broadcast over rows, in place.
pub fn add_bias(x: &mut Tensor, bias: &Tensor) {
    let (_r, c) = x.as_2d();
    assert_eq!(bias.numel(), c, "bias width");
    let bd = bias.data();
    for row in x.data_mut().chunks_mut(c) {
        for (v, &b) in row.iter_mut().zip(bd) {
            *v += b;
        }
    }
}

/// LayerNorm over the last dimension: `(x-µ)/√(σ²+eps) * γ + β`.
pub fn layer_norm(x: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
    let (r, c) = x.as_2d();
    assert_eq!(gamma.numel(), c);
    assert_eq!(beta.numel(), c);
    let mut out = vec![0.0f32; r * c];
    let g = gamma.data();
    let b = beta.data();
    for (orow, xrow) in out.chunks_mut(c).zip(x.data().chunks(c)) {
        let mu: f32 = xrow.iter().sum::<f32>() / c as f32;
        let var: f32 = xrow.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / c as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (o, ((&xv, &gv), &bv)) in orow.iter_mut().zip(xrow.iter().zip(g).zip(b)) {
            *o = (xv - mu) * inv * gv + bv;
        }
    }
    Tensor::new(x.shape(), out).unwrap()
}

/// GELU, tanh approximation — identical formula to the L2 graph.
/// Uses [`crate::util::fastmath::fast_tanh`] (~2e-7 abs err): the executor
/// evaluates ~1M GELUs per batch-32 forward (§Perf).
#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    0.5 * x
        * (1.0
            + crate::util::fastmath::fast_tanh(0.797_884_56_f32 * (x + 0.044715 * x * x * x)))
}

pub fn gelu(x: &Tensor) -> Tensor {
    let data = x.data().iter().map(|&v| gelu_scalar(v)).collect();
    Tensor::new(x.shape(), data).unwrap()
}

pub fn relu(x: &Tensor) -> Tensor {
    let data = x.data().iter().map(|&v| v.max(0.0)).collect();
    Tensor::new(x.shape(), data).unwrap()
}

pub fn tanh(x: &Tensor) -> Tensor {
    let data = x.data().iter().map(|&v| v.tanh()).collect();
    Tensor::new(x.shape(), data).unwrap()
}

/// Numerically-stable softmax over the last dimension.
pub fn softmax_last(x: &Tensor) -> Tensor {
    let (r, c) = x.as_2d();
    let mut out = vec![0.0f32; r * c];
    for (orow, xrow) in out.chunks_mut(c).zip(x.data().chunks(c)) {
        let mx = xrow.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0;
        for (o, &v) in orow.iter_mut().zip(xrow) {
            *o = crate::util::fastmath::fast_exp(v - mx);
            sum += *o;
        }
        let inv = 1.0 / sum;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
    Tensor::new(x.shape(), out).unwrap()
}

/// log-softmax over the last dimension (loss computation).
pub fn log_softmax_last(x: &Tensor) -> Tensor {
    let (r, c) = x.as_2d();
    let mut out = vec![0.0f32; r * c];
    for (orow, xrow) in out.chunks_mut(c).zip(x.data().chunks(c)) {
        let mx = xrow.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let lse = xrow.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
        for (o, &v) in orow.iter_mut().zip(xrow) {
            *o = v - lse;
        }
    }
    Tensor::new(x.shape(), out).unwrap()
}

/// Embedding lookup: `ids(B×L)` into `table(V×H)` → `(B×L×H)`.
pub fn embedding(table: &Tensor, ids: &IntTensor) -> Tensor {
    let (v, h) = (table.shape()[0], table.shape()[1]);
    let (b, l) = (ids.shape()[0], ids.shape()[1]);
    let mut out = vec![0.0f32; b * l * h];
    for (slot, &id) in out.chunks_mut(h).zip(ids.data()) {
        let id = id as usize;
        assert!(id < v, "token id {id} out of vocab {v}");
        slot.copy_from_slice(&table.data()[id * h..(id + 1) * h]);
    }
    Tensor::new(&[b, l, h], out).unwrap()
}

/// Transpose a 2-D tensor.
pub fn transpose2(x: &Tensor) -> Tensor {
    let (r, c) = (x.shape()[0], x.shape()[1]);
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        for j in 0..c {
            out[j * r + i] = x.at2(i, j);
        }
    }
    Tensor::new(&[c, r], out).unwrap()
}

/// 2-D convolution, NCHW × OIHW, stride 1, SAME padding (matches
/// `lax.conv_general_dilated` in the L2 CNN graph).
pub fn conv2d_same(x: &Tensor, w: &Tensor, bias: &Tensor) -> Tensor {
    let (n, ci, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (co, ci2, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(ci, ci2, "conv channel mismatch");
    assert_eq!(bias.numel(), co);
    let (ph, pw) = (kh / 2, kw / 2);
    let mut out = vec![0.0f32; n * co * h * wd];
    let xd = x.data();
    let wdat = w.data();
    for ni in 0..n {
        for oc in 0..co {
            let b = bias.data()[oc];
            for oy in 0..h {
                for ox in 0..wd {
                    let mut acc = b;
                    for ic in 0..ci {
                        for ky in 0..kh {
                            let iy = oy as isize + ky as isize - ph as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..kw {
                                let ix = ox as isize + kx as isize - pw as isize;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                let xv = xd[((ni * ci + ic) * h + iy as usize) * wd + ix as usize];
                                let wv = wdat[((oc * ci + ic) * kh + ky) * kw + kx];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[((ni * co + oc) * h + oy) * wd + ox] = acc;
                }
            }
        }
    }
    Tensor::new(&[n, co, h, wd], out).unwrap()
}

/// 2×2 max-pool, stride 2, VALID (NCHW).
pub fn maxpool2(x: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; n * c * oh * ow];
    let xd = x.data();
    for nc in 0..n * c {
        let base = nc * h * w;
        let obase = nc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let i = base + (2 * oy) * w + 2 * ox;
                let m = xd[i].max(xd[i + 1]).max(xd[i + w]).max(xd[i + w + 1]);
                out[obase + oy * ow + ox] = m;
            }
        }
    }
    Tensor::new(&[n, c, oh, ow], out).unwrap()
}

/// Eval-mode batch norm over channel dim of NCHW.
pub fn batch_norm_eval(
    x: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    mean: &Tensor,
    var: &Tensor,
    eps: f32,
) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = vec![0.0f32; n * c * h * w];
    for ni in 0..n {
        for ci in 0..c {
            let inv = 1.0 / (var.data()[ci] + eps).sqrt();
            let g = gamma.data()[ci];
            let b = beta.data()[ci];
            let m = mean.data()[ci];
            let base = (ni * c + ci) * h * w;
            for idx in 0..h * w {
                out[base + idx] = (x.data()[base + idx] - m) * inv * g + b;
            }
        }
    }
    Tensor::new(x.shape(), out).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]).unwrap();
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Tensor::randn(&[5, 7], 0.0, 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[7, 7]);
        for i in 0..7 {
            eye.data_mut()[i * 7 + i] = 1.0;
        }
        let c = matmul(&a, &eye);
        assert!(a.max_abs_diff(&c) < 1e-6);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::new(&[2, 3], vec![1., 2., 3., -1., 0., 1.]).unwrap();
        let s = softmax_last(&x);
        for row in s.data().chunks(3) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_handles_neg_inf_mask() {
        let x = Tensor::new(&[1, 3], vec![0.0, NEG_INF, 0.0]).unwrap();
        let s = softmax_last(&x);
        assert!((s.data()[0] - 0.5).abs() < 1e-6);
        assert!(s.data()[1] < 1e-12);
    }

    #[test]
    fn layernorm_standardizes() {
        let x = Tensor::new(&[1, 4], vec![1., 2., 3., 4.]).unwrap();
        let g = Tensor::ones(&[4]);
        let b = Tensor::zeros(&[4]);
        let y = layer_norm(&x, &g, &b, 1e-12);
        let m: f32 = y.data().iter().sum::<f32>() / 4.0;
        let v: f32 = y.data().iter().map(|&v| (v - m) * (v - m)).sum::<f32>() / 4.0;
        assert!(m.abs() < 1e-5);
        assert!((v - 1.0).abs() < 1e-4);
    }

    #[test]
    fn gelu_reference_points() {
        assert!(gelu_scalar(0.0).abs() < 1e-7);
        assert!((gelu_scalar(1.0) - 0.8411).abs() < 1e-3);
        assert!((gelu_scalar(-1.0) + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn embedding_lookup() {
        let table = Tensor::new(&[3, 2], vec![0., 0., 1., 1., 2., 2.]).unwrap();
        let ids = IntTensor::new(&[1, 3], vec![2, 0, 1]).unwrap();
        let e = embedding(&table, &ids);
        assert_eq!(e.shape(), &[1, 3, 2]);
        assert_eq!(e.data(), &[2., 2., 0., 0., 1., 1.]);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1x3x3 input, identity 3x3 kernel (center 1) reproduces input
        let x = Tensor::new(&[1, 1, 3, 3], (1..=9).map(|v| v as f32).collect()).unwrap();
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        w.data_mut()[4] = 1.0;
        let b = Tensor::zeros(&[1]);
        let y = conv2d_same(&x, &w, &b);
        assert!(x.max_abs_diff(&y) < 1e-6);
    }

    #[test]
    fn maxpool_picks_max() {
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 5., 3., 2.]).unwrap();
        let y = maxpool2(&x);
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.data()[0], 5.0);
    }

    #[test]
    fn bn_eval_identity_params() {
        let x = Tensor::new(&[1, 2, 1, 1], vec![3.0, -1.0]).unwrap();
        let ones = Tensor::ones(&[2]);
        let zeros = Tensor::zeros(&[2]);
        let y = batch_norm_eval(&x, &ones, &zeros, &zeros, &Tensor::full(&[2], 1.0), 0.0);
        assert!(x.max_abs_diff(&y) < 1e-6);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(2);
        let a = Tensor::randn(&[4, 6], 0.0, 1.0, &mut rng);
        let t = transpose2(&transpose2(&a));
        assert!(a.max_abs_diff(&t) < 1e-7);
    }

    #[test]
    fn property_simd_serial_is_bit_identical_to_scalar_serial() {
        use crate::parallel::KernelKind;
        crate::util::proptest::check("simd serial == scalar serial (exact)", 40, |rng| {
            let m = rng.range(1, 34);
            let k = rng.range(1, 41); // includes k % 4 != 0
            let n = rng.range(1, 35); // includes n % 8 != 0
            let vals = crate::util::proptest::gen_values_with_outliers(rng, m * k, 0.05);
            let mut a = Tensor::new(&[m, k], vals).unwrap();
            // zero whole rows: the quad zero-skip must agree across engines
            for i in 0..m {
                if rng.chance(0.3) {
                    for v in &mut a.data_mut()[i * k..(i + 1) * k] {
                        *v = 0.0;
                    }
                }
            }
            let b = Tensor::new(
                &[k, n],
                crate::util::proptest::gen_values_with_outliers(rng, k * n, 0.05),
            )
            .unwrap();
            let scalar = matmul_serial_with(&a, &b, KernelKind::Scalar);
            let simd = matmul_serial_with(&a, &b, KernelKind::Simd);
            assert_eq!(scalar.data(), simd.data(), "engines diverged at {m}x{k}x{n}");
        });
    }

    #[test]
    fn batch_matmul_matches_loop() {
        let mut rng = crate::util::rng::Rng::new(3);
        let a = Tensor::randn(&[2, 3, 4], 0.0, 1.0, &mut rng);
        let b = Tensor::randn(&[2, 4, 5], 0.0, 1.0, &mut rng);
        let c = batch_matmul(&a, &b);
        for bi in 0..2 {
            let a2 = Tensor::new(&[3, 4], a.data()[bi * 12..(bi + 1) * 12].to_vec()).unwrap();
            let b2 = Tensor::new(&[4, 5], b.data()[bi * 20..(bi + 1) * 20].to_vec()).unwrap();
            let exp = matmul(&a2, &b2);
            let got = &c.data()[bi * 15..(bi + 1) * 15];
            for (g, e) in got.iter().zip(exp.data()) {
                assert!((g - e).abs() < 1e-5);
            }
        }
    }
}
