//! Dense tensors, ops and bit-packed storage — the numeric substrate for the
//! pure-Rust executor and the quantization engine.

pub mod dense;
pub mod ops;
pub mod packing;
#[cfg(feature = "simd")]
pub mod simd;

pub use dense::{IntTensor, Tensor};
