//! ASCII / markdown table rendering for benchmark and CLI output, plus the
//! machine-readable bench-record sidecar ([`bench_json`]).

pub mod bench_json;

/// A simple aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavoured markdown (EXPERIMENTS.md sections).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format an accuracy as the paper does: `89.8%`.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format a signed delta in percentage points: `+3.3%p`.
pub fn pct_delta(x: f64) -> String {
    format!("{:+.1}%p", x * 100.0)
}

/// Format a byte count.
pub fn bytes(n: usize) -> String {
    if n >= 1 << 20 {
        format!("{:.2} MiB", n as f64 / (1 << 20) as f64)
    } else if n >= 1 << 10 {
        format!("{:.1} KiB", n as f64 / 1024.0)
    } else {
        format!("{n} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["wide cell".into(), "x".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header and rows aligned to same width
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.starts_with("| x | y |\n|---|---|\n| 1 | 2 |\n"));
    }

    #[test]
    fn formatters() {
        assert_eq!(pct(0.898), "89.8%");
        assert_eq!(pct_delta(0.033), "+3.3%p");
        assert_eq!(pct_delta(-0.001), "-0.1%p");
        assert_eq!(bytes(100), "100 B");
        assert_eq!(bytes(2048), "2.0 KiB");
    }
}
