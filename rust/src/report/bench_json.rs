//! Machine-readable benchmark records — `BENCH_kernels.json`.
//!
//! The bench binaries print human tables; this sidecar gives CI and later
//! PRs something diffable: a flat JSON array of rows, each keyed by
//! `(bench, shape, engine)` with `ns_per_iter` and a streaming `gb_per_s`
//! rate (total bytes read + written per iteration over wall time — an
//! engine-neutral figure that is meaningful for both FLOP-bound matmuls
//! and byte-bound serving rows). Re-running a bench **merges** by key into
//! the existing file, so `kernel_hotpath` and `serving` can share one
//! `BENCH_kernels.json` and a partial re-run never loses the other rows.

use std::path::Path;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::json::{obj, Json};

/// One benchmark row. `extra` carries bench-specific metrics (`gflops`,
/// `qps`, …) that land as additional JSON fields.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub bench: String,
    pub shape: String,
    pub engine: String,
    pub ns_per_iter: f64,
    pub gb_per_s: f64,
    pub extra: Vec<(String, f64)>,
}

impl BenchRecord {
    /// Build a row from a per-iteration wall time and the bytes one
    /// iteration streams (inputs read + outputs written).
    pub fn new(
        bench: &str,
        shape: &str,
        engine: &str,
        per_iter: Duration,
        bytes_per_iter: usize,
    ) -> BenchRecord {
        let secs = per_iter.as_secs_f64();
        BenchRecord {
            bench: bench.to_string(),
            shape: shape.to_string(),
            engine: engine.to_string(),
            ns_per_iter: per_iter.as_nanos() as f64,
            gb_per_s: if secs > 0.0 { bytes_per_iter as f64 / secs / 1e9 } else { 0.0 },
            extra: Vec::new(),
        }
    }

    /// Attach a bench-specific metric (builder style).
    pub fn with(mut self, key: &str, value: f64) -> BenchRecord {
        self.extra.push((key.to_string(), value));
        self
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("bench", Json::from(self.bench.as_str())),
            ("shape", Json::from(self.shape.as_str())),
            ("engine", Json::from(self.engine.as_str())),
            ("ns_per_iter", Json::from(self.ns_per_iter)),
            ("gb_per_s", Json::from(self.gb_per_s)),
        ];
        for (k, v) in &self.extra {
            pairs.push((k.as_str(), Json::from(*v)));
        }
        obj(pairs)
    }
}

fn row_key(j: &Json) -> Option<(String, String, String)> {
    Some((
        j.get("bench").ok()?.as_str().ok()?.to_string(),
        j.get("shape").ok()?.as_str().ok()?.to_string(),
        j.get("engine").ok()?.as_str().ok()?.to_string(),
    ))
}

/// Merge `records` into the JSON array at `path` (replace rows with the
/// same `(bench, shape, engine)` key, append new ones, keep the rest) and
/// write it back. A missing or malformed file starts fresh.
pub fn merge_write(path: &Path, records: &[BenchRecord]) -> Result<()> {
    let mut entries: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_arr().map(|a| a.to_vec()).ok())
        .unwrap_or_default();
    for r in records {
        let k = (r.bench.clone(), r.shape.clone(), r.engine.clone());
        let j = r.to_json();
        if let Some(slot) = entries.iter_mut().find(|e| row_key(e).as_ref() == Some(&k)) {
            *slot = j;
        } else {
            entries.push(j);
        }
    }
    std::fs::write(path, Json::Arr(entries).to_string()).map_err(Error::Io)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_computes_rates_and_serializes() {
        let r = BenchRecord::new("matmul", "512x512x512", "pool8-simd",
            Duration::from_millis(10), 3 * 512 * 512 * 4)
            .with("gflops", 26.8);
        assert!((r.ns_per_iter - 1e7).abs() < 1.0);
        assert!(r.gb_per_s > 0.0);
        let j = r.to_json();
        assert_eq!(j.get("engine").unwrap().as_str().unwrap(), "pool8-simd");
        assert!((j.get("gflops").unwrap().as_f64().unwrap() - 26.8).abs() < 1e-9);
    }

    #[test]
    fn merge_write_is_byte_identical_across_reruns() {
        // the determinism contract on serialized artifacts (sq-lint's
        // `deterministic-iteration` rule guards the code side): key order
        // comes from the BTreeMap-backed `Json::Obj`, row order from merge
        // insertion order — so the same records must produce the same bytes
        let p1 = std::env::temp_dir().join("sq_bench_json_det_1.json");
        let p2 = std::env::temp_dir().join("sq_bench_json_det_2.json");
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
        let rows = vec![
            BenchRecord::new("m", "s1", "scalar", Duration::from_micros(5), 1000)
                .with("gflops", 1.25),
            BenchRecord::new("m", "s1", "simd", Duration::from_micros(2), 1000),
            BenchRecord::new("serve", "b8", "pool", Duration::from_micros(9), 4096)
                .with("qps", 800.0),
        ];
        merge_write(&p1, &rows).unwrap();
        merge_write(&p2, &rows).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        assert_eq!(b1, std::fs::read(&p2).unwrap(), "fresh writes differ");
        // re-merging the same rows into an existing file is a byte-level noop
        merge_write(&p1, &rows).unwrap();
        assert_eq!(b1, std::fs::read(&p1).unwrap(), "re-merge changed bytes");
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn merge_replaces_by_key_and_appends_new() {
        let path = std::env::temp_dir().join("sq_bench_json_merge.json");
        std::fs::remove_file(&path).ok();
        let a = BenchRecord::new("m", "s1", "scalar", Duration::from_micros(5), 1000);
        let b = BenchRecord::new("m", "s1", "simd", Duration::from_micros(2), 1000);
        merge_write(&path, &[a.clone(), b]).unwrap();
        // re-run of one row replaces it in place, the other row survives
        let a2 = BenchRecord::new("m", "s1", "scalar", Duration::from_micros(4), 1000);
        merge_write(&path, &[a2]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let scalar = arr
            .iter()
            .find(|e| e.get("engine").unwrap().as_str().unwrap() == "scalar")
            .unwrap();
        assert!((scalar.get("ns_per_iter").unwrap().as_f64().unwrap() - 4000.0).abs() < 1.0);
        // a malformed file starts fresh instead of erroring
        std::fs::write(&path, "not json").unwrap();
        merge_write(&path, &[a]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 1);
        std::fs::remove_file(&path).ok();
    }
}
