//! The `sq-lint` rule engine: repo-specific invariant checks over the
//! token stream of [`super::lexer`], with per-module scoping and a
//! `// sq-lint: allow(<rule>) — <reason>` escape hatch.
//!
//! Every rule machine-checks a contract the repo otherwise states only in
//! doc comments and property tests:
//!
//! | rule | contract |
//! |------|----------|
//! | `no-fma` | bit-identity: no `mul_add`/`fma` in the kernel files — an FMA rounds once where the engines must round per op |
//! | `no-nested-dispatch` | no pooled kernel entry point called lexically inside a `WorkerPool::scope(...)` argument — nested dispatch would deadlock or silently serialize |
//! | `deterministic-iteration` | no `HashMap`/`HashSet` iteration in `autotune/`, `quant/`, `report/`, `qhealth/`, where ordering leaks into serialized `BitPlan`/bench artifacts |
//! | `no-panic-in-serving` | no `unwrap()`/`expect(`/`panic!` (and, under `coordinator/` + `shardstore/`, no `[idx]` indexing) in non-test serving code |
//! | `safety-comment` | every `unsafe` token carries a `// SAFETY:` comment immediately above (or trailing on the same line) |
//! | `lock-across-io` | no lock guard held across file IO or pooled dispatch (deadlock/stall heuristic for the shard-fault path) |
//! | `no-timing-in-kernels` | overhead budget: no `Instant` / `trace::` emission in the micro-kernel files (`tensor/`: whole file; `parallel/kernels.rs`: loop bodies — its dispatch prologue may arm chunk spans) |
//! | `bounded-retry` | fault-tolerance contract: an unconditional loop in `coordinator/`/`shardstore/` that re-reads or retries must mention an attempt cap — unbounded retry turns one bad shard into a hung request |
//!
//! Scoping notes (deliberate, documented here and in ROADMAP):
//! * `no-panic-in-serving`'s indexing facet covers `coordinator/` and
//!   `shardstore/` only — the kernels under `parallel/` index raw output
//!   buffers in their innermost loops by design (shape-checked at entry),
//!   and annotating every hot-loop subscript would bury real findings.
//!   The `unwrap`/`expect`/`panic!` facet still covers `parallel/`.
//! * `lock-across-io` treats `util::sync::lock_recover` exactly like
//!   `.lock()` — poison recovery does not change what the guard holds.
//! * `no-timing-in-kernels` keys on chunk granularity: span guards armed in
//!   a dispatcher's *prologue* cost one relaxed load per chunk and are
//!   allowed (with an annotation in `parallel/kernels.rs`, whose task
//!   closures sit lexically inside the partition loop); a clock read or
//!   trace emission in an inner loop would run per element and is not.
//! * `deterministic-iteration` also covers `trace/` (the exporters) and
//!   `qhealth/` (the numeric-health recorder): the Chrome/Prometheus text,
//!   the `doctor` report and the `qhealth-*` bench rows must all be
//!   byte-deterministic for a given snapshot, so map iteration there must
//!   be ordered.
//!
//! An allow comment must be a `//` line comment, name a real rule, and
//! carry a reason after the closing paren; a malformed one is itself a
//! finding (`allow-syntax`), so typos cannot silently disable a check.

use super::lexer::{lex, test_regions, Comment, LexFile, TokKind, Token};

/// Rule identifiers (stable strings: used in allow comments and CI logs).
pub const RULE_NO_FMA: &str = "no-fma";
pub const RULE_NESTED_DISPATCH: &str = "no-nested-dispatch";
pub const RULE_DET_ITER: &str = "deterministic-iteration";
pub const RULE_NO_PANIC: &str = "no-panic-in-serving";
pub const RULE_SAFETY: &str = "safety-comment";
pub const RULE_LOCK_IO: &str = "lock-across-io";
pub const RULE_NO_TIMING: &str = "no-timing-in-kernels";
pub const RULE_BOUNDED_RETRY: &str = "bounded-retry";
pub const RULE_ALLOW_SYNTAX: &str = "allow-syntax";

/// `(name, one-line description)` for every shipped rule, in report order.
pub const RULES: &[(&str, &str)] = &[
    (RULE_NO_FMA, "mul_add/fma banned in kernel files (bit-identity contract)"),
    (RULE_NESTED_DISPATCH, "pooled kernel call inside a WorkerPool scope(...) argument"),
    (RULE_DET_ITER, "HashMap/HashSet iteration in autotune/, quant/, report/, qhealth/"),
    (RULE_NO_PANIC, "unwrap/expect/panic!/[idx] in non-test serving code"),
    (RULE_SAFETY, "unsafe without an immediately-preceding // SAFETY: comment"),
    (RULE_LOCK_IO, "lock guard held across file IO or pooled dispatch"),
    (RULE_NO_TIMING, "Instant/trace emission inside micro-kernel code (overhead budget)"),
    (RULE_BOUNDED_RETRY, "unconditional retry loop with no visible attempt cap"),
    (RULE_ALLOW_SYNTAX, "malformed or unknown sq-lint allow comment"),
];

/// Files under the bit-identity contract (relative to the lint root).
const FMA_FILES: &[&str] = &["tensor/simd.rs", "tensor/ops.rs", "parallel/kernels.rs"];

/// Micro-kernel files where any `Instant` / `trace::` token is a
/// `no-timing-in-kernels` finding — these hold only inner loops.
const TIMING_WHOLE_FILE: &[&str] = &["tensor/simd.rs", "tensor/ops.rs"];

/// Dispatcher files where the rule fires only inside loop bodies: the
/// prologue may arm chunk-granularity spans, the partition/FMA loops may
/// not touch the clock.
const TIMING_LOOPS_ONLY: &[&str] = &["parallel/kernels.rs"];

/// Pool-dispatching kernel entry points (exact identifier match — note
/// `matmul_rows` and friends are micro-kernels, not dispatchers, and must
/// NOT appear here).
const POOLED: &[&str] = &[
    "matmul",
    "matmul_with",
    "batch_matmul",
    "split_matmul",
    "split_matmul_with",
    "split_matmul_pooled",
    "split_matmul_pooled_with",
    "split_matmul_int8",
    "matmul_fused",
];

/// Identifiers that mean "this statement performs file IO".
const IO_IDENTS: &[&str] = &[
    "read_exact",
    "read_to_end",
    "read_to_string",
    "seek",
    "write_all",
    "sync_all",
    "flush",
    "File",
    "OpenOptions",
    "create_dir_all",
    "remove_file",
    "remove_dir_all",
    "rename",
    "fs",
];

/// Identifiers that mean "this loop body performs a read that could be a
/// retry" (`bounded-retry` rule). Exact identifier match — `fetch_add` and
/// friends lex as single tokens and do not trip `fetch`.
const RETRY_TRIGGERS: &[&str] = &[
    "read",
    "read_raw",
    "read_exact",
    "read_verified",
    "fetch",
    "retry",
    "attempt",
    "reread",
];

/// Identifiers whose presence in the same loop body signals a visible
/// attempt bound (`bounded-retry` rule). Heuristic by design: the rule asks
/// that a retry loop *name* its cap, not that the lint prove termination.
const RETRY_CAPS: &[&str] =
    &["max", "max_attempts", "attempts", "cap", "limit", "budget", "tried"];

/// Map-iteration adaptors whose order is the map's internal order.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// One lint finding. `allowed` is set when a well-formed
/// `sq-lint: allow` comment covers the finding's rule and line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
    pub allowed: bool,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let tag = if self.allowed { " (allowed)" } else { "" };
        write!(f, "{}:{}: [{}] {}{}", self.path, self.line, self.rule, self.msg, tag)
    }
}

/// A parsed, well-formed allow comment.
#[derive(Debug)]
struct Allow {
    rule: String,
    /// Source lines this allow suppresses: its own line (trailing form) or
    /// the next line that has any token (own-line form).
    covers: Vec<usize>,
}

struct Ctx<'a> {
    rel: &'a str,
    lex: &'a LexFile,
    tests: Vec<(usize, usize)>,
}

impl<'a> Ctx<'a> {
    fn toks(&self) -> &[Token] {
        &self.lex.tokens
    }

    fn in_test(&self, idx: usize) -> bool {
        self.tests.iter().any(|&(s, e)| idx >= s && idx < e)
    }

    fn in_dir(&self, dirs: &[&str]) -> bool {
        dirs.iter().any(|d| self.rel.starts_with(d))
    }

    fn finding(&self, rule: &'static str, line: usize, msg: String) -> Finding {
        Finding { rule, path: self.rel.to_string(), line, msg, allowed: false }
    }
}

/// Index of the matching closer for the opener at `open_idx` (whose text
/// must be `open`). Returns `toks.len()` if unbalanced.
fn match_close(toks: &[Token], open_idx: usize, open: &str, close: &str) -> usize {
    let mut depth = 1usize;
    let mut j = open_idx + 1;
    while j < toks.len() {
        if toks[j].is_punct(open) {
            depth += 1;
        } else if toks[j].is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    toks.len()
}

/// Index of the first token of the statement containing `idx` (the token
/// after the nearest preceding `;`, `{` or `}`).
fn statement_start(toks: &[Token], idx: usize) -> usize {
    let mut j = idx;
    while j > 0 {
        let t = &toks[j - 1];
        if t.is_punct(";") || t.is_punct("{") || t.is_punct("}") {
            return j;
        }
        j -= 1;
    }
    0
}

/// Index just past the statement containing `idx`: the first `;` at
/// bracket depth 0, or the closing `}` of the enclosing block.
fn statement_end(toks: &[Token], idx: usize) -> usize {
    let mut depth = 0isize;
    let mut j = idx;
    while j < toks.len() {
        let t = &toks[j];
        if t.is_punct("(") || t.is_punct("[") || t.is_punct("{") {
            depth += 1;
        } else if t.is_punct(")") || t.is_punct("]") {
            depth -= 1;
        } else if t.is_punct("}") {
            if depth == 0 {
                return j;
            }
            depth -= 1;
        } else if t.is_punct(";") && depth <= 0 {
            return j;
        }
        j += 1;
    }
    toks.len()
}

/// Index of the `}` closing the innermost block containing `idx`.
fn enclosing_block_end(toks: &[Token], idx: usize) -> usize {
    let mut depth = 0isize;
    let mut j = idx + 1;
    while j < toks.len() {
        if toks[j].is_punct("{") {
            depth += 1;
        } else if toks[j].is_punct("}") {
            if depth == 0 {
                return j;
            }
            depth -= 1;
        }
        j += 1;
    }
    toks.len()
}

fn prev_is(toks: &[Token], idx: usize, text: &str) -> bool {
    idx > 0 && (toks[idx - 1].is_punct(text) || toks[idx - 1].is_ident(text))
}

fn next_is_punct(toks: &[Token], idx: usize, text: &str) -> bool {
    toks.get(idx + 1).is_some_and(|t| t.is_punct(text))
}

// ---------------------------------------------------------------- rules --

fn rule_no_fma(ctx: &Ctx, out: &mut Vec<Finding>) {
    if !FMA_FILES.contains(&ctx.rel) {
        return;
    }
    for t in ctx.toks() {
        if t.kind == TokKind::Ident && (t.text == "mul_add" || t.text == "fma") {
            out.push(ctx.finding(
                RULE_NO_FMA,
                t.line,
                format!(
                    "`{}` breaks the bit-identity contract: an FMA rounds once where \
                     every engine must round per IEEE op",
                    t.text
                ),
            ));
        }
    }
}

fn rule_nested_dispatch(ctx: &Ctx, out: &mut Vec<Finding>) {
    let toks = ctx.toks();
    for i in 0..toks.len() {
        if !(toks[i].is_ident("scope") && next_is_punct(toks, i, "(") && !prev_is(toks, i, "fn"))
        {
            continue;
        }
        let close = match_close(toks, i + 1, "(", ")");
        for j in i + 2..close {
            if toks[j].kind == TokKind::Ident
                && POOLED.contains(&toks[j].text.as_str())
                && next_is_punct(toks, j, "(")
                && !prev_is(toks, j, "fn")
            {
                out.push(ctx.finding(
                    RULE_NESTED_DISPATCH,
                    toks[j].line,
                    format!(
                        "pooled `{}` called inside a WorkerPool `scope(...)` argument — \
                         nested dispatch deadlocks or silently serializes",
                        toks[j].text
                    ),
                ));
            }
        }
    }
}

fn rule_det_iter(ctx: &Ctx, out: &mut Vec<Finding>) {
    if !ctx.in_dir(&["autotune/", "quant/", "report/", "trace/", "qhealth/"]) {
        return;
    }
    let toks = ctx.toks();
    // pass 1: names bound (let / field / param) to a HashMap or HashSet
    let mut maps: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if !(toks[i].is_ident("HashMap") || toks[i].is_ident("HashSet")) {
            continue;
        }
        let stmt = statement_start(toks, i);
        // nearest binder marker (`=` of a let, or the `:` of an annotation)
        // walking back from the type name
        let mut j = i;
        while j > stmt {
            j -= 1;
            let t = &toks[j];
            let single_eq = t.is_punct("=")
                && !next_is_punct(toks, j, "=")
                && !next_is_punct(toks, j, ">")
                && !(j > 0
                    && matches!(
                        toks[j - 1].text.as_str(),
                        "=" | "<" | ">" | "!" | "+" | "-" | "*" | "/" | "%" | "&" | "|" | "^"
                    ));
            let single_colon =
                t.is_punct(":") && !next_is_punct(toks, j, ":") && !prev_is(toks, j, ":");
            if single_eq || single_colon {
                if j > 0 && toks[j - 1].kind == TokKind::Ident {
                    let name = toks[j - 1].text.clone();
                    if !maps.contains(&name) {
                        maps.push(name);
                    }
                }
                break;
            }
        }
    }
    if maps.is_empty() {
        return;
    }
    // pass 2a: `name.iter()` / `.keys()` / … method chains
    for i in 0..toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        let t = &toks[i];
        if t.kind == TokKind::Ident
            && maps.iter().any(|m| m == &t.text)
            && next_is_punct(toks, i, ".")
            && toks
                .get(i + 2)
                .is_some_and(|m| m.kind == TokKind::Ident && ITER_METHODS.contains(&m.text.as_str()))
            && next_is_punct(toks, i + 2, "(")
        {
            out.push(ctx.finding(
                RULE_DET_ITER,
                t.line,
                format!(
                    "`{}.{}()` iterates a HashMap/HashSet — ordering is nondeterministic \
                     and leaks into serialized artifacts; use BTreeMap or sort first",
                    t.text,
                    toks[i + 2].text
                ),
            ));
        }
    }
    // pass 2b: `for … in <expr mentioning a map> {`
    for i in 0..toks.len() {
        if !toks[i].is_ident("for") || ctx.in_test(i) {
            continue;
        }
        // find the `in` of this for-loop header (skip pattern parens)
        let mut j = i + 1;
        let mut depth = 0isize;
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct("(") || t.is_punct("[") {
                depth += 1;
            } else if t.is_punct(")") || t.is_punct("]") {
                depth -= 1;
            } else if t.is_ident("in") && depth == 0 {
                break;
            } else if t.is_punct("{") || t.is_punct(";") {
                j = toks.len(); // not a for-loop header we understand
            }
            j += 1;
        }
        if j >= toks.len() {
            continue;
        }
        // header runs to the body `{` at depth 0
        let mut k = j + 1;
        depth = 0;
        while k < toks.len() {
            let t = &toks[k];
            if t.is_punct("(") || t.is_punct("[") {
                depth += 1;
            } else if t.is_punct(")") || t.is_punct("]") {
                depth -= 1;
            } else if t.is_punct("{") && depth == 0 {
                break;
            }
            // a called ident (`store.names()`) yields its *return* value —
            // only a bare map name iterates the map itself
            if t.kind == TokKind::Ident
                && maps.iter().any(|m| m == &t.text)
                && !next_is_punct(toks, k, "(")
            {
                out.push(ctx.finding(
                    RULE_DET_ITER,
                    t.line,
                    format!(
                        "`for … in` over HashMap/HashSet `{}` — ordering is \
                         nondeterministic; use BTreeMap or sort first",
                        t.text
                    ),
                ));
            }
            k += 1;
        }
    }
}

fn rule_no_panic(ctx: &Ctx, out: &mut Vec<Finding>) {
    if !ctx.in_dir(&["coordinator/", "shardstore/", "parallel/"]) {
        return;
    }
    let toks = ctx.toks();
    for i in 0..toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        let t = &toks[i];
        if (t.is_ident("unwrap") || t.is_ident("expect"))
            && prev_is(toks, i, ".")
            && next_is_punct(toks, i, "(")
        {
            out.push(ctx.finding(
                RULE_NO_PANIC,
                t.line,
                format!(
                    "`.{}()` in serving code — return an Error (or lock_recover for \
                     mutexes), or allow-annotate if provably infallible",
                    t.text
                ),
            ));
        } else if t.is_ident("panic") && next_is_punct(toks, i, "!") {
            out.push(ctx.finding(
                RULE_NO_PANIC,
                t.line,
                "`panic!` in serving code — return an Error, or allow-annotate with the \
                 invariant that makes this unreachable"
                    .to_string(),
            ));
        }
    }
    // indexing facet: coordinator/ + shardstore/ only (parallel/ kernels
    // index raw buffers in hot loops by design — see module docs)
    if !ctx.in_dir(&["coordinator/", "shardstore/"]) {
        return;
    }
    for i in 0..toks.len() {
        if ctx.in_test(i) || !toks[i].is_punct("[") || i == 0 {
            continue;
        }
        let p = &toks[i - 1];
        // an index expression follows a value (ident or closing bracket);
        // `let [a] = …` slice patterns follow the `let` keyword instead
        let indexes = (p.kind == TokKind::Ident && p.text != "let")
            || p.is_punct(")")
            || p.is_punct("]");
        if !indexes {
            continue;
        }
        let close = match_close(toks, i, "[", "]");
        let mut has_range = false;
        let mut j = i + 1;
        while j + 1 < close {
            if toks[j].is_punct(".") && toks[j + 1].is_punct(".") {
                has_range = true;
                break;
            }
            j += 1;
        }
        if !has_range {
            out.push(ctx.finding(
                RULE_NO_PANIC,
                toks[i].line,
                "`[idx]` indexing in serving code can panic — use .get() with an Error, \
                 or allow-annotate the bound that holds"
                    .to_string(),
            ));
        }
    }
}

fn rule_safety(ctx: &Ctx, out: &mut Vec<Finding>) {
    let toks = ctx.toks();
    for t in toks {
        if !t.is_ident("unsafe") {
            continue;
        }
        let line = t.line;
        let covered = ctx.lex.comments.iter().any(|c| {
            if !c.text.contains("SAFETY:") {
                return false;
            }
            if c.line == line {
                return true; // trailing on the same line
            }
            // immediately above: no *token* line strictly between the
            // comment's end and the unsafe token (comments/blanks are fine)
            c.end_line < line
                && !toks.iter().any(|o| o.line > c.end_line && o.line < line)
        });
        if !covered {
            out.push(ctx.finding(
                RULE_SAFETY,
                line,
                "`unsafe` without an immediately-preceding `// SAFETY:` comment stating \
                 the invariant it relies on"
                    .to_string(),
            ));
        }
    }
}

fn rule_lock_io(ctx: &Ctx, out: &mut Vec<Finding>) {
    if !ctx.in_dir(&["coordinator/", "shardstore/", "model/", "runtime/"]) {
        return;
    }
    let toks = ctx.toks();
    for i in 0..toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        let t = &toks[i];
        let is_lock = (t.is_ident("lock") && prev_is(toks, i, ".") && next_is_punct(toks, i, "("))
            || (t.is_ident("lock_recover") && next_is_punct(toks, i, "("));
        if !is_lock {
            continue;
        }
        let stmt = statement_start(toks, i);
        let let_bound = toks[stmt].is_ident("let");
        // a let-bound guard lives to the end of the enclosing block; a
        // statement-level temporary only to the end of its statement
        let end = if let_bound {
            enclosing_block_end(toks, i)
        } else {
            statement_end(toks, i)
        };
        for j in i + 1..end.min(toks.len()) {
            let o = &toks[j];
            let io = o.kind == TokKind::Ident && IO_IDENTS.contains(&o.text.as_str());
            let dispatch = o.kind == TokKind::Ident
                && next_is_punct(toks, j, "(")
                && (POOLED.contains(&o.text.as_str())
                    || (o.text == "scope" && prev_is(toks, j, ".")));
            if io || dispatch {
                out.push(ctx.finding(
                    RULE_LOCK_IO,
                    t.line,
                    format!(
                        "lock guard held across `{}` (line {}) — IO or pooled dispatch \
                         under a lock stalls every other locker; drop the guard first",
                        o.text, o.line
                    ),
                ));
                break;
            }
        }
    }
}

fn rule_bounded_retry(ctx: &Ctx, out: &mut Vec<Finding>) {
    if !ctx.in_dir(&["coordinator/", "shardstore/"]) {
        return;
    }
    let toks = ctx.toks();
    for i in 0..toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        let t = &toks[i];
        // unconditional loops only: `loop { … }` and `while true { … }`.
        // A `while cond` / `for` loop has a data-driven exit and is not a
        // retry-bound concern.
        let body_open = if t.is_ident("loop") && next_is_punct(toks, i, "{") {
            i + 1
        } else if t.is_ident("while")
            && toks.get(i + 1).is_some_and(|o| o.is_ident("true"))
            && next_is_punct(toks, i + 1, "{")
        {
            i + 2
        } else {
            continue;
        };
        let close = match_close(toks, body_open, "{", "}");
        let mut trigger: Option<&Token> = None;
        let mut capped = false;
        for o in toks.iter().take(close).skip(body_open + 1) {
            if o.kind != TokKind::Ident {
                continue;
            }
            if trigger.is_none() && RETRY_TRIGGERS.contains(&o.text.as_str()) {
                trigger = Some(o);
            }
            if RETRY_CAPS.contains(&o.text.as_str()) {
                capped = true;
                break;
            }
        }
        if let (Some(tr), false) = (trigger, capped) {
            out.push(ctx.finding(
                RULE_BOUNDED_RETRY,
                t.line,
                format!(
                    "unconditional loop re-reads (`{}`, line {}) with no visible attempt \
                     cap — bound it (RetryPolicy-style max_attempts) or allow-annotate \
                     the exit that makes it finite",
                    tr.text, tr.line
                ),
            ));
        }
    }
}

/// True when the `for` at `idx` heads a for-loop (a depth-0 `in` appears
/// before the body `{`), as opposed to `impl Trait for Type` or an HRTB
/// `for<'a>` binder.
fn for_loop_header(toks: &[Token], idx: usize) -> bool {
    let mut depth = 0isize;
    let mut j = idx + 1;
    while j < toks.len() {
        let t = &toks[j];
        if t.is_punct("(") || t.is_punct("[") {
            depth += 1;
        } else if t.is_punct(")") || t.is_punct("]") {
            depth -= 1;
        } else if t.is_ident("in") && depth == 0 {
            return true;
        } else if t.is_punct("{") || t.is_punct(";") {
            return false;
        }
        j += 1;
    }
    false
}

fn rule_no_timing(ctx: &Ctx, out: &mut Vec<Finding>) {
    let whole = TIMING_WHOLE_FILE.contains(&ctx.rel);
    let loops_only = TIMING_LOOPS_ONLY.contains(&ctx.rel);
    if !whole && !loops_only {
        return;
    }
    let toks = ctx.toks();
    // brace stack: which open blocks are loop bodies. `pending` holds the
    // bracket depth a loop keyword was seen at, so the body `{` is matched
    // at that same depth (header parens/brackets sit deeper).
    let mut stack: Vec<bool> = Vec::new();
    let mut pending: Option<isize> = None;
    let mut depth = 0isize;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.is_punct("(") || t.is_punct("[") {
            depth += 1;
        } else if t.is_punct(")") || t.is_punct("]") {
            depth -= 1;
        } else if t.is_punct("{") {
            stack.push(pending == Some(depth));
            if pending == Some(depth) {
                pending = None;
            }
        } else if t.is_punct("}") {
            stack.pop();
        } else if t.is_punct(";") && pending == Some(depth) {
            pending = None;
        } else if t.is_ident("while")
            || t.is_ident("loop")
            || (t.is_ident("for") && for_loop_header(toks, i))
        {
            pending = Some(depth);
        }
        let timing = t.is_ident("Instant")
            || (t.is_ident("trace")
                && next_is_punct(toks, i, ":")
                && toks.get(i + 2).is_some_and(|o| o.is_punct(":")));
        if !timing || ctx.in_test(i) {
            continue;
        }
        if whole || stack.iter().any(|&l| l) {
            out.push(ctx.finding(
                RULE_NO_TIMING,
                t.line,
                format!(
                    "`{}` in micro-kernel code — clock reads and trace emission are \
                     banned below chunk granularity (overhead budget); hoist the span \
                     to the dispatch prologue",
                    t.text
                ),
            ));
        }
    }
}

// ------------------------------------------------------- allow comments --

fn known_rule(name: &str) -> bool {
    RULES.iter().any(|(r, _)| *r == name && *r != RULE_ALLOW_SYNTAX)
}

fn parse_allows(ctx: &Ctx, out: &mut Vec<Finding>) -> Vec<Allow> {
    let mut allows = Vec::new();
    for c in &ctx.lex.comments {
        // a candidate allow *starts* with `sq-lint:` right after the
        // comment delimiters — prose that merely mentions the convention
        // (like this module's own docs) is not an allow attempt
        let body = c
            .text
            .trim_start_matches(|ch| ch == '/' || ch == '*' || ch == '!')
            .trim_start();
        if !body.starts_with("sq-lint:") {
            continue;
        }
        if !c.text.starts_with("//") {
            out.push(ctx.finding(
                RULE_ALLOW_SYNTAX,
                c.line,
                "sq-lint allow must be a `//` line comment (block comments don't suppress)"
                    .to_string(),
            ));
            continue;
        }
        let rest = body["sq-lint:".len()..].trim_start();
        let Some(body) = rest.strip_prefix("allow(") else {
            out.push(ctx.finding(
                RULE_ALLOW_SYNTAX,
                c.line,
                format!("expected `sq-lint: allow(<rule>) — <reason>`, got `{}`", c.text.trim()),
            ));
            continue;
        };
        let Some(close) = body.find(')') else {
            out.push(ctx.finding(
                RULE_ALLOW_SYNTAX,
                c.line,
                "unterminated `allow(` — missing `)`".to_string(),
            ));
            continue;
        };
        let rule = body[..close].trim().to_string();
        if !known_rule(&rule) {
            out.push(ctx.finding(
                RULE_ALLOW_SYNTAX,
                c.line,
                format!("unknown rule `{rule}` in allow comment"),
            ));
            continue;
        }
        let reason = body[close + 1..]
            .trim_start_matches(|ch: char| {
                ch.is_whitespace() || ch == '-' || ch == '—' || ch == '–' || ch == ':'
            })
            .trim();
        if reason.is_empty() {
            out.push(ctx.finding(
                RULE_ALLOW_SYNTAX,
                c.line,
                format!("allow({rule}) without a reason — state why the finding is safe"),
            ));
            continue;
        }
        let covers = if ctx.lex.line_has_token(c.line) {
            vec![c.line] // trailing form: covers its own line only
        } else {
            // own-line form: covers the next line that has code on it
            ctx.lex.next_token_line(c.line).map(|l| vec![l]).unwrap_or_default()
        };
        allows.push(Allow { rule, covers });
    }
    allows
}

// --------------------------------------------------------------- driver --

/// Lint one file's source text. `rel` is the path relative to the lint
/// root (unix separators), e.g. `"coordinator/server.rs"` — the rules'
/// per-module scoping keys off it.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let tests = test_regions(&lexed);
    let ctx = Ctx { rel, lex: &lexed, tests };
    let mut out = Vec::new();
    rule_no_fma(&ctx, &mut out);
    rule_nested_dispatch(&ctx, &mut out);
    rule_det_iter(&ctx, &mut out);
    rule_no_panic(&ctx, &mut out);
    rule_safety(&ctx, &mut out);
    rule_lock_io(&ctx, &mut out);
    rule_bounded_retry(&ctx, &mut out);
    rule_no_timing(&ctx, &mut out);
    let allows = parse_allows(&ctx, &mut out);
    for f in &mut out {
        if f.rule != RULE_ALLOW_SYNTAX
            && allows.iter().any(|a| a.rule == f.rule && a.covers.contains(&f.line))
        {
            f.allowed = true;
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unallowed(fs: &[Finding]) -> usize {
        fs.iter().filter(|f| !f.allowed).count()
    }

    #[test]
    fn rules_table_is_consistent() {
        assert_eq!(RULES.len(), 9);
        assert!(known_rule(RULE_NO_FMA));
        assert!(known_rule(RULE_BOUNDED_RETRY));
        assert!(!known_rule("allow-syntax")); // can't allow the meta rule
        assert!(!known_rule("no-such-rule"));
    }

    #[test]
    fn scoping_keeps_out_of_scope_files_clean() {
        // mul_add outside the kernel files is not this rule's business
        let fs = lint_source("model/bert.rs", "fn f(a: f32) -> f32 { a.mul_add(2.0, 1.0) }");
        assert!(fs.iter().all(|f| f.rule != RULE_NO_FMA), "{fs:?}");
    }

    #[test]
    fn trailing_allow_covers_its_own_line_only() {
        let src = "fn f(v: &[u8]) {\n\
                   let a = v.first().unwrap(); // sq-lint: allow(no-panic-in-serving) — test one\n\
                   let b = v.last().unwrap();\n}";
        let fs = lint_source("coordinator/x.rs", src);
        let allowed: Vec<_> = fs.iter().filter(|f| f.allowed).collect();
        assert_eq!(allowed.len(), 1, "{fs:?}");
        assert_eq!(allowed[0].line, 2);
        assert_eq!(unallowed(&fs), 1);
    }

    #[test]
    fn own_line_allow_covers_the_next_code_line() {
        let src = "fn f(v: &[u8]) {\n\
                   // sq-lint: allow(no-panic-in-serving) — caller checked non-empty\n\
                   let a = v.first().unwrap();\n}";
        let fs = lint_source("coordinator/x.rs", src);
        assert_eq!(unallowed(&fs), 0, "{fs:?}");
        assert_eq!(fs.len(), 1);
    }

    #[test]
    fn malformed_allow_is_itself_a_finding() {
        for bad in [
            "// sq-lint: allow(no-panic-in-serving)", // no reason
            "// sq-lint: allow(not-a-rule) — reason", // unknown rule
            "// sq-lint: disable(no-fma) — reason",   // wrong verb
        ] {
            let fs = lint_source("model/x.rs", &format!("{bad}\nfn f() {{}}"));
            assert!(
                fs.iter().any(|f| f.rule == RULE_ALLOW_SYNTAX && !f.allowed),
                "`{bad}` should be an allow-syntax finding: {fs:?}"
            );
        }
    }

    #[test]
    fn allow_for_the_wrong_rule_does_not_suppress() {
        let src = "// sq-lint: allow(no-fma) — wrong rule on purpose\n\
                   fn f(v: &[u8]) { v.first().unwrap(); }";
        let fs = lint_source("coordinator/x.rs", src);
        assert_eq!(unallowed(&fs), 1, "{fs:?}");
    }
}
