//! `sq-lint` (§Static analysis): a self-contained source-level linter that
//! machine-checks the repo's bit-exactness, determinism and concurrency
//! contracts — the invariants that otherwise live only in doc comments and
//! runtime property tests.
//!
//! * [`lexer`] — hand-rolled Rust lexer (no external crates): token stream
//!   with comments, strings, raw strings, nested block comments and
//!   `#[cfg(test)]`-region tracking handled faithfully.
//! * [`rules`] — the rule engine: eight repo-specific rules with per-module
//!   scoping and a `// sq-lint: allow(<rule>) — <reason>` escape hatch
//!   (see [`rules::RULES`] for the shipped set).
//!
//! Entry points: [`lint_tree`] walks a source root (the `splitquant lint`
//! subcommand and the self-lint unit test both use it); [`lint_source`]
//! lints one file's text (the fixture corpus uses it directly).
//!
//! The linter lints **its own source tree in a unit test**
//! (`repo_source_tree_lints_clean`), so a patch that violates a contract —
//! or removes an allow-comment's justification — fails `cargo test` as
//! well as the CI `sq-lint` lane. Fixture files under `testdata/` are
//! lexer/rule test inputs, not compiled code: the walker skips any
//! directory named `testdata`.

pub mod lexer;
pub mod rules;

use std::path::Path;

pub use rules::{lint_source, Finding, RULES};

/// Outcome of linting a whole tree.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Number of `.rs` files visited.
    pub files: usize,
    /// All findings, allowed ones included, ordered by (path, line, rule).
    pub findings: Vec<Finding>,
}

impl LintReport {
    /// Findings not covered by an allow comment — the CI-failing set.
    pub fn unallowed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.allowed)
    }

    pub fn allowed_count(&self) -> usize {
        self.findings.iter().filter(|f| f.allowed).count()
    }
}

/// Lint every `.rs` file under `root` (recursively, deterministic order,
/// skipping `testdata/` fixture directories). Paths in the findings are
/// relative to `root` with `/` separators — the same keys the rules'
/// per-module scoping uses.
pub fn lint_tree(root: &Path) -> std::io::Result<LintReport> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut report = LintReport::default();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&path)?;
        report.files += 1;
        report.findings.extend(lint_source(&rel, &src));
    }
    report
        .findings
        .sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            if entry.file_name() == "testdata" {
                continue; // rule/lexer fixtures, not source code
            }
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::rules::*;
    use super::*;

    fn by_rule<'a>(fs: &'a [Finding], rule: &str) -> Vec<&'a Finding> {
        fs.iter().filter(|f| f.rule == rule).collect()
    }

    // ------------------------------------------------ fixture corpus --
    // One positive (rule fires) + one negative (rule stays quiet) fixture
    // per rule, as real files under testdata/ so the lexer runs on honest
    // multi-line sources rather than inline strings.

    #[test]
    fn fixture_no_fma_fires() {
        let fs = lint_source("tensor/simd.rs", include_str!("testdata/no_fma_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_NO_FMA).len(), 2, "{fs:?}");
    }

    #[test]
    fn fixture_no_fma_quiet_on_prose_and_lookalikes() {
        let fs = lint_source("tensor/simd.rs", include_str!("testdata/no_fma_neg.rs"));
        assert!(by_rule(&fs, RULE_NO_FMA).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_nested_dispatch_fires() {
        let fs = lint_source("model/x.rs", include_str!("testdata/nested_dispatch_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_NESTED_DISPATCH).len(), 2, "{fs:?}");
    }

    #[test]
    fn fixture_nested_dispatch_quiet_on_prebuilt_tasks() {
        let fs = lint_source("model/x.rs", include_str!("testdata/nested_dispatch_neg.rs"));
        assert!(by_rule(&fs, RULE_NESTED_DISPATCH).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_det_iter_fires() {
        let fs = lint_source("autotune/x.rs", include_str!("testdata/det_iter_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_DET_ITER).len(), 3, "{fs:?}");
    }

    #[test]
    fn fixture_det_iter_quiet_on_btreemap_and_lookups() {
        let fs = lint_source("autotune/x.rs", include_str!("testdata/det_iter_neg.rs"));
        assert!(by_rule(&fs, RULE_DET_ITER).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_det_iter_scoped_to_artifact_dirs() {
        // the same source outside autotune//quant//report/ is not flagged
        let fs = lint_source("model/x.rs", include_str!("testdata/det_iter_pos.rs"));
        assert!(by_rule(&fs, RULE_DET_ITER).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_det_iter_fires_in_qhealth() {
        // qhealth/ renders byte-deterministic reports, so it sits under the
        // same ordered-iteration contract as the artifact dirs
        let fs = lint_source("qhealth/mod.rs", include_str!("testdata/det_iter_qhealth_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_DET_ITER).len(), 3, "{fs:?}");
    }

    #[test]
    fn fixture_det_iter_quiet_on_ordered_qhealth_state() {
        let fs = lint_source("qhealth/mod.rs", include_str!("testdata/det_iter_qhealth_neg.rs"));
        assert!(by_rule(&fs, RULE_DET_ITER).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_no_panic_fires() {
        let fs = lint_source("coordinator/x.rs", include_str!("testdata/no_panic_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_NO_PANIC).len(), 4, "{fs:?}");
    }

    #[test]
    fn fixture_no_panic_quiet_on_tests_ranges_and_fallbacks() {
        let fs = lint_source("coordinator/x.rs", include_str!("testdata/no_panic_neg.rs"));
        assert!(by_rule(&fs, RULE_NO_PANIC).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_safety_fires() {
        let fs = lint_source("runtime/x.rs", include_str!("testdata/safety_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_SAFETY).len(), 1, "{fs:?}");
    }

    #[test]
    fn fixture_safety_quiet_with_comment_above_or_trailing() {
        let fs = lint_source("runtime/x.rs", include_str!("testdata/safety_neg.rs"));
        assert!(by_rule(&fs, RULE_SAFETY).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_lock_io_fires() {
        let fs = lint_source("shardstore/x.rs", include_str!("testdata/lock_io_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_LOCK_IO).len(), 2, "{fs:?}");
    }

    #[test]
    fn fixture_lock_io_quiet_when_guard_dropped_first() {
        let fs = lint_source("shardstore/x.rs", include_str!("testdata/lock_io_neg.rs"));
        assert!(by_rule(&fs, RULE_LOCK_IO).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_no_timing_fires_in_loop_bodies_only() {
        let fs = lint_source("parallel/kernels.rs", include_str!("testdata/no_timing_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_NO_TIMING).len(), 2, "{fs:?}");
    }

    #[test]
    fn fixture_no_timing_whole_file_in_tensor_kernels() {
        let fs = lint_source("tensor/ops.rs", include_str!("testdata/no_timing_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_NO_TIMING).len(), 3, "{fs:?}");
    }

    #[test]
    fn fixture_no_timing_quiet_on_annotated_chunk_spans() {
        let fs = lint_source("parallel/kernels.rs", include_str!("testdata/no_timing_neg.rs"));
        let hits = by_rule(&fs, RULE_NO_TIMING);
        assert_eq!(hits.len(), 1, "{fs:?}");
        assert!(hits.iter().all(|f| f.allowed), "{fs:?}");
    }

    #[test]
    fn fixture_no_timing_scoped_to_kernel_files() {
        let fs = lint_source("model/x.rs", include_str!("testdata/no_timing_pos.rs"));
        assert!(by_rule(&fs, RULE_NO_TIMING).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_bounded_retry_fires() {
        let fs = lint_source("shardstore/x.rs", include_str!("testdata/bounded_retry_pos.rs"));
        assert_eq!(by_rule(&fs, RULE_BOUNDED_RETRY).len(), 2, "{fs:?}");
    }

    #[test]
    fn fixture_bounded_retry_quiet_on_capped_and_conditional_loops() {
        let fs = lint_source("shardstore/x.rs", include_str!("testdata/bounded_retry_neg.rs"));
        assert!(by_rule(&fs, RULE_BOUNDED_RETRY).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_bounded_retry_scoped_to_serving_dirs() {
        // the same unbounded loops outside coordinator//shardstore/ are not
        // this rule's business (kernels and utils spin by design)
        let fs = lint_source("model/x.rs", include_str!("testdata/bounded_retry_pos.rs"));
        assert!(by_rule(&fs, RULE_BOUNDED_RETRY).is_empty(), "{fs:?}");
    }

    #[test]
    fn fixture_lexer_torture_produces_no_findings() {
        // mul_add in a raw string, unwrap in a normal string, unsafe inside
        // a nested block comment, sq-lint text inside a string: all inert
        let fs = lint_source("tensor/simd.rs", include_str!("testdata/torture.rs"));
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn block_comment_allow_is_rejected() {
        let src = "/* sq-lint: allow(no-fma) — wrong comment style */\nfn f() {}";
        let fs = lint_source("model/x.rs", src);
        assert_eq!(by_rule(&fs, RULE_ALLOW_SYNTAX).len(), 1, "{fs:?}");
    }

    // ------------------------------------------------------ self-lint --

    #[test]
    fn repo_source_tree_lints_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src");
        let report = lint_tree(&root).expect("walking rust/src");
        assert!(report.files > 30, "walker only found {} files", report.files);
        let bad: Vec<String> = report.unallowed().map(|f| f.to_string()).collect();
        assert!(
            bad.is_empty(),
            "sq-lint: {} unallowed finding(s) in the repo tree:\n{}",
            bad.len(),
            bad.join("\n")
        );
    }

    #[test]
    fn walker_skips_testdata_fixtures() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("rust")
            .join("src")
            .join("analysis");
        let report = lint_tree(&root).expect("walking analysis/");
        // exactly this module's three source files, none of the fixtures
        assert_eq!(report.files, 3, "{report:?}");
    }
}
