//! A hand-rolled Rust lexer for the `sq-lint` invariant linter.
//!
//! The linter's rules are *lexical*: they need a faithful token stream
//! (identifiers, punctuation, literals) with comments and string contents
//! kept out of it — `mul_add` in a doc comment is prose, `"unwrap()"` in a
//! string literal is data — plus line numbers so findings and
//! `sq-lint: allow` comments can be matched up. Nothing here parses Rust
//! grammar; the rule engine works on token patterns and brace/paren
//! matching, which is all the repo's invariants need (no external crates,
//! per the sandbox rules — this is the whole point of hand-rolling).
//!
//! Handled faithfully, because the rules depend on it:
//! * line (`//`) and nested block (`/* /* */ */`) comments — captured
//!   separately for the `safety-comment` rule and allow-comment parsing;
//! * string, byte-string, raw-string (`r#"…"#`, any hash count) and char
//!   literals — their contents never become tokens;
//! * `'a` lifetimes vs `'x'` char literals;
//! * raw identifiers (`r#fn`).

/// What kind of lexeme a [`Token`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `let`, `matmul`, …).
    Ident,
    /// `'a`-style lifetime (the leading quote is kept in the text).
    Lifetime,
    /// String / char / numeric literal (contents opaque to the rules).
    Literal,
    /// A single punctuation character (`{`, `(`, `.`, `#`, …).
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

/// One comment (either style), with the line it *starts* on and its full
/// text including the `//` / `/*` delimiters.
#[derive(Debug, Clone)]
pub struct Comment {
    pub text: String,
    pub line: usize,
    /// Line the comment ends on (same as `line` for `//` comments).
    pub end_line: usize,
}

/// The lexed form of one source file.
#[derive(Debug, Default)]
pub struct LexFile {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

impl LexFile {
    /// `true` if any token sits on `line` (used to tell a trailing comment
    /// from one on a line of its own).
    pub fn line_has_token(&self, line: usize) -> bool {
        self.tokens.iter().any(|t| t.line == line)
    }

    /// The first token line strictly greater than `line`, if any.
    pub fn next_token_line(&self, line: usize) -> Option<usize> {
        self.tokens.iter().map(|t| t.line).filter(|&l| l > line).min()
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Lex `src` into tokens + comments. Never fails: unterminated constructs
/// simply run to end-of-file (the linter must not panic on the tree it is
/// guarding).
pub fn lex(src: &str) -> LexFile {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = LexFile::default();
    let mut i = 0usize;
    let mut line = 1usize;

    let at = |i: usize| -> char {
        if i < n {
            cs[i]
        } else {
            '\0'
        }
    };

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // line comment
        if c == '/' && at(i + 1) == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment {
                text: cs[start..i].iter().collect(),
                line,
                end_line: line,
            });
            continue;
        }

        // nested block comment
        if c == '/' && at(i + 1) == '*' {
            let start = i;
            let start_line = line;
            i += 2;
            let mut depth = 1usize;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && at(i + 1) == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && at(i + 1) == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.comments.push(Comment {
                text: cs[start..i].iter().collect(),
                line: start_line,
                end_line: line,
            });
            continue;
        }

        // raw strings (r"…", r#"…"#, br#"…"#) and raw identifiers (r#fn)
        if (c == 'r' || c == 'b') && {
            let mut j = i + 1;
            if c == 'b' && at(j) == 'r' {
                j += 1;
            }
            let raw_prefixed = j > i + 1 || c == 'r';
            let mut hashes = 0usize;
            while at(j + hashes) == '#' {
                hashes += 1;
            }
            raw_prefixed && (at(j + hashes) == '"' || (hashes == 1 && is_ident_start(at(j + 1))))
        } {
            let mut j = i + 1;
            if c == 'b' && at(j) == 'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            while at(j + hashes) == '#' {
                hashes += 1;
            }
            if at(j + hashes) == '"' {
                // raw (byte) string: runs to `"` followed by `hashes` hashes
                let start_line = line;
                let mut k = j + hashes + 1;
                loop {
                    if k >= n {
                        break;
                    }
                    if cs[k] == '\n' {
                        line += 1;
                        k += 1;
                        continue;
                    }
                    if cs[k] == '"' {
                        let mut h = 0usize;
                        while h < hashes && at(k + 1 + h) == '#' {
                            h += 1;
                        }
                        if h == hashes {
                            k += 1 + hashes;
                            break;
                        }
                    }
                    k += 1;
                }
                out.tokens.push(Token {
                    kind: TokKind::Literal,
                    text: String::from("\"raw\""),
                    line: start_line,
                });
                i = k;
                continue;
            }
            // raw identifier r#name: token text is the bare name
            let mut k = j + 1;
            while k < n && is_ident_continue(cs[k]) {
                k += 1;
            }
            out.tokens.push(Token {
                kind: TokKind::Ident,
                text: cs[j + 1..k].iter().collect(),
                line,
            });
            i = k;
            continue;
        }

        // string / byte-string literal
        if c == '"' || (c == 'b' && at(i + 1) == '"') {
            let start_line = line;
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            while j < n {
                if cs[j] == '\\' {
                    if at(j + 1) == '\n' {
                        line += 1;
                    }
                    j += 2;
                } else if cs[j] == '"' {
                    j += 1;
                    break;
                } else {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            out.tokens.push(Token {
                kind: TokKind::Literal,
                text: String::from("\"str\""),
                line: start_line,
            });
            i = j;
            continue;
        }

        // char literal vs lifetime
        if c == '\'' || (c == 'b' && at(i + 1) == '\'') {
            let q = if c == 'b' { i + 1 } else { i };
            let is_char = at(q + 1) == '\\' || at(q + 2) == '\'' || !is_ident_start(at(q + 1));
            if is_char {
                let mut j = q + 1;
                while j < n {
                    if cs[j] == '\\' {
                        j += 2;
                    } else if cs[j] == '\'' {
                        j += 1;
                        break;
                    } else {
                        if cs[j] == '\n' {
                            line += 1;
                        }
                        j += 1;
                    }
                }
                out.tokens.push(Token {
                    kind: TokKind::Literal,
                    text: String::from("'c'"),
                    line,
                });
                i = j;
                continue;
            }
            // lifetime: `'` + ident, no closing quote
            let mut j = q + 1;
            while j < n && is_ident_continue(cs[j]) {
                j += 1;
            }
            out.tokens.push(Token {
                kind: TokKind::Lifetime,
                text: cs[q..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }

        // identifier / keyword
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(cs[i]) {
                i += 1;
            }
            out.tokens.push(Token {
                kind: TokKind::Ident,
                text: cs[start..i].iter().collect(),
                line,
            });
            continue;
        }

        // numeric literal (digits, suffixes, `_`; a `.` only when it starts
        // a fraction — `0..10` must keep its range dots as punctuation)
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (is_ident_continue(cs[i])) {
                i += 1;
            }
            if at(i) == '.' && at(i + 1).is_ascii_digit() {
                i += 1;
                while i < n && is_ident_continue(cs[i]) {
                    i += 1;
                }
            }
            out.tokens.push(Token {
                kind: TokKind::Literal,
                text: cs[start..i].iter().collect(),
                line,
            });
            continue;
        }

        // everything else: single-char punctuation
        out.tokens.push(Token { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }

    out
}

/// Token index ranges (`[start, end)`) covering test-only code: items
/// under a `#[cfg(test)]` / `#[test]` attribute, attribute included.
///
/// Detection is deliberately conservative and lexical: an attribute whose
/// identifier list is exactly `test`, or starts with `cfg` and mentions
/// `test` without `not`, marks the following item (attributes chain; the
/// item body is the brace-matched block, or nothing if a `;` lands first).
pub fn test_regions(lex: &LexFile) -> Vec<(usize, usize)> {
    let toks = &lex.tokens;
    let n = toks.len();
    let mut regions = Vec::new();
    let mut i = 0usize;
    while i < n {
        if !(toks[i].is_punct("#") && i + 1 < n && toks[i + 1].is_punct("[")) {
            i += 1;
            continue;
        }
        let attr_start = i;
        // bracket-match the attribute body
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut idents: Vec<&str> = Vec::new();
        while j < n && depth > 0 {
            if toks[j].is_punct("[") {
                depth += 1;
            } else if toks[j].is_punct("]") {
                depth -= 1;
            } else if toks[j].kind == TokKind::Ident {
                idents.push(&toks[j].text);
            }
            j += 1;
        }
        let is_test_attr = idents.as_slice() == ["test"]
            || (idents.first() == Some(&"cfg")
                && idents.iter().any(|s| *s == "test")
                && !idents.iter().any(|s| *s == "not"));
        if !is_test_attr {
            i = j;
            continue;
        }
        // skip any further attributes on the same item
        let mut k = j;
        while k + 1 < n && toks[k].is_punct("#") && toks[k + 1].is_punct("[") {
            let mut d = 1usize;
            k += 2;
            while k < n && d > 0 {
                if toks[k].is_punct("[") {
                    d += 1;
                } else if toks[k].is_punct("]") {
                    d -= 1;
                }
                k += 1;
            }
        }
        // find the item body: first `{` before a top-level `;`
        let mut body_end = None;
        let mut m = k;
        while m < n {
            if toks[m].is_punct(";") {
                body_end = Some(m + 1);
                break;
            }
            if toks[m].is_punct("{") {
                let mut d = 1usize;
                let mut p = m + 1;
                while p < n && d > 0 {
                    if toks[p].is_punct("{") {
                        d += 1;
                    } else if toks[p].is_punct("}") {
                        d -= 1;
                    }
                    p += 1;
                }
                body_end = Some(p);
                break;
            }
            m += 1;
        }
        let end = body_end.unwrap_or(n);
        regions.push((attr_start, end));
        i = end;
    }
    regions
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(lex: &LexFile) -> Vec<&str> {
        lex.tokens
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_their_contents() {
        let src = "let a = \"mul_add()\"; // mul_add\n/* unsafe */ let b = 1;";
        let lex = lex(src);
        assert_eq!(idents(&lex), ["let", "a", "let", "b"]);
        assert_eq!(lex.comments.len(), 2);
        assert!(lex.comments[0].text.contains("mul_add"));
    }

    #[test]
    fn raw_strings_any_hash_count() {
        let src = "let s = r##\"quote \"# inside unwrap()\"##; call();";
        let lex = lex(src);
        assert_eq!(idents(&lex), ["let", "s", "call"]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner unsafe */ still comment */ fn f() {}";
        let lex = lex(src);
        assert_eq!(idents(&lex), ["fn", "f"]);
        assert_eq!(lex.comments.len(), 1);
    }

    #[test]
    fn lifetime_vs_char_literal() {
        let src = "fn f<'a>(x: &'a u8) { let c = 'x'; let e = '\\n'; }";
        let lex = lex(src);
        let lifetimes: Vec<_> =
            lex.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        assert!(idents(&lex).contains(&"c"));
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "/* a\nb\nc */\nlet x = \"p\nq\";\nlet y = 2;";
        let lex = lex(src);
        let y = lex.tokens.iter().find(|t| t.is_ident("y")).unwrap();
        assert_eq!(y.line, 6);
    }

    #[test]
    fn range_dots_stay_punctuation() {
        let src = "for i in 0..10 {}";
        let lex = lex(src);
        let dots = lex.tokens.iter().filter(|t| t.is_punct(".")).count();
        assert_eq!(dots, 2);
    }

    #[test]
    fn cfg_test_region_covers_module_body() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n fn t() { inner(); }\n}\nfn after() {}";
        let lex = lex(src);
        let regions = test_regions(&lex);
        assert_eq!(regions.len(), 1);
        let (s, e) = regions[0];
        let inner = lex.tokens.iter().position(|t| t.is_ident("inner")).unwrap();
        let after = lex.tokens.iter().position(|t| t.is_ident("after")).unwrap();
        assert!(s < inner && inner < e);
        assert!(after >= e);
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nfn live() { body(); }";
        let lex = lex(src);
        assert!(test_regions(&lex).is_empty());
    }

    #[test]
    fn raw_identifiers_lex_as_idents() {
        let src = "let r#fn = 1; let r = 2;";
        let lex = lex(src);
        assert_eq!(idents(&lex), ["let", "fn", "let", "r"]);
    }
}
