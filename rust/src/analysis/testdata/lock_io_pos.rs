//! Fixture (positive): a lock guard held across file IO, and one held
//! across pooled dispatch — two findings (`lock_recover` counts as a lock).

pub fn fault(file: &Mutex<File>, buf: &mut Vec<u8>) -> io::Result<()> {
    let mut f = file.lock().unwrap();
    f.seek(SeekFrom::Start(0))?;
    f.read_exact(buf)
}

pub fn dispatch(m: &Mutex<State>, a: &Tensor, b: &Tensor) -> Tensor {
    let guard = lock_recover(m);
    let out = matmul(a, b);
    drop(guard);
    out
}
