//! Fixture (negative): range slicing, `unwrap_or` fallbacks and
//! `#[cfg(test)]` code are all exempt.

pub fn admit(v: &[u32]) -> u32 {
    let head = &v[..1];
    head.first().copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_code_may_unwrap_and_index() {
        let v = vec![1u32, 2];
        assert_eq!(admit(&v), 1);
        let x = v.last().unwrap();
        assert_eq!(*x + v[0], 3);
    }
}
