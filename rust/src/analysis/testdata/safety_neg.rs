//! Fixture (negative): every accepted `// SAFETY:` placement — a comment
//! block directly above, and the trailing same-line form.

pub fn deref(p: *const u8) -> u8 {
    // SAFETY: the caller guarantees `p` points at a live byte (fixture;
    // a second comment line between SAFETY and the keyword is fine).
    unsafe { *p }
}

// SAFETY: this impl is a fixture; the type owns no thread-affine state.
unsafe impl Send for Fixture {}

pub struct Fixture;

pub fn trailing(p: *const u8) -> u8 {
    let v = unsafe { *p }; // SAFETY: trailing-comment form, same line.
    v
}
