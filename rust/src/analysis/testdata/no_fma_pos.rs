//! Fixture (positive): FMA intrinsics in a kernel-scoped file must fire
//! `no-fma` — once for `mul_add`, once for `fma`.

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc = a[i].mul_add(b[i], acc);
    }
    acc
}

pub fn fused(x: f64, y: f64, z: f64) -> f64 {
    fma(x, y, z)
}
