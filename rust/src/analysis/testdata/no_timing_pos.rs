//! Positive fixture for `no-timing-in-kernels`: one clock read in the
//! dispatch prologue and two trace emissions inside loop bodies. Linted as
//! `parallel/kernels.rs` (loops-only scope) exactly the two in-loop sites
//! fire; as `tensor/ops.rs` (whole-file scope) all three fire; under any
//! other path the rule stays quiet.

pub fn hot_path(rows: usize) -> u64 {
    let t0 = std::time::Instant::now(); // whole-file facet only
    let mut acc = 0u64;
    for r in 0..rows {
        let _sp = crate::trace::kernel_span("chunk", r as u64, 1);
        acc += r as u64;
    }
    let mut i = 0u64;
    while i < rows as u64 {
        crate::trace::count("inner-probe", 1);
        i += 1;
    }
    acc + i + t0.elapsed().as_nanos() as u64
}
