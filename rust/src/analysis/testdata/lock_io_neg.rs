//! Fixture (negative): guard dropped (inner block) before the IO happens,
//! and a statement-level temporary that touches no IO.

pub fn fault(file: &Mutex<State>, buf: &mut Vec<u8>) -> io::Result<u64> {
    let off = {
        let state = file.lock().unwrap();
        state.offset()
    };
    read_at(off, buf)?;
    Ok(off)
}

pub fn counter(m: &Mutex<u64>) -> u64 {
    *m.lock().unwrap()
}
