//! Fixture (positive): pooled kernel entry points called lexically inside
//! a `WorkerPool::scope(...)` argument — two findings.

pub fn bad(pool: &WorkerPool, a: &Tensor, b: &Tensor) {
    pool.scope(vec![Box::new(move || {
        let _ = matmul(a, b);
    })]);
    pool.scope(vec![Box::new(move || drop(split_matmul(a, b)))]);
}
