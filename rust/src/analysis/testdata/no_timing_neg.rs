//! Negative fixture for `no-timing-in-kernels` under the loops-only scope
//! (`parallel/kernels.rs`): a prologue span outside any loop is fine, an
//! annotated chunk span inside the partition loop is allowed, and an
//! `impl Trait for Type` header must not be mistaken for a for-loop.

pub struct Dispatcher;

pub trait Run {
    fn run(&self, rows: usize) -> u64;
}

impl Run for Dispatcher {
    fn run(&self, rows: usize) -> u64 {
        let _sp = crate::trace::kernel_span("dispatch", 0, rows as u64);
        let mut acc = 0u64;
        for r in 0..rows {
            // sq-lint: allow(no-timing-in-kernels) — chunk-granularity span, one per task closure
            let _c = crate::trace::kernel_span("chunk", r as u64, 1);
            acc += r as u64;
        }
        acc
    }
}
