//! Positive fixture: unconditional retry loops in serving code that never
//! name an attempt bound — `bounded-retry` fires on both.

fn keep_reading(io: &dyn ShardIo, name: &str) -> Vec<u8> {
    loop {
        if let Ok(bytes) = io.read_raw(name) {
            return bytes;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}

fn poll_until_present(store: &Store, name: &str) -> Data {
    while true {
        if let Some(d) = store.fetch(name) {
            return d;
        }
    }
}
