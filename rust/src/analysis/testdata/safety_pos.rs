//! Fixture (positive): `unsafe` with no `// SAFETY:` comment — one finding.

pub fn deref(p: *const u8) -> u8 {
    unsafe { *p }
}
