//! Lexer torture fixture: linted as `tensor/simd.rs`, must produce ZERO
//! findings — every trigger below is hidden in a string or comment.

pub fn tricky() -> String {
    let raw = r##"call .mul_add(x, y) then fma() and .unwrap() // sq-lint: allow(no-fma) — fake"##;
    let s = "unsafe { panic!(\"no\") }";
    /* block comments can nest: /* inner unsafe mul_add */ and resume */
    let lifetime_not_char: &'static str = "ok";
    let c = 'x';
    let esc = '\'';
    format!("{raw}{s}{lifetime_not_char}{c}{esc}")
}
