//! Fixture (negative): the shape `qhealth/` actually uses — `BTreeMap`
//! iteration (sorted, so the report is byte-deterministic) plus `HashMap`
//! point lookups and size queries that leak no ordering — no findings.

use std::collections::{BTreeMap, HashMap};

pub fn snapshot(sites: &BTreeMap<usize, u64>, cache: &HashMap<String, u64>) -> Vec<String> {
    let mut out = Vec::new();
    for (site, clipped) in sites {
        out.push(format!("site {site}: clipped={clipped}"));
    }
    if let Some(hits) = cache.get("shadow-samples") {
        out.push(hits.to_string());
    }
    out.push(cache.len().to_string());
    out
}
