//! Fixture (negative): mul_add in prose, strings and lookalike
//! identifiers must NOT fire `no-fma` — the rule matches whole tokens.

pub fn matmul_rows(a: &[f32], out: &mut f32) {
    // a real kernel must not use mul_add (that is the whole contract)
    let s = "calling .mul_add() or fma() in a string is data, not code";
    let mul_add_sites = s.len(); // lookalike binder, not the intrinsic
    *out = a.len() as f32 + mul_add_sites as f32;
}
