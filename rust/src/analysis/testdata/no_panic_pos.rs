//! Fixture (positive): four ways serving code can panic — `unwrap`,
//! `expect`, `panic!` and `[idx]` indexing.

pub fn admit(v: &[u32], o: Option<u32>) -> u32 {
    let a = o.unwrap();
    let b = o.expect("always present");
    if v.is_empty() {
        panic!("empty batch");
    }
    a + b + v[0]
}
