//! Fixture (positive): HashMap iteration in a numeric-health module —
//! snapshot/report order would depend on hash state, breaking the
//! byte-deterministic `doctor` report. Three findings: a `for … in`, a
//! `.keys()`, and a `.drain()`.

use std::collections::HashMap;

pub fn snapshot(sites: &HashMap<usize, u64>) -> Vec<String> {
    let mut out = Vec::new();
    for (site, clipped) in sites {
        out.push(format!("site {site}: clipped={clipped}"));
    }
    let layers: Vec<&usize> = sites.keys().collect();
    out.push(layers.len().to_string());
    let mut occupancy = HashMap::new();
    occupancy.insert("encoder.0.attn.q".to_string(), 3u64);
    let drained: Vec<(String, u64)> = occupancy.drain().collect();
    out.push(drained.len().to_string());
    out
}
