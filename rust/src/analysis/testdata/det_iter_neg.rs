//! Fixture (negative): BTreeMap iteration is ordered, HashMap point
//! lookups and size queries don't leak ordering — no findings.

use std::collections::{BTreeMap, HashMap};

pub fn emit(plan: &BTreeMap<String, u8>, stats: &HashMap<String, u64>) -> Vec<String> {
    let mut out = Vec::new();
    for (name, bits) in plan {
        out.push(format!("{name}={bits}"));
    }
    if let Some(hits) = stats.get("total") {
        out.push(hits.to_string());
    }
    out.push(stats.len().to_string());
    out
}
