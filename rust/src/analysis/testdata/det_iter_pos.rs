//! Fixture (positive): HashMap iteration in an artifact-producing module —
//! three findings: a `for … in`, a `.keys()`, and a `.drain()`.

use std::collections::HashMap;

pub fn emit(plan: &HashMap<String, u8>) -> Vec<String> {
    let mut out = Vec::new();
    for (name, bits) in plan {
        out.push(format!("{name}={bits}"));
    }
    let names: Vec<&String> = plan.keys().collect();
    out.push(names.len().to_string());
    let mut index = HashMap::new();
    index.insert(1u8, 2u8);
    let drained: Vec<(u8, u8)> = index.drain().collect();
    out.push(drained.len().to_string());
    out
}
