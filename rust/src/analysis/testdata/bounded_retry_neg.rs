//! Negative fixture: loops `bounded-retry` must stay quiet on — a retry
//! loop that names its cap, an unconditional loop with no reads in it, a
//! condition-driven re-read, and a test-region loop.

fn bounded(io: &dyn ShardIo, name: &str) -> Result<Vec<u8>> {
    let max_attempts = 3;
    let mut tried = 0;
    loop {
        tried += 1;
        match io.read_raw(name) {
            Ok(b) => return Ok(b),
            Err(e) if tried >= max_attempts => return Err(e),
            Err(_) => {}
        }
    }
}

fn drains_a_queue(q: &mut Vec<u64>) -> u64 {
    let mut acc = 0;
    loop {
        match q.pop() {
            Some(v) => acc += v,
            None => return acc,
        }
    }
}

fn condition_driven_reread(io: &dyn ShardIo, name: &str, want: usize) -> Vec<u8> {
    let mut bytes = Vec::new();
    while bytes.len() < want {
        if let Ok(b) = io.read_raw(name) {
            bytes = b;
        }
    }
    bytes
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_spin_on_a_read() {
        loop {
            if fetch() {
                break;
            }
        }
    }
}
