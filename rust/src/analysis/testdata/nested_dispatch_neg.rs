//! Fixture (negative): pooled calls *before* entering the pool, prebuilt
//! task vectors, micro-kernel names and the `scope` definition itself are
//! all fine.

pub fn good(pool: &WorkerPool, a: &Tensor, b: &Tensor, tasks: Vec<Task>) {
    let _warm = matmul(a, b); // dispatch before the scope: not nested
    pool.scope(tasks); // tasks built elsewhere: lexically clean
    let _rows = matmul_rows(a, b); // micro-kernel, not a dispatcher
}

pub fn scope(tasks: Vec<Task>) {
    run(tasks) // a fn *named* scope is not a pool submit
}
